"""Benchmark harness: one entry per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` scales up the
trace sizes; default sizing finishes on a single CPU core.

Besides the stdout CSV, every run writes
``results/bench/run_summary.json``: one entry per executed cell with its
wall time and the process peak RSS observed when the cell finished
(``ru_maxrss`` — a high-water mark, so per-cell values are monotone
within a run; the delta between consecutive cells bounds a cell's own
footprint).

Exit code contract (the CI lanes depend on it): any selected bench that
raises — including a failure while deriving its summary cell — produces
an ``ERROR:`` row and a non-zero exit; ``--only`` with a name that
matches no bench is an argument error, never a silent empty run.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _peak_rss_mb() -> float:
    """Process high-water-mark RSS in MiB (0.0 where unsupported)."""
    try:
        import resource
        kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    except Exception:       # pragma: no cover - non-POSIX
        return 0.0
    # Linux reports KiB; macOS reports bytes.
    if sys.platform == "darwin":    # pragma: no cover
        kb /= 1024.0
    return round(kb / 1024.0, 2)


def _raise_on_grid_failures(summary) -> None:
    """A policy crashing mid-grid is a bench failure, not a smaller grid."""
    fails = summary.get("failures") or []
    if fails:
        raise RuntimeError(
            "policy failures: " + "; ".join(
                f"{f['policy']} ({len(f['cells'])} cells): {f['error']}"
                for f in fails))


def _run(name, fn, **kw):
    t0 = time.time()
    try:
        out = fn(**kw)
        dt = time.time() - t0
        return name, dt, out, None
    except Exception as e:
        traceback.print_exc()
        return name, time.time() - t0, None, f"{type(e).__name__}: {e}"


def _derived(name, out) -> str:
    if name == "overhead_vF":
        return (f"decision={out['decision_latency_s'] * 1e3:.1f}ms;"
                f"bar2s={'PASS' if out['meets_paper_bar'] else 'FAIL'}")
    if name == "obs_overhead":
        o = out["overhead"]
        return (f"off={o['obs_off_overhead']:.2%};on="
                f"{o['obs_on_overhead']:.2%};budget="
                f"{'PASS' if o['off_within_budget'] else 'FAIL'};parity="
                f"{'PASS' if out['events']['parity_seq_vec'] else 'FAIL'}")
    if name == "roofline_g":
        s = out["summary"]
        return (f"cells_ok={s['baseline_cells_ok']};"
                f"skipped={s['baseline_cells_skipped']}")
    if name == "scheduling_fig5_6_7":
        ks = {n: d["kiviat"] for n, d in out["scenarios"].items()}
        wins = sum(1 for k in ks.values() if max(k, key=k.get) == "MRSch")
        derived = f"MRSch_best_in={wins}/{len(ks)}"
        if "vector_sweep" in out:
            sw = out["vector_sweep"]
            derived += (f";sweep_speedup_N{sw['n_envs']}="
                        f"{sw['decision_throughput_speedup']:.2f}x")
        return derived
    if name == "eval_matrix":
        s = out["summary"]
        _raise_on_grid_failures(s)
        return (f"cells={s['n_cells']};wins="
                + "/".join(f"{k}:{v}" for k, v in s["wins"].items()))
    if name == "tournament":
        s = out["summary"]
        _raise_on_grid_failures(s)
        imp = out["relative_improvement"]
        derived = f"policies={s['n_policies']};leader={s['leader']}"
        if imp["max"] is not None:
            derived += f";{imp['reference']}_wait_cut_max={imp['max']:+.1%}"
        return derived
    if name == "queue_encoder_ab":
        ratios = out["wait_ratio_attention_vs_mlp"]
        trained = out["loss"]["attention"]["decreased"]
        return (f"attn_trains={'PASS' if trained else 'FAIL'};"
                + ";".join(f"{k.split('-')[-1]}_wait_ratio={v:.2f}"
                           for k, v in ratios.items()))
    if name == "state_module_fig3":
        if "kiviat" in out:
            k = out["kiviat"]
            return f"MLP={k.get('MLP', 0):.3f};CNN={k.get('CNN', 0):.3f}"
        s = out["shapes"][-1]       # --backend microbench variant
        return (f"backend={out['backend']};fwd_speedup="
                f"{s.get('fwd_speedup_vs_xla', 1.0)}x")
    if name == "curriculum_fig4":
        fl = {k: v["final_loss"] for k, v in out.items()
              if k != "vector_training"}
        best = min((v, k) for k, v in fl.items() if v is not None)[1]
        derived = f"best_order={best}"
        vt = out.get("vector_training")
        if vt:
            derived += (f";train_speedup_N{vt['n_envs']}="
                        f"{vt['speedup']:.2f}x")
        return derived
    if name == "serving":
        s = out["summary"]
        loaded = [c for c in out["cells"]
                  if c["max_wait_ms"] > 0 and c["clients"] > 1]
        derived = ";".join(f"{k}={v}x" for k, v in s.items()
                           if "speedup" in k)
        if loaded:
            derived += f";p99_loaded={loaded[-1]['p99_ms']:.1f}ms"
        return derived
    if name == "goal_adaptation_fig8_9":
        return (f"rBB_S1={out['S1']['mean']:.3f};"
                f"rBB_S5={out['S5']['mean']:.3f}")
    if name == "three_resource_fig10":
        wins = sum(1 for d in out.values()
                   if max(d["kiviat"], key=d["kiviat"].get) == "MRSch")
        return f"MRSch_best_in={wins}/{len(out)}"
    return ""


def run_benches(benches) -> int:
    """Run every bench, print CSV rows, return the failure count.

    A failure is a bench body raising OR its derived-summary cell
    raising (a bench whose output lost a contract key is as broken as
    one that crashed) — both print an ``ERROR:`` row and count.  Each
    cell's wall time and peak RSS land in
    ``results/bench/run_summary.json``.
    """
    print("name,us_per_call,derived")
    failures = 0
    cells = {}
    for name, fn in benches.items():
        bname, dt, out, err = _run(name, fn)
        if err is None:
            try:
                derived = _derived(name, out)
            except Exception as e:
                traceback.print_exc()
                err = f"derived: {type(e).__name__}: {e}"
        cells[bname] = {"wall_s": round(dt, 3),
                        "peak_rss_mb": _peak_rss_mb(),
                        "ok": err is None}
        if err:
            failures += 1
            cells[bname]["error"] = err
            print(f"{bname},{dt * 1e6:.0f},ERROR:{err}", flush=True)
            continue
        print(f"{bname},{dt * 1e6:.0f},{derived}", flush=True)
    _save_summary(cells, failures)
    if failures:
        print(f"{failures}/{len(benches)} benches failed", file=sys.stderr)
    return failures


def _save_summary(cells, failures) -> None:
    try:
        from .common import save_json
        path = save_json("run_summary", {
            "schema": "mrsch.bench.run/v1",
            "cells": cells,
            "total_wall_s": round(sum(c["wall_s"] for c in cells.values()),
                                  3),
            "peak_rss_mb": _peak_rss_mb(),
            "failures": failures,
        })
        print(f"run summary -> {path}", file=sys.stderr)
    except Exception:       # a broken summary must not fail the benches
        traceback.print_exc()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--vector", type=int, default=0,
                    help="batched rollout width for the scheduling sweep "
                         "(0 = sequential only)")
    ap.add_argument("--backend", default=None, choices=("xla", "pallas"),
                    help="NN backend for the state-module/curriculum "
                         "benches (None = xla + Fig. 3 ablation)")
    args = ap.parse_args(argv)
    quick = not args.full

    from . import (bench_curriculum, bench_goal_adaptation, bench_obs,
                   bench_overhead, bench_queue_encoder, bench_roofline,
                   bench_scheduling, bench_serving, bench_state_module,
                   bench_three_resource)

    benches = {
        "overhead_vF": lambda: bench_overhead.run(quick=quick),
        "obs_overhead": lambda: bench_obs.run(quick=quick),
        "roofline_g": lambda: bench_roofline.run(quick=quick),
        "state_module_fig3": lambda: bench_state_module.run(
            quick=quick, backend=args.backend),
        "queue_encoder_ab": lambda: bench_queue_encoder.run(
            quick=quick, smoke=quick),
        "curriculum_fig4": lambda: bench_curriculum.run(
            quick=quick, backend=args.backend),
        "scheduling_fig5_6_7": lambda: bench_scheduling.run(
            quick=quick, vector=args.vector),
        "eval_matrix": lambda: bench_scheduling.run_matrix_bench(
            smoke=quick, vector=args.vector or 4),
        "tournament": lambda: bench_scheduling.run_tournament_bench(
            smoke=quick, vector=args.vector or 4),
        "serving": lambda: bench_serving.run(
            quick=quick,
            backends=(args.backend,) if args.backend else ("xla",)),
        "goal_adaptation_fig8_9": lambda: bench_goal_adaptation.run(quick=quick),
        "three_resource_fig10": lambda: bench_three_resource.run(quick=quick),
    }
    if args.only:
        keep = set(args.only.split(","))
        unknown = keep - set(benches)
        if unknown:
            ap.error(f"unknown bench name(s) {sorted(unknown)}; "
                     f"available: {', '.join(benches)}")
        benches = {k: v for k, v in benches.items() if k in keep}

    return 1 if run_benches(benches) else 0


if __name__ == "__main__":
    sys.exit(main())
