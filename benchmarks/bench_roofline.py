"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits the
EXPERIMENTS.md §Roofline table: three terms per (arch x shape), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and baseline->optimized deltas.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN = os.environ.get("REPRO_RESULTS", "results/dryrun")


def load_cells() -> Dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[os.path.basename(path)[:-5]] = rec
    return out


def table(mesh: str = "single", tag: str = "") -> List[dict]:
    rows = []
    for cell, rec in load_cells().items():
        parts = cell.split("__")
        if parts[2] != mesh or len(parts) > 4:
            continue
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "status": rec["status"]}
        if rec["status"] == "ok" and "roofline" in rec:
            r = rec["roofline"]
            row.update(
                compute_s=r["compute_s"], memory_s=r["memory_s"],
                collective_s=r["collective_s"], dominant=r["dominant"],
                frac=r["roofline_fraction"],
                useful_ratio=rec.get("useful_ratio"),
                mem_gb=rec["mem"]["total_hbm_gb"],
            )
        elif rec["status"] == "skipped":
            row["reason"] = rec.get("reason", "")[:60]
        rows.append(row)
    return rows


def markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| frac | useful | mem GB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] == "ok" and "frac" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
                f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
                f"| {r['dominant']} | {r['frac']:.2f} "
                f"| {r.get('useful_ratio') or 0:.2f} | {r['mem_gb']:.1f} |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — "
                         f"| {r['status']} | — | — | — |")
    return "\n".join(lines)


def run(quick: bool = True):
    base = table("single", "")
    opt = table("single", "opt") + table("single", "serve")
    out = {"baseline": base, "optimized": opt}
    n_ok = sum(1 for r in base if r["status"] == "ok")
    out["summary"] = {
        "baseline_cells_ok": n_ok,
        "baseline_cells_skipped": sum(1 for r in base
                                      if r["status"] == "skipped"),
        "mean_frac_baseline": (sum(r.get("frac", 0) for r in base
                                   if r["status"] == "ok") / max(n_ok, 1)),
    }
    from .common import save_json
    save_json("roofline", out)
    return out


if __name__ == "__main__":
    print(markdown(table("single", "")))
    print()
    print("### optimized")
    print(markdown(table("single", "opt") + table("single", "serve")))
