"""§V-F: decision latency (paper bar: < 2 s on a 2 GHz laptop CPU; HPC
schedulers must respond within 15-30 s) + the DFP-step §Perf hillclimb
measurements (H3) — this is the paper's own compute, measured wall-clock.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentConfig, MRSchAgent
from repro.core.agent import _train_step, _values
from repro.sim import Cluster, ResourceSpec
from repro.sim.simulator import SchedContext
from repro.sim.job import Job
from repro.workloads import THETA_BB_UNITS, THETA_NODES

from .common import save_json


def _theta_ctx(n_jobs: int = 10):
    c = Cluster([ResourceSpec("node", THETA_NODES),
                 ResourceSpec("bb", THETA_BB_UNITS)])
    window = [Job(i, 0.0, 3600.0, 7200.0, {"node": 128 * (i + 1), "bb": i})
              for i in range(n_jobs)]
    return SchedContext(now=100.0, cluster=c, window=window,
                        queue_len=n_jobs, running=[], queue=window)


def run(quick: bool = True, seed: int = 0):
    out = {}
    # Full paper-scale agent: 11410 -> 4000 -> 1000 -> 512.
    agent = MRSchAgent(
        [ResourceSpec("node", THETA_NODES), ResourceSpec("bb", THETA_BB_UNITS)],
        AgentConfig(seed=seed))
    ctx = _theta_ctx()

    # --- decision latency (encode + forward + argmax), incl. warmup split
    t0 = time.time()
    agent.select(ctx)
    out["first_decision_s"] = time.time() - t0           # includes jit compile
    reps = 10 if quick else 50
    t0 = time.time()
    for _ in range(reps):
        agent.select(ctx)
    per = (time.time() - t0) / reps
    out["decision_latency_s"] = per
    out["paper_bar_s"] = 2.0
    out["meets_paper_bar"] = bool(per < 2.0)

    # --- H3 iteration log: state-encoding vs network forward split
    from repro.core.encoding import encode_state
    t0 = time.time()
    for _ in range(reps):
        encode_state(agent.enc, ctx)
    out["encode_s"] = (time.time() - t0) / reps
    s = jnp.asarray(encode_state(agent.enc, ctx))
    m = jnp.zeros((2,), jnp.float32)
    g = jnp.full((2,), 0.5, jnp.float32)
    mask = jnp.ones((10,), bool)
    _values(agent.params, agent.dfp, s, m, g, mask).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        _values(agent.params, agent.dfp, s, m, g, mask).block_until_ready()
    out["forward_s"] = (time.time() - t0) / reps

    # --- training step latency (batched replay update)
    batch = {
        "state": jnp.asarray(np.random.randn(64, agent.enc.state_dim),
                             jnp.float32),
        "meas": jnp.zeros((64, 2)), "goal": jnp.full((64, 2), 0.5),
        "action": jnp.zeros((64,), jnp.int32),
        "target": jnp.zeros((64, 6, 2)), "target_mask": jnp.ones((64, 6)),
    }
    p, o = agent.params, agent.opt_state
    p, o, _ = _train_step(agent.dfp, p, o, batch, 1e-4, 10.0)  # compile
    t0 = time.time()
    for _ in range(5):
        p, o, loss = _train_step(agent.dfp, p, o, batch, 1e-4, 10.0)
    jax.block_until_ready(loss)
    out["train_step_s"] = (time.time() - t0) / 5
    save_json("overhead", out)
    return out


if __name__ == "__main__":
    o = run()
    print({k: (round(v, 4) if isinstance(v, float) else v)
           for k, v in o.items()})
