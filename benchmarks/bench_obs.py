"""Telemetry overhead + event-stream bench (the observability gate).

Three sections, gated by ``tools/check_bench.py`` against
``benchmarks/baselines/obs_overhead.json``:

* ``events`` — deterministic ``mrsch.trace/v1`` event counts for a fixed
  registry scenario/seed under the sequential engine, plus the
  sequential-vs-vector byte-parity bit.  Exact-gated (``__gates__`` pins
  rtol 0): any change to what the engines emit is a schema change and
  must come with a baseline update.
* ``overhead`` — the cost of *disabled* instrumentation.
  ``obs_off_overhead`` is the fraction of the traced run's wall time
  spent in NULL-tracer emit calls (per-call cost of the no-op methods x
  events emitted / untraced runtime); the ISSUE bar is <= 2 % and CI
  fails above it (``off_within_budget`` + the direction-aware
  ``*overhead*`` gate).  ``obs_on_overhead`` (BufferTracer recording
  everything) is reported and loosely gated — recording is allowed to
  cost something; disabled instrumentation is not.
"""
from __future__ import annotations

import time

from repro.core import FCFSPolicy
from repro.obs.trace import NULL, BufferTracer, trace_lines
from repro.sim.simulator import SimConfig, Simulator
from repro.sim.vector import VectorSimulator
from repro.workloads import build_jobs

from .common import mini_setup, save_json

#: obs-off instrumentation budget: NULL-tracer emits may cost at most
#: this fraction of the engine's untraced runtime (ISSUE: <= 2 %).
OFF_BUDGET = 0.02


def _drive(res, jobs, sim_cfg, tracer) -> Simulator:
    sim = Simulator(res, list(jobs), FCFSPolicy(), sim_cfg, tracer=tracer)
    while (ctx := sim.next_decision()) is not None:
        sim.post_action(int(sim.policy.select(ctx)))
    return sim


def _null_emit_cost(calls: int = 200_000, reps: int = 3) -> float:
    """Per-call seconds of a NULL-tracer emit (min over reps)."""
    best = float("inf")
    emit = NULL.decision
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(calls):
            emit(0, 0.0, 0, 0, 0, 1)
        best = min(best, time.perf_counter() - t0)
    return best / calls


def run(quick: bool = True, scenario: str = "S2", seed: int = 1):
    days, jobs_day = (0.5, 120) if quick else (2.0, 220)
    cfg, res = mini_setup(seed=0, duration_days=days, jobs_per_day=jobs_day)
    jobs = build_jobs(scenario, cfg, seed=seed)
    sim_cfg = SimConfig(window=10, backfill=True)
    reps = 3 if quick else 5

    # Traced reference run: the canonical event stream + counts.
    ref = BufferTracer()
    _drive(res, jobs, sim_cfg, ref)
    counts: dict = {}
    for e in ref.events:
        counts[e["ev"]] = counts.get(e["ev"], 0) + 1
    n_events = len(ref.events)

    # Sequential vs vector byte parity on the same two-env scenario.
    seq_tr = BufferTracer()
    for env in (0, 1):
        sim = Simulator(res, list(jobs), FCFSPolicy(), sim_cfg,
                        tracer=seq_tr, env=env)
        while (ctx := sim.next_decision()) is not None:
            sim.post_action(int(sim.policy.select(ctx)))
    vec_tr = BufferTracer()
    VectorSimulator.from_jobsets(res, [list(jobs), list(jobs)], FCFSPolicy(),
                                 sim_cfg, tracer=vec_tr).run()
    parity = trace_lines(seq_tr.events) == trace_lines(vec_tr.events)

    # Wall time with instrumentation disabled (NULL) vs recording.
    off_s = min(_time_run(res, jobs, sim_cfg, NULL) for _ in range(reps))
    on_s = min(_time_run(res, jobs, sim_cfg, BufferTracer())
               for _ in range(reps))
    null_emit_s = _null_emit_cost()
    off_overhead = null_emit_s * n_events / off_s
    on_overhead = max(0.0, (on_s - off_s) / off_s)

    out = {
        "schema": "mrsch.bench.obs/v1",
        "scenario": scenario,
        "seed": seed,
        "events": {
            "n_events": n_events,
            "parity_seq_vec": bool(parity),
            "counts": counts,
        },
        "overhead": {
            "n_events": n_events,
            "null_emit_ns": round(null_emit_s * 1e9, 2),
            "off_runtime_s": round(off_s, 4),
            "on_runtime_s": round(on_s, 4),
            "obs_off_overhead": round(off_overhead, 5),
            "obs_on_overhead": round(on_overhead, 5),
            "budget": OFF_BUDGET,
            "off_within_budget": bool(off_overhead <= OFF_BUDGET),
        },
    }
    out["path"] = save_json("obs_overhead", out)
    return out


def _time_run(res, jobs, sim_cfg, tracer) -> float:
    t0 = time.perf_counter()
    _drive(res, jobs, sim_cfg, tracer)
    return time.perf_counter() - t0


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
