"""Shared benchmark scaffolding (CPU-sized defaults; --full for bigger)."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Sequence

from repro.core import (AgentConfig, FCFSPolicy, GAConfig, GAOptimizer,
                        MRSchAgent, ScalarRLConfig, ScalarRLPolicy, evaluate,
                        train_agent)
# One scorer for the per-figure benches and the eval-matrix wins summary.
from repro.eval.matrix import kiviat_scores  # noqa: F401  (re-export)
from repro.workloads import ThetaConfig, build_curriculum, build_scenarios, generate_trace

RESULTS = os.environ.get("REPRO_BENCH_RESULTS", "results/bench")


def mini_setup(seed: int = 0, duration_days: float = 2.0,
               jobs_per_day: float = 260.0):
    cfg = ThetaConfig.mini(seed=seed, duration_days=duration_days,
                           jobs_per_day=jobs_per_day)
    return cfg, cfg.resources()


def agent_config(quick: bool = True) -> AgentConfig:
    """CPU-sized agent: same architecture family as the paper's (§IV-C),
    scaled to the mini cluster encoding."""
    return AgentConfig(
        state_hidden=(1024, 256) if quick else (4000, 1000),
        state_out=128 if quick else 512,
        module_hidden=64 if quick else 128,
        batch_size=64, grad_steps_per_episode=72,
        eps_decay=0.75, seed=0)


def train_mrsch(resources, jobsets, quick: bool = True,
                state_module: str = "mlp") -> MRSchAgent:
    from dataclasses import replace
    cfg = replace(agent_config(quick), state_module=state_module)
    agent = MRSchAgent(resources, cfg)
    train_agent(agent, resources, jobsets)
    return agent


def train_scalar_rl(resources, jobsets) -> ScalarRLPolicy:
    pol = ScalarRLPolicy(resources, ScalarRLConfig(hidden=(512, 128)))
    pol.training = True
    from repro.sim import run_trace
    for js in jobsets:
        run_trace(resources, js, pol)
        pol.end_episode()
    pol.training = False
    return pol


def metric_row(name: str, result) -> Dict[str, float]:
    row = result.metrics.as_row()
    return {"method": name, **{k: round(v, 4) for k, v in row.items()}}




def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name + ".json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def us(self, calls: int = 1) -> float:
        return (time.time() - self.t0) * 1e6 / max(calls, 1)
