"""Queue-encoder A/B: MLP window encoding vs queue-as-tokens attention.

Trains one agent per state module (same seeds, same curriculum) on the
huge-queue registry scenarios — the regime where the classic W-window
encoding is blind to nearly all of the backlog — then evaluates both on
held-out huge-queue traces.  Emits per-(module, scenario) metric rows,
the training-loss trajectory (the "attention trains end-to-end" gate),
and the attention/MLP wait ratio per scenario.

CLI:
    python -m benchmarks.bench_queue_encoder --smoke       # CI sizing
    python -m benchmarks.bench_queue_encoder               # quick local
    python -m benchmarks.bench_queue_encoder --smoke --update-baseline
        # refresh the committed benchmarks/baselines/queue_encoder_ab.json
        # (the curated contract the nightly check_bench gate compares to)
"""
from __future__ import annotations

import argparse
import json
import os
from dataclasses import replace

from repro.core import MRSchAgent, evaluate, train_agent
from repro.workloads import build_jobs

from .common import agent_config, metric_row, mini_setup, save_json

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
SCHEMA = "mrsch.bench.queue_encoder/v1"
EVAL_SCENARIOS = ("huge-queue-flood", "huge-queue-sustained")


def _agent(resources, module: str, quick: bool, queue_cap: int,
           seed: int) -> MRSchAgent:
    cfg = replace(agent_config(quick),
                  state_module=module, seed=seed, queue_cap=queue_cap,
                  attn_dim=32 if quick else 64,
                  attn_heads=2 if quick else 4,
                  attn_layers=1 if quick else 2)
    return MRSchAgent(resources, cfg)


def run(quick: bool = True, seed: int = 0, smoke: bool = False,
        baseline_path: str | None = None):
    if smoke:
        cfg, res = mini_setup(seed=seed, duration_days=0.5,
                              jobs_per_day=160.0)
        queue_cap = 48
    else:
        cfg, res = mini_setup(seed=seed, duration_days=1.0,
                              jobs_per_day=260.0)
        queue_cap = 64 if quick else 256
    train_sets = [build_jobs("huge-queue-flood", cfg, seed=seed + i)
                  for i in (1, 2, 3)]
    eval_traces = {name: build_jobs(name, cfg, seed=seed + 7)
                   for name in EVAL_SCENARIOS}

    rows, loss = [], {}
    waits: dict = {}
    for module in ("mlp", "attention"):
        agent = _agent(res, module, quick, queue_cap, seed)
        log = train_agent(agent, res, train_sets)
        losses = [float(x) for x in log.episode_losses]
        loss[module] = {
            "first": round(losses[0], 4) if losses else None,
            "last": round(losses[-1], 4) if losses else None,
            "n_episodes": len(losses),
            "decreased": bool(losses and losses[-1] < losses[0]),
        }
        for name, jobs in eval_traces.items():
            r = evaluate(agent, res, jobs, window=agent.config.window)
            row = metric_row(module.upper(), r)
            row["scenario"] = name
            rows.append(row)
            waits.setdefault(name, {})[module] = row["avg_wait"]

    out = {
        "bench": "queue_encoder_ab",
        "schema": SCHEMA,
        "smoke": smoke,
        "quick": quick,
        "queue_cap": queue_cap,
        "window": 10,
        "rows": rows,
        "loss": loss,
        "wait_ratio_attention_vs_mlp": {
            name: round(w["attention"] / max(w["mlp"], 1e-9), 4)
            for name, w in waits.items()},
    }
    save_json("queue_encoder_ab", out)
    if baseline_path:
        # Curated contract: schema + both modules' loss-decreased flags +
        # the deterministic metric columns of every row (direction-aware
        # in check_bench: wait/slowdown may only rise rtol above the
        # baseline, util_* may only drop rtol below it).
        contract = {
            "bench": out["bench"],
            "schema": out["schema"],
            "smoke": out["smoke"],
            "queue_cap": out["queue_cap"],
            "loss": {m: {"decreased": loss[m]["decreased"]} for m in loss},
            "rows": [{k: row[k] for k in
                      ("method", "scenario", "avg_wait",
                       "avg_bounded_slowdown", "util_node", "n_jobs")}
                     for row in rows],
        }
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        with open(baseline_path, "w") as f:
            json.dump(contract, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing (shortest traces, smallest queue cap)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--update-baseline", action="store_true",
                    help="also refresh the committed "
                         "benchmarks/baselines/queue_encoder_ab.json")
    args = ap.parse_args()
    o = run(quick=not args.full, seed=args.seed, smoke=args.smoke,
            baseline_path=os.path.join(BASELINE_DIR, "queue_encoder_ab.json")
            if args.update_baseline else None)
    for row in o["rows"]:
        print(f"{row['method']:>9} {row['scenario']:<22} "
              f"wait={row['avg_wait']:.0f}s "
              f"bslow={row['avg_bounded_slowdown']:.2f} "
              f"trunc={row['truncated_jobs']:.0f}")
    print("loss:", o["loss"])
    print("wait ratio (attention/mlp):", o["wait_ratio_attention_vs_mlp"])
