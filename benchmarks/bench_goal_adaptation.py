"""Figs. 8-9: dynamic goal-vector adaptation.

Evaluates a trained MRSch agent on S1-S5 and reports the distribution of
r_BB (Eq. 1's burst-buffer weight): it should (a) vary over time rather
than sit at the ScalarRL's fixed 0.5, and (b) shift upward from S1 to S5
as BB contention intensifies."""
from __future__ import annotations

import numpy as np

from repro.core import evaluate
from repro.workloads import build_curriculum, build_scenarios

from .common import mini_setup, save_json, train_mrsch


def run(quick: bool = True, seed: int = 0):
    cfg, res = mini_setup(seed=seed)
    train_cfg, _ = mini_setup(seed=seed + 1, duration_days=3.0)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=3, n_real=1, n_synth=2,
                           jobs_per_set=220, seed=seed)
    agent = train_mrsch(res, cur.ordered("sampled_real_synthetic"),
                        quick=quick)

    scen = build_scenarios(cfg, names=("S1", "S2", "S3", "S4", "S5"),
                           seed=seed + 7)
    out = {}
    for name, jobs in scen.items():
        agent.goal_log.clear()
        evaluate(agent, res, jobs)
        r_bb = np.array([g[1] for g in agent.goal_log])
        out[name] = {
            "min": float(r_bb.min()), "q1": float(np.percentile(r_bb, 25)),
            "mean": float(r_bb.mean()),
            "q3": float(np.percentile(r_bb, 75)), "max": float(r_bb.max()),
            "std": float(r_bb.std()), "n": int(len(r_bb)),
            "trace_head": [round(float(x), 4) for x in r_bb[:50]],
        }
    save_json("goal_adaptation", out)
    return out


if __name__ == "__main__":
    o = run()
    for k, v in o.items():
        print(k, f"mean r_BB={v['mean']:.3f} (q1={v['q1']:.3f}, "
                 f"q3={v['q3']:.3f}, std={v['std']:.3f})")
