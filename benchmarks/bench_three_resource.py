"""Fig. 10 / §V-E: three-resource case study (CPU + burst buffer + power).

S6-S10 add a power profile (100-215 W/node against a scaled 500 kW-class
budget); MRSch extends by widening R — no code changes, just resources."""
from __future__ import annotations

from repro.core import FCFSPolicy, GAConfig, GAOptimizer, evaluate
from repro.workloads import build_curriculum, build_scenarios

from .common import (kiviat_scores, metric_row, mini_setup, save_json,
                     train_mrsch, train_scalar_rl)


def run(quick: bool = True, scenarios=("S6", "S8", "S10"), seed: int = 0):
    cfg, _ = mini_setup(seed=seed)
    res = cfg.resources(power_budget_kw=cfg.default_power_budget_kw())

    train_cfg, _ = mini_setup(seed=seed + 1, duration_days=3.0)
    train_trace = build_scenarios(train_cfg, names=("S7",), power=True,
                                  seed=seed)["S7"]
    cur = build_curriculum(train_cfg, train_trace, n_sampled=3, n_real=1,
                           n_synth=2, jobs_per_set=240, seed=seed)
    sets = cur.ordered("sampled_real_synthetic")
    agent = train_mrsch(res, sets, quick=quick)
    scalar = train_scalar_rl(res, sets)

    eval_sets = build_scenarios(cfg, names=scenarios, seed=seed + 7)
    out = {}
    for name in scenarios:
        jobs = eval_sets[name]
        rows = []
        for label, policy in [
            ("FCFS", FCFSPolicy()),
            ("Optimization(GA)", GAOptimizer(GAConfig(population=10,
                                                      generations=6))),
            ("ScalarRL", scalar),
            ("MRSch", agent),
        ]:
            rows.append(metric_row(label, evaluate(policy, res, jobs)))
        out[name] = {"rows": rows, "kiviat": kiviat_scores(rows)}
    save_json("three_resource", out)
    return out


if __name__ == "__main__":
    o = run()
    for k, v in o.items():
        print(k, v["kiviat"])
