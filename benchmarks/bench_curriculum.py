"""Fig. 4 + training throughput: curriculum ablation and vectorized DFP.

Part 1 (Fig. 4): training-order ablation (sampled->real->synthetic vs
others) — compares DFP loss trajectories for three jobset orderings; the
paper's ordering should converge fastest / lowest.

Part 2: training-throughput comparison on the mini config — the same
(scenario x seed) jobset grid trained once sequentially (one trace at a
time through ``run_trace``) and once through the batched rollout engine
at N=8 lockstep environments.  Target: >= 3x decisions/sec vectorized.
"""
from __future__ import annotations

import numpy as np

from repro.core import (AgentConfig, MRSchAgent, TrainConfig, train_agent)
from repro.workloads import (ThetaConfig, build_curriculum, build_scenarios,
                             build_sweep)

from .common import mini_setup, save_json, train_mrsch


ORDERINGS = [
    "sampled_real_synthetic",      # the paper's curriculum
    "synthetic_real_sampled",      # hardest-first
    "real_sampled_synthetic",
]

# Dispatch-dominated mini network for the throughput comparison: small
# enough that a CPU batch-8 forward costs little more than a batch-1
# forward, so the lockstep engine's amortized dispatch shows through.
THROUGHPUT_AGENT = AgentConfig(
    state_hidden=(256, 64), state_out=32, module_hidden=32, stream_hidden=64,
    batch_size=32, grad_steps_per_episode=8, eps_decay=0.75, seed=0)


def vector_training(quick: bool = True, seed: int = 0, n_envs: int = 8,
                    backend: str | None = None):
    """Sequential vs N-env lockstep training on an identical jobset grid.

    ``backend`` routes BOTH arms through the chosen NN backend
    ("xla" default; "pallas" = fused-MLP kernels), so the reported
    vector-vs-sequential speedup isolates the rollout engine while the
    backend choice shows up in absolute decisions/sec.
    """
    from dataclasses import replace as dc_replace

    agent_cfg = THROUGHPUT_AGENT if backend is None else \
        dc_replace(THROUGHPUT_AGENT, backend=backend)
    cfg = ThetaConfig.mini(seed=seed, duration_days=1.3 if quick else 3.0,
                           jobs_per_day=140)
    res = cfg.resources()
    # Balanced grid: 16 jobsets = 2 per lane at N=8, so the decision batch
    # stays wide until the very end of training.
    tasks = build_sweep(cfg, scenarios=("S1", "S2", "S3", "S4"),
                        seeds=(1, 2, 3, 4))
    jobsets = [jobs for _, jobs in tasks]
    labels = [f"{t.scenario}/seed{t.seed}" for t, _ in tasks]

    # Warm the jit cache for BOTH timed arms: the vectorized run compiles
    # the pow-of-2 batched forwards + the scanned train step, the short
    # sequential run compiles the single-decision forward (_values).
    warm = MRSchAgent(res, agent_cfg)
    train_agent(warm, res, jobsets[:n_envs],
                config=TrainConfig(n_envs=n_envs))
    warm_seq = MRSchAgent(res, agent_cfg)
    train_agent(warm_seq, res, jobsets[:1])

    a_seq = MRSchAgent(res, agent_cfg)
    seq = train_agent(a_seq, res, jobsets)
    a_vec = MRSchAgent(res, agent_cfg)
    vec = train_agent(a_vec, res, jobsets,
                      config=TrainConfig(n_envs=n_envs))
    out = {
        "n_envs": n_envs,
        "backend": backend or "xla",
        "n_jobsets": len(jobsets),
        "jobsets": labels,
        "sequential": {
            "decisions": seq.decisions,
            "wall_seconds": round(seq.wall_seconds, 3),
            "decisions_per_sec": round(seq.decisions_per_sec, 1),
            "episodes_trained": len(seq.episode_losses),
        },
        "vectorized": {
            "decisions": vec.decisions,
            "wall_seconds": round(vec.wall_seconds, 3),
            "decisions_per_sec": round(vec.decisions_per_sec, 1),
            "episodes_trained": len(vec.episode_losses),
            "rounds": vec.rounds,
        },
        "speedup": round(vec.decisions_per_sec /
                         max(seq.decisions_per_sec, 1e-9), 2),
    }
    return out


def device_rollout(quick: bool = True, seed: int = 0, n_envs: int = 512,
                   backend: str | None = None):
    """Device-resident vs host-lockstep rollout throughput at N envs.

    Both arms collect training trajectories from the SAME jobset grid
    with the SAME agent: the host arm through ``VectorSimulator`` in
    training mode (slot-aware ``select_batch`` — per-decision row
    encoding, exploration draws, and episode recording on the host, a
    Python round trip every lockstep round), the device arm through
    ``DeviceSimulator`` in collection mode (in-graph epsilon-greedy +
    packed decision-row capture) — the whole rollout is one jitted
    program, so the only host work is ingesting the packed trace.

    The workload is a small contended cluster (16 nodes / 8 BB units,
    ~43 jobs per trace): short traces keep the device program
    dispatch-bound rather than size-bound, which is where widening N is
    nearly free on device while the host arm pays per decision — the
    regime a curriculum training loop (many short episodes, wide batch)
    actually runs in.  Each arm re-runs its full per-epoch cost: the
    host engine rebuilds its simulators every pass, the device engine
    re-rolls from the packed arrays.  Compile time is reported
    separately; the throughput rows time the warm program (best of a few
    repeats, since the wall clock is scheduler-noisy), which is what a
    training loop amortizes to.
    """
    from dataclasses import replace as dc_replace

    from repro.sim import DeviceSimulator, SimConfig, VectorSimulator

    agent_cfg = THROUGHPUT_AGENT if backend is None else \
        dc_replace(THROUGHPUT_AGENT, backend=backend)
    cfg = ThetaConfig(n_nodes=16, bb_units=8, duration_days=0.15,
                      jobs_per_day=600, seed=seed,
                      runtime_median_s=30 * 60.0, runtime_max_s=6 * 3600.0)
    res = cfg.resources()
    scenarios = ("S1", "S2", "S3", "S4")
    seeds = tuple(range(1, 1 + max(1, n_envs // len(scenarios))))
    tasks = build_sweep(cfg, scenarios=scenarios, seeds=seeds)[:n_envs]
    jobsets = [jobs for _, jobs in tasks]
    agent = MRSchAgent(res, agent_cfg)

    def vec_arm():
        agent.training = True
        agent.begin_vector_episodes(len(jobsets))
        try:
            vec = VectorSimulator.from_jobsets(
                res, jobsets, agent, SimConfig.for_engine("vector"))
            vec.run()
        finally:
            agent.training = False
        return vec.stats.decisions

    def dev_arm(dev):
        ro = dev.rollout(eps=0.1, seed=seed, collect=True)
        return ro.stats.decisions

    import time as _time

    vec_reps, dev_reps = (2, 3) if quick else (3, 5)
    vec_arm()                                     # warm the batched forward
    vec_wall = float("inf")
    for _ in range(vec_reps):
        t0 = _time.perf_counter()
        vec_decisions = vec_arm()
        vec_wall = min(vec_wall, _time.perf_counter() - t0)

    dev = DeviceSimulator(res, jobsets, agent, SimConfig.for_engine("device"))
    t0 = _time.perf_counter()
    dev_arm(dev)                                  # compile + first run
    compile_wall = _time.perf_counter() - t0
    dev_wall = float("inf")
    for _ in range(dev_reps):
        t0 = _time.perf_counter()
        dev_decisions = dev_arm(dev)
        dev_wall = min(dev_wall, _time.perf_counter() - t0)

    vec_per_sec = vec_decisions / max(vec_wall, 1e-9)
    dev_per_sec = dev_decisions / max(dev_wall, 1e-9)
    out = {
        "n_envs": n_envs,
        "backend": backend or "xla",
        "n_jobs": sum(len(js) for js in jobsets),
        "vector": {
            "decisions": vec_decisions,
            "wall_seconds": round(vec_wall, 4),
            "decisions_per_sec": round(vec_per_sec, 1),
        },
        "device": {
            "decisions": dev_decisions,
            "wall_seconds": round(dev_wall, 4),
            "compile_seconds": round(compile_wall, 3),
            "decisions_per_sec": round(dev_per_sec, 1),
        },
        "speedup": round(dev_per_sec / max(vec_per_sec, 1e-9), 2),
    }
    save_json("device_rollout", out)
    return out


def run(quick: bool = True, seed: int = 0, backend: str | None = None):
    train_cfg, res = mini_setup(seed=seed + 1, duration_days=3.0)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=3, n_real=2, n_synth=3,
                           jobs_per_set=220, seed=seed)
    out = {}
    for order in ORDERINGS:
        agent = train_mrsch(res, cur.ordered(order), quick=quick)
        losses = agent.losses
        out[order] = {
            "losses": [round(float(l), 5) for l in losses],
            "final_loss": float(np.mean(losses[-2:])) if losses else None,
        }
    out["vector_training"] = vector_training(quick=quick, seed=seed,
                                             backend=backend)
    save_json("curriculum", out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default=None, choices=("xla", "pallas"),
                    help="NN backend for the training-throughput arms")
    ap.add_argument("--throughput-only", action="store_true",
                    help="skip the Fig. 4 ordering ablation")
    ap.add_argument("--device-rollout", action="store_true",
                    help="only the device-vs-vector rollout throughput cell")
    args = ap.parse_args()
    if args.device_rollout:
        dr = device_rollout(quick=not args.full, backend=args.backend)
        print(f"device rollout [N={dr['n_envs']}, {dr['backend']}]: "
              f"vec={dr['vector']['decisions_per_sec']}/s "
              f"dev={dr['device']['decisions_per_sec']}/s "
              f"(compile {dr['device']['compile_seconds']}s) "
              f"speedup={dr['speedup']}x")
        raise SystemExit(0)
    if args.throughput_only:
        o = {"vector_training": vector_training(quick=not args.full,
                                                backend=args.backend)}
    else:
        o = run(quick=not args.full, backend=args.backend)
        for k, v in o.items():
            if k == "vector_training":
                continue
            print(k, "final:", v["final_loss"])
    vt = o["vector_training"]
    print(f"vector training [N={vt['n_envs']}, {vt['backend']}]: "
          f"seq={vt['sequential']['decisions_per_sec']}/s "
          f"vec={vt['vectorized']['decisions_per_sec']}/s "
          f"speedup={vt['speedup']}x")
