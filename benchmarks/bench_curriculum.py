"""Fig. 4: training-order ablation (sampled->real->synthetic vs others).

Compares DFP loss trajectories for three jobset orderings; the paper's
ordering should converge fastest / lowest."""
from __future__ import annotations

import numpy as np

from repro.workloads import build_curriculum, build_scenarios

from .common import mini_setup, save_json, train_mrsch


ORDERINGS = [
    "sampled_real_synthetic",      # the paper's curriculum
    "synthetic_real_sampled",      # hardest-first
    "real_sampled_synthetic",
]


def run(quick: bool = True, seed: int = 0):
    train_cfg, res = mini_setup(seed=seed + 1, duration_days=3.0)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=3, n_real=2, n_synth=3,
                           jobs_per_set=220, seed=seed)
    out = {}
    for order in ORDERINGS:
        agent = train_mrsch(res, cur.ordered(order), quick=quick)
        losses = agent.losses
        out[order] = {
            "losses": [round(float(l), 5) for l in losses],
            "final_loss": float(np.mean(losses[-2:])) if losses else None,
        }
    save_json("curriculum", out)
    return out


if __name__ == "__main__":
    o = run()
    for k, v in o.items():
        print(k, "final:", v["final_loss"])
