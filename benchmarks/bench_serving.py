"""Closed-loop load generator + latency harness for the decision service.

    python -m benchmarks.bench_serving --smoke          # CI cell grid
    python -m benchmarks.bench_serving --full --clients 1,4,16

Each cell fixes (backend, max_wait, concurrent clients) and runs a
closed loop: every client thread submits decision requests back-to-back
against a pool of frozen mid-trace scheduling contexts, so offered load
equals the service's achievable throughput at that concurrency.
Reported per cell: decisions/sec and p50/p95/p99 end-to-end request
latency, plus the observed micro-batch and shape-bucket behaviour
behind them.  This is the repo's first *latency*-oriented hot path —
the sweep/matrix benches measure offline replay throughput; this one
measures what a live scheduler client would see.

The ``max_wait`` dimension exposes the batching-policy tradeoff:
``0`` (greedy dispatch) minimizes idle-service latency but under load
forms ragged batches out of thread-wakeup ping-pong; a sub-millisecond
wait lets each batch fill to the offered concurrency, which on CPU
raises 8-client throughput to >=3x the single-client rate AND tightens
p99 (orderly batches instead of wakeup jitter).  See docs/serving.md.

Output schema ``mrsch.bench.serving/v1`` (stable: CI gates
``results/bench/serving.json`` against ``benchmarks/baselines/``):
cells appear in the deterministic (backend, max_wait, clients) grid
order with flat ``*_ms`` / ``*_per_sec`` keys so ``tools/check_bench.py``
applies its direction-aware tolerance to each.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import AgentConfig, FCFSPolicy, MRSchAgent
from repro.serve import DecisionService, ServeConfig
from repro.sim import Simulator
from repro.workloads import ThetaConfig, build_jobs

from .common import save_json

SCHEMA = "mrsch.bench.serving/v1"


def harvest_contexts(resources, jobs, n: int, depth: int = 6) -> List:
    """Freeze ``n`` pending decisions, each a few decisions into its own
    copy of the trace (FCFS-advanced).  A context owns references to its
    simulator's cluster/queue/jobs, so it stays valid after the (never
    advanced again) simulator is dropped."""
    pool = []
    for i in range(n):
        sim = Simulator(resources, jobs, FCFSPolicy())
        ctx = sim.next_decision()
        for _ in range(depth + i % 5):        # stagger the depths
            if ctx is None:
                break
            sim.post_action(sim.policy.select(ctx))
            ctx = sim.next_decision()
        if ctx is not None:
            pool.append(ctx)
    if not pool:
        raise RuntimeError("trace too small to harvest serving contexts")
    return pool


def _percentiles(lat_s: Sequence[float]) -> Dict[str, float]:
    ms = np.asarray(lat_s) * 1e3
    return {
        "mean_ms": round(float(ms.mean()), 3),
        "p50_ms": round(float(np.percentile(ms, 50)), 3),
        "p95_ms": round(float(np.percentile(ms, 95)), 3),
        "p99_ms": round(float(np.percentile(ms, 99)), 3),
    }


def run_cell(service: DecisionService, ctxs: Sequence, clients: int,
             requests_per_client: int, warmup: int = 8) -> Dict:
    """One closed-loop cell: ``clients`` threads, back-to-back requests."""
    for i in range(warmup):
        service.decide(ctxs[i % len(ctxs)])
    stats0 = service.stats()
    latencies: List[List[float]] = [[] for _ in range(clients)]
    barrier = threading.Barrier(clients + 1)

    def client(k: int) -> None:
        lat = latencies[k]
        barrier.wait()
        for r in range(requests_per_client):
            ctx = ctxs[(k * 7919 + r) % len(ctxs)]
            t0 = time.perf_counter()
            service.decide(ctx)
            lat.append(time.perf_counter() - t0)

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats1 = service.stats()
    n = clients * requests_per_client
    batches = stats1["batches"] - stats0["batches"]
    # Cells share a service within one (backend, max_wait) group, so
    # batch/bucket figures are deltas over this cell's closed loop only.
    hist0, hist1 = stats0["batch_hist"], stats1["batch_hist"]
    cell_max = max((k for k in hist1 if hist1[k] > hist0.get(k, 0)),
                   default=0)
    retraces = (stats1["buckets"]["compiles"]
                - stats0["buckets"]["compiles"])
    flat = [x for lat in latencies for x in lat]
    return {
        "clients": clients,
        "requests": n,
        "wall_seconds": round(wall, 4),
        "decisions_per_sec": round(n / max(wall, 1e-9), 2),
        **_percentiles(flat),
        "mean_batch": round(n / max(batches, 1), 3),
        "max_batch_seen": cell_max,
        "bucket_retraces": retraces,     # warmup pre-traced: 0 expected
    }


def run(quick: bool = True, clients: Sequence[int] = (1, 2, 8),
        backends: Sequence[str] = ("xla",), requests: Optional[int] = None,
        max_batch: int = 16, waits_ms: Sequence[float] = (0.0, 0.5),
        pool: int = 24) -> Dict:
    """The (backend x max_wait x clients) cell grid on one scenario."""
    cfg = ThetaConfig.mini(seed=0, duration_days=0.5, jobs_per_day=160)
    resources = cfg.resources()
    jobs = build_jobs("S1", cfg, seed=1)
    total = requests or (320 if quick else 2000)
    agent_cfg = AgentConfig(state_hidden=(256, 64) if quick else (1024, 256),
                            state_out=32 if quick else 128,
                            module_hidden=16 if quick else 64, seed=0)
    ctxs = harvest_contexts(resources, jobs, pool)
    cells: List[Dict] = []
    for backend in backends:
        agent = MRSchAgent(resources, agent_cfg)
        if backend != "xla":
            agent.set_backend(backend)
        for wait_ms in waits_ms:
            svc_cfg = ServeConfig(max_batch=max_batch,
                                  max_wait_s=wait_ms / 1e3)
            with DecisionService(agent, svc_cfg) as svc:
                for c in clients:
                    cell = run_cell(svc, ctxs, c, max(total // c, 1))
                    cells.append({"backend": backend,
                                  "max_wait_ms": wait_ms, **cell})
    return {
        "schema": SCHEMA,
        "config": {
            "scenario": "S1", "pool_contexts": len(ctxs),
            "max_batch": max_batch, "clients": list(clients),
            "waits_ms": list(waits_ms), "backends": list(backends),
            "state_hidden": list(agent_cfg.state_hidden),
            "quick": quick,
        },
        "cells": cells,
        "summary": _summary(cells),
    }


def _summary(cells: Sequence[Dict]) -> Dict:
    """Throughput scaling per (backend, wait): widest vs single client.

    ``batched_speedup_<backend>`` is the acceptance number — measured at
    the largest configured wait (the load-serving policy); greedy
    dispatch reports separately as ``greedy_speedup_<backend>``.
    """
    out: Dict[str, object] = {}
    for backend in dict.fromkeys(c["backend"] for c in cells):
        for wait in sorted({c["max_wait_ms"] for c in cells
                            if c["backend"] == backend}):
            grp = [c for c in cells
                   if c["backend"] == backend and c["max_wait_ms"] == wait]
            single = next((c for c in grp if c["clients"] == 1), None)
            widest = max(grp, key=lambda c: c["clients"])
            if single is None or widest is single:
                continue
            speedup = round(widest["decisions_per_sec"]
                            / max(single["decisions_per_sec"], 1e-9), 3)
            key = (f"greedy_speedup_{backend}" if wait == 0
                   else f"batched_speedup_{backend}")
            out[key] = speedup
            out[f"clients_{backend}"] = widest["clients"]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="closed-loop decision-service load test")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing (small agent, short closed loop; "
                         "this is also the default)")
    ap.add_argument("--full", action="store_true",
                    help="big agent + long closed loop")
    ap.add_argument("--clients", default=None,
                    help="comma-separated concurrency cells (default 1,2,8)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests per cell (split across clients)")
    ap.add_argument("--backend", default="xla",
                    help="comma-separated backends (xla[,pallas])")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--waits-ms", default="0,0.5",
                    help="comma-separated micro-batcher max_wait cells (ms)")
    ap.add_argument("--out", default="serving",
                    help="results/bench/<out>.json")
    args = ap.parse_args(argv)
    clients = (tuple(int(x) for x in args.clients.split(","))
               if args.clients else (1, 2, 8))
    out = run(quick=not args.full,
              clients=clients, backends=tuple(args.backend.split(",")),
              requests=args.requests, max_batch=args.max_batch,
              waits_ms=tuple(float(x) for x in args.waits_ms.split(",")))
    path = save_json(args.out, out)
    for cell in out["cells"]:
        print(f"{cell['backend']:7s} wait={cell['max_wait_ms']:<4g} "
              f"clients={cell['clients']:<3d} "
              f"{cell['decisions_per_sec']:>9.1f} dec/s  "
              f"p50={cell['p50_ms']:.2f}ms p95={cell['p95_ms']:.2f}ms "
              f"p99={cell['p99_ms']:.2f}ms  mean_batch={cell['mean_batch']}")
    for k, v in out["summary"].items():
        print(f"{k} = {v}")
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
