"""Figs. 5-7: scheduling performance on S1-S5, four methods.

Trains MRSch (curriculum) and ScalarRL on sampled/real/synthetic jobsets,
then evaluates FCFS / GA / ScalarRL / MRSch on each scenario's held-out
trace.  Emits per-scenario metric rows (Figs. 5-6) and normalized overall
scores (Fig. 7 Kiviat areas).

Standalone entry point (also the CI benchmark smoke)::

    python -m benchmarks.bench_scheduling --smoke --vector 4

times the scenario sweep sequentially AND through the batched
``VectorSimulator`` rollout engine and records the decision-throughput
speedup in the result JSON.

The registry-wide policy x scenario grid (the nightly CI signal)::

    python -m benchmarks.bench_scheduling --matrix --smoke

runs >=3 registry scenarios (incl. one §V-D drift workload) x >=3
policies on the vector engine and writes the schema-stable
``results/bench/matrix.json`` (+ ``.csv``).  ``--drift`` runs the §V-D
adaptation experiment: a drifting trace split into phases, each policy
walked through them via the lockstep refill hook, per-phase metrics in
``results/bench/drift.json``.  ``--faults`` runs the job-lifecycle grid
(workflow DAGs, requeue-on-failure, scheduled node drains) and writes the
CI-gated ``results/bench/faults.json``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.core import (AgentConfig, FCFSPolicy, GAConfig, GAOptimizer,
                        MRSchAgent, evaluate)
from repro.eval import (MatrixConfig, TournamentConfig, default_policies,
                        eval_factory, run_matrix, run_tournament, save_matrix,
                        save_tournament, zoo_policies)
from repro.obs.trace import BufferTracer, write_trace
from repro.workloads import (build_curriculum, build_jobs, build_scenarios,
                             build_sweep, get_scenario, run_phases, run_sweep,
                             segment_jobs)

from .common import (RESULTS, Timer, kiviat_scores, metric_row, mini_setup,
                     save_json, train_mrsch, train_scalar_rl)


def sweep_throughput(agent, res, cfg, scenarios, seeds, vector: int,
                     trials: int = 3):
    """Decision throughput of the same sweep, sequential vs vector=N.

    Per-env results must be identical between the two modes (the lockstep
    engine only changes when inference happens); divergence raises — and
    thereby fails the CI smoke — so a speedup can never come from silently
    diverging rollouts.  Throughput is the median of ``trials`` runs per
    mode to damp scheduler/CPU noise.
    """
    tasks = build_sweep(cfg, scenarios=scenarios, seeds=seeds)
    warm = [(t, jobs[:40]) for t, jobs in tasks]
    run_sweep(res, warm, agent, vector=0)            # jit warm-up, both paths
    run_sweep(res, warm, agent, vector=vector)
    seq_runs = [run_sweep(res, tasks, agent, vector=0) for _ in range(trials)]
    vec_runs = [run_sweep(res, tasks, agent, vector=vector)
                for _ in range(trials)]
    seq = sorted(seq_runs, key=lambda r: r["decisions_per_sec"])[trials // 2]
    vec = sorted(vec_runs, key=lambda r: r["decisions_per_sec"])[trials // 2]
    equivalent = seq["tasks"] == vec["tasks"]
    if not equivalent:
        diverged = [a["scenario"] for a, b in zip(seq["tasks"], vec["tasks"])
                    if a != b]
        raise RuntimeError(
            f"vectorized rollouts diverged from sequential on {diverged}; "
            "a throughput comparison over different trajectories is invalid")
    return {
        "n_envs": vector,
        "sequential": seq,
        "vectorized": vec,
        "decision_throughput_speedup": round(
            vec["decisions_per_sec"] / max(seq["decisions_per_sec"], 1e-9), 3),
        "equivalent": equivalent,
    }


def run_smoke(vector: int = 4, trials: int = 3, seed: int = 0):
    """CI-sized sweep benchmark: mini cluster, short trace, untrained agent.

    Skips policy training — the batching speedup and the sequential/vector
    equivalence are properties of the rollout engine, not of the weights.
    """
    cfg, res = mini_setup(seed=seed, duration_days=0.75, jobs_per_day=160)
    agent = _matrix_agent(res, seed)       # same CI agent the matrix gates
    out = {
        "config": "mini(256 nodes, 80 bb units), 0.75 days, untrained agent",
        **sweep_throughput(agent, res, cfg, scenarios=("S1", "S2", "S3", "S4"),
                           seeds=(1, 2), vector=vector, trials=trials),
    }
    save_json("scheduling_sweep", out)
    return out


SMOKE_MATRIX = ("S2", "bursty-campaigns", "drift-bb-surge",
                "workflow-pipelines", "faulty-drain")
FULL_MATRIX = ("S1", "S2", "S3", "S4", "S5", "theta-base", "diurnal-heavy",
               "bursty-campaigns", "size-skew-small", "size-skew-large",
               "drift-bb-surge", "drift-arrival-ramp", "drift-node-shift",
               "workflow-pipelines", "workflow-ensembles", "faulty-jobs",
               "faulty-drain", "drift-failure-wave")

# The lifecycle grid (--faults): workflow DAGs + requeue/fault scenarios,
# gated in CI on the lifecycle metric columns (pipeline makespan may only
# rise, completed-work fraction may only drop, per the direction-aware
# check_bench patterns).
FAULTS_GRID = ("workflow-pipelines", "workflow-ensembles", "faulty-jobs",
               "faulty-drain")
FAULTS_CELL_KEYS = ("decisions", "n_unstarted", "avg_wait", "makespan",
                    "requeues", "n_failed", "failed_node_hours",
                    "completed_work_frac", "pipeline_makespan")


def _matrix_agent(res, seed: int = 0) -> MRSchAgent:
    return MRSchAgent(res, AgentConfig(
        state_hidden=(256, 64), state_out=32, module_hidden=16, seed=seed))


def run_matrix_bench(smoke: bool = True, vector: int = 4, seed: int = 0,
                     agent: MRSchAgent | None = None,
                     scenarios=None, seeds=None):
    """Policy x scenario grid on the vector engine -> matrix.json/.csv.

    Smoke sizing (the CI lane): 3 registry scenarios — one per family
    class, including a §V-D drift workload — x 4 policies, untrained
    agents (grid mechanics and schema don't depend on the weights).
    """
    days, jobs_day = (0.6, 120) if smoke else (2.0, 220)
    cfg, res = mini_setup(seed=seed, duration_days=days, jobs_per_day=jobs_day)
    policies = default_policies(res, agent=agent or _matrix_agent(res, seed))
    mcfg = MatrixConfig(
        scenarios=tuple(scenarios) if scenarios
        else (SMOKE_MATRIX if smoke else FULL_MATRIX),
        seeds=tuple(seeds) if seeds else ((1,) if smoke else (1, 2)),
        vector=vector)
    tracer = BufferTracer()
    matrix = run_matrix(policies, res, cfg, mcfg, tracer=tracer)
    json_path, csv_path = save_matrix(
        matrix, os.path.join(RESULTS, "matrix.json"))
    trace_path = str(write_trace(tracer.events,
                                 os.path.join(RESULTS, "matrix_trace.jsonl"),
                                 meta=tracer.meta))
    matrix["paths"] = {"json": json_path, "csv": csv_path,
                       "trace": trace_path}
    return matrix


# The standing tournament (--tournament): the full baseline zoo — the
# paper's four methods plus PRB-EWT, the CP window-packing dispatcher,
# the DRAS-style two-level agent, and the RL co-scheduler variant —
# round-robin over one scenario per registry family class.
TOURNAMENT_SMOKE = ("S2", "bursty-campaigns", "drift-bb-surge",
                    "workflow-pipelines")
TOURNAMENT_FULL = FULL_MATRIX


def run_tournament_bench(smoke: bool = True, vector: int = 4, seed: int = 0,
                         agent: MRSchAgent | None = None,
                         scenarios=None, seeds=None):
    """Baseline-zoo round-robin -> tournament.json + leaderboard.md.

    Smoke sizing: 8 policies x 4 scenarios (one per family class) x 1
    seed, untrained NN entrants (the standings mechanics and the
    per-policy gate aggregates don't depend on the weights — the
    paper-faithful standings load trained checkpoints via ``agent``).
    Deterministic for a fixed seed; ``tools/check_bench.py`` gates the
    ``per_policy`` section against the committed baseline.
    """
    days, jobs_day = (0.6, 120) if smoke else (2.0, 220)
    cfg, res = mini_setup(seed=seed, duration_days=days, jobs_per_day=jobs_day)
    policies = zoo_policies(res, agent=agent or _matrix_agent(res, seed),
                            seed=seed)
    tcfg = TournamentConfig(
        scenarios=tuple(scenarios) if scenarios
        else (TOURNAMENT_SMOKE if smoke else TOURNAMENT_FULL),
        seeds=tuple(seeds) if seeds else ((1,) if smoke else (1, 2)),
        vector=vector)
    tracer = BufferTracer()
    t = run_tournament(policies, res, cfg, tcfg, tracer=tracer)
    json_path, md_path = save_tournament(
        t, os.path.join(RESULTS, "tournament.json"))
    trace_path = str(write_trace(
        tracer.events, os.path.join(RESULTS, "tournament_trace.jsonl"),
        meta=tracer.meta))
    t["paths"] = {"json": json_path, "md": md_path, "trace": trace_path}
    return t


def summarize_tournament(t) -> str:
    s = t["summary"]
    imp = t["relative_improvement"]
    lines = [f"tournament[{t['schema']}]: {s['n_policies']} policies x "
             f"{len(t['config']['scenarios'])} scenarios x "
             f"{len(t['config']['seeds'])} seeds = {s['n_cells']} cells in "
             f"{s['wall_seconds']:.1f}s; leader={s['leader']}"]
    if imp["max"] is not None:
        lines.append(f"  {imp['reference']} wait improvement: "
                     f"max {imp['max']:+.1%} "
                     + " ".join(f"{p}={v:+.1%}"
                                for p, v in sorted(imp["vs"].items())))
    for e in t["leaderboard"]:
        lines.append(f"  #{e['rank']} {e['policy']}: "
                     f"overall={e['overall_score']:.4f} wins={e['wins']} "
                     f"wait={e['avg_wait']:.0f}s")
    for f in s["failures"]:
        lines.append(f"  FAILED {f['policy']}: {f['error']} "
                     f"({len(f['cells'])} cells)")
    lines.append(f"  -> {t.get('paths', {}).get('json', 'results/bench/tournament.json')}")
    return "\n".join(lines)


def summarize_matrix(matrix) -> str:
    s = matrix["summary"]
    cfgm = matrix["config"]
    n_drift = len({r["scenario"] for r in matrix["rows"] if r["drift"]})
    return (f"matrix[{matrix['schema']}]: {len(cfgm['scenarios'])} scenarios "
            f"({n_drift} drift) x {len(cfgm['policies'])} policies x "
            f"{len(cfgm['seeds'])} seeds = {s['n_cells']} cells in "
            f"{s['wall_seconds']:.1f}s; wins={s['wins']} "
            f"-> {matrix.get('paths', {}).get('json', 'results/bench/matrix.json')}")


def run_faults_bench(smoke: bool = True, vector: int = 4, seed: int = 0):
    """Lifecycle smoke: workflow-DAG + fault-injection grid -> faults.json.

    FCFS and the CI agent over the ``FAULTS_GRID`` scenarios on the
    vector engine; cells are keyed (policy -> scenario -> metrics) rather
    than row-ordered so the committed baseline stays insensitive to grid
    growth.  The rows are deterministic for a seed: the gate catches a
    lifecycle regression (lost requeues, broken dependency staging, work
    accounting drift), not runner noise.
    """
    days, jobs_day = (0.6, 120) if smoke else (2.0, 220)
    cfg, res = mini_setup(seed=seed, duration_days=days, jobs_per_day=jobs_day)
    policies = {"FCFS": FCFSPolicy,
                "MRSch": lambda: _matrix_agent(res, seed)}
    mcfg = MatrixConfig(scenarios=FAULTS_GRID, seeds=(1,), vector=vector)
    matrix = run_matrix(policies, res, cfg, mcfg)
    cells: dict = {}
    for r in matrix["rows"]:
        cells.setdefault(r["policy"], {})[r["scenario"]] = {
            k: r[k] for k in FAULTS_CELL_KEYS}
    any_requeues = sum(c["requeues"] for by_s in cells.values()
                       for s, c in by_s.items() if s.startswith("faulty"))
    any_pipelines = all(c["pipeline_makespan"] > 0
                        for by_s in cells.values()
                        for s, c in by_s.items() if s.startswith("workflow"))
    out = {
        "schema": "mrsch.bench.faults/v1",
        "grid": list(FAULTS_GRID),
        "config": matrix["config"],
        "cells": cells,
        "summary": {
            "n_cells": len(matrix["rows"]),
            "faulty_scenarios_requeue": any_requeues > 0,
            "workflow_scenarios_pipeline": any_pipelines,
            "failures": matrix["summary"]["failures"],
            "wall_seconds": matrix["summary"]["wall_seconds"],
        },
    }
    save_json("faults", out)
    return out


def summarize_faults(out) -> str:
    lines = [f"faults[{out['schema']}]: {out['summary']['n_cells']} cells, "
             f"requeue={out['summary']['faulty_scenarios_requeue']} "
             f"pipeline={out['summary']['workflow_scenarios_pipeline']} in "
             f"{out['summary']['wall_seconds']:.1f}s"]
    for policy, by_s in out["cells"].items():
        for s, c in by_s.items():
            lines.append(
                f"  {policy}/{s}: requeues={c['requeues']} "
                f"failed={c['n_failed']} frac={c['completed_work_frac']:.4f} "
                f"pipeline_makespan={c['pipeline_makespan']:.0f}s")
    return "\n".join(lines)


def run_drift_bench(smoke: bool = True, scenario: str = "drift-bb-surge",
                    n_phases: int = 2, seed: int = 0):
    """§V-D adaptation: per-phase metrics across a mid-trace shift.

    The drifted trace is cut at the schedule boundaries into phases; each
    policy walks them via the lockstep ``refill`` hook so the per-phase
    rows show how (or whether) it re-prioritizes after the shift.
    """
    days = 1.0 if smoke else 4.0
    cfg, res = mini_setup(seed=seed, duration_days=days, jobs_per_day=160)
    jobs = build_jobs(scenario, cfg, seed=1)
    phases = segment_jobs(jobs, n_phases)
    policies = default_policies(res, agent=_matrix_agent(res, seed))
    out = {"scenario": scenario,
           "description": get_scenario(scenario).description,
           "n_phases": n_phases, "policies": {}}
    for name, factory in policies.items():
        pol = factory()
        if hasattr(pol, "select_batch"):
            was = getattr(pol, "training", None)
            if was:
                pol.training = False
            results = run_phases(pol, res, [phases])
            if was:
                pol.training = was
        else:                      # GA-style: own frozen instance per lane
            results = run_phases(None, res, [phases],
                                 policy_factory=eval_factory(factory))
        out["policies"][name] = [
            {"phase": pr.phase, **metric_row(name, pr.result)}
            for pr in sorted(results, key=lambda p: p.phase)]
    save_json("drift", out)
    return out


def summarize_drift(out) -> str:
    lines = [f"drift[{out['scenario']}] {out['n_phases']} phases:"]
    for name, rows in out["policies"].items():
        utils = " -> ".join(f"bb={r['util_bb']:.3f}/wait={r['avg_wait']:.0f}s"
                            for r in rows)
        lines.append(f"  {name}: {utils}")
    return "\n".join(lines)


def run(quick: bool = True, scenarios=("S1", "S2", "S3", "S4", "S5"),
        seed: int = 0, vector: int = 0):
    cfg, res = mini_setup(seed=seed)
    n_sets, jobs_per_set = (6, 260) if quick else (16, 1200)

    # Training workloads span the contention range (paper §III-D trains
    # across "a range of workloads"): mix the mid (S2) and heavy (S4)
    # regimes through the sampled->real->synthetic curriculum.
    ordered = []
    for i, regime in enumerate(("S2",)):
        train_cfg, _ = mini_setup(seed=seed + 1 + i, duration_days=3.0)
        train_trace = build_scenarios(train_cfg, names=(regime,))[regime]
        cur = build_curriculum(train_cfg, train_trace,
                               n_sampled=n_sets // 2,
                               n_real=n_sets // 3 or 1,
                               n_synth=n_sets // 3 or 1,
                               jobs_per_set=jobs_per_set, seed=seed + i)
        ordered.extend(cur.ordered("sampled_real_synthetic"))
    # Burst-buffer demands for sampled/synthetic sets follow the scenario.
    t0 = time.time()
    agent = train_mrsch(res, ordered, quick=quick)
    scalar = train_scalar_rl(res, ordered)
    train_s = time.time() - t0

    eval_sets = build_scenarios(cfg, names=scenarios, seed=seed + 7)
    out = {"train_seconds": train_s, "scenarios": {}}
    for name in scenarios:
        jobs = eval_sets[name]
        rows = []
        for label, policy in [
            ("FCFS", FCFSPolicy()),
            ("Optimization(GA)", GAOptimizer(GAConfig(population=12,
                                                      generations=8))),
            ("ScalarRL", scalar),
            ("MRSch", agent),
        ]:
            r = evaluate(policy, res, jobs, window=10)
            rows.append(metric_row(label, r))
        out["scenarios"][name] = {
            "rows": rows,
            "kiviat": kiviat_scores(rows),
        }
    if vector and vector > 1:
        # Same trained agent swept through the batched rollout engine:
        # record the decision-throughput speedup next to the fidelity rows.
        out["vector_sweep"] = sweep_throughput(
            agent, res, cfg, scenarios=scenarios, seeds=(seed + 7,),
            vector=vector)
    save_json("scheduling", out)
    return out


def summarize(out) -> str:
    lines = []
    for name, data in out["scenarios"].items():
        k = data["kiviat"]
        best = max(k, key=k.get)
        fcfs = [r for r in data["rows"] if r["method"] == "FCFS"][0]
        mrsch = [r for r in data["rows"] if r["method"] == "MRSch"][0]
        wait_gain = (fcfs["avg_wait"] - mrsch["avg_wait"]) / max(
            fcfs["avg_wait"], 1e-9)
        lines.append(f"{name}: best={best} kiviat={k} "
                     f"MRSch wait cut vs FCFS={wait_gain:.1%}")
    if "vector_sweep" in out:
        lines.append(summarize_sweep(out["vector_sweep"]))
    return "\n".join(lines)


def summarize_sweep(sw) -> str:
    return (f"sweep[N={sw['n_envs']}]: "
            f"seq={sw['sequential']['decisions_per_sec']:.0f}/s "
            f"vec={sw['vectorized']['decisions_per_sec']:.0f}/s "
            f"speedup={sw['decision_throughput_speedup']:.2f}x "
            f"equivalent={sw['equivalent']}")


def _grid_exit(summary) -> int:
    """Exit status for grid benches: any policy crashing mid-grid makes
    the run a failure even though the surviving rows were written (the
    partial JSON is still uploaded as evidence)."""
    fails = summary.get("failures") or []
    for f in fails:
        print(f"FAILED policy {f['policy']}: {f['error']} "
              f"({len(f['cells'])} cells lost)", file=sys.stderr)
    return 1 if fails else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--vector", type=int, default=0,
                    help="also time the sweep with N lockstep environments")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizing, no training")
    ap.add_argument("--matrix", action="store_true",
                    help="policy x scenario registry grid "
                         "-> results/bench/matrix.json/.csv")
    ap.add_argument("--tournament", action="store_true",
                    help="baseline-zoo round-robin + leaderboard "
                         "-> results/bench/tournament.json + leaderboard.md")
    ap.add_argument("--drift", action="store_true",
                    help="§V-D adaptation: per-phase metrics across a "
                         "mid-trace workload shift -> results/bench/drift.json")
    ap.add_argument("--faults", action="store_true",
                    help="lifecycle grid: workflow DAGs + fault injection "
                         "-> results/bench/faults.json")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated registry scenario subset for "
                         "--matrix/--tournament (default: the lane's grid)")
    ap.add_argument("--seeds", type=int, default=0,
                    help="number of seeds (1..N) for --matrix/--tournament "
                         "(default: the lane's seed set)")
    args = ap.parse_args(argv)
    if args.vector < 0:
        ap.error(f"--vector must be >= 0, got {args.vector}")
    if args.seeds < 0:
        ap.error(f"--seeds must be >= 0, got {args.seeds}")
    scenarios = tuple(s for s in (args.scenarios or "").split(",") if s) or None
    seeds = tuple(range(1, args.seeds + 1)) if args.seeds else None
    if args.tournament:
        t = run_tournament_bench(smoke=args.smoke, vector=args.vector or 4,
                                 scenarios=scenarios, seeds=seeds)
        print(summarize_tournament(t))
        return _grid_exit(t["summary"])
    if args.matrix:
        m = run_matrix_bench(smoke=args.smoke, vector=args.vector or 4,
                             scenarios=scenarios, seeds=seeds)
        print(summarize_matrix(m))
        return _grid_exit(m["summary"])
    if args.faults:
        out = run_faults_bench(smoke=args.smoke, vector=args.vector or 4)
        print(summarize_faults(out))
        return _grid_exit(out["summary"])
    if args.drift:
        print(summarize_drift(run_drift_bench(smoke=args.smoke)))
        return 0
    if args.smoke:
        print(summarize_sweep(run_smoke(vector=args.vector or 4)))
        return 0
    print(summarize(run(quick=not args.full, vector=args.vector)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
