"""Figs. 5-7: scheduling performance on S1-S5, four methods.

Trains MRSch (curriculum) and ScalarRL on sampled/real/synthetic jobsets,
then evaluates FCFS / GA / ScalarRL / MRSch on each scenario's held-out
trace.  Emits per-scenario metric rows (Figs. 5-6) and normalized overall
scores (Fig. 7 Kiviat areas).
"""
from __future__ import annotations

import time

from repro.core import FCFSPolicy, GAConfig, GAOptimizer, evaluate
from repro.workloads import build_curriculum, build_scenarios, generate_trace

from .common import (Timer, kiviat_scores, metric_row, mini_setup, save_json,
                     train_mrsch, train_scalar_rl)


def run(quick: bool = True, scenarios=("S1", "S2", "S3", "S4", "S5"),
        seed: int = 0):
    cfg, res = mini_setup(seed=seed)
    n_sets, jobs_per_set = (6, 260) if quick else (16, 1200)

    # Training workloads span the contention range (paper §III-D trains
    # across "a range of workloads"): mix the mid (S2) and heavy (S4)
    # regimes through the sampled->real->synthetic curriculum.
    ordered = []
    for i, regime in enumerate(("S2",)):
        train_cfg, _ = mini_setup(seed=seed + 1 + i, duration_days=3.0)
        train_trace = build_scenarios(train_cfg, names=(regime,))[regime]
        cur = build_curriculum(train_cfg, train_trace,
                               n_sampled=n_sets // 2,
                               n_real=n_sets // 3 or 1,
                               n_synth=n_sets // 3 or 1,
                               jobs_per_set=jobs_per_set, seed=seed + i)
        ordered.extend(cur.ordered("sampled_real_synthetic"))
    # Burst-buffer demands for sampled/synthetic sets follow the scenario.
    t0 = time.time()
    agent = train_mrsch(res, ordered, quick=quick)
    scalar = train_scalar_rl(res, ordered)
    train_s = time.time() - t0

    eval_sets = build_scenarios(cfg, names=scenarios, seed=seed + 7)
    out = {"train_seconds": train_s, "scenarios": {}}
    for name in scenarios:
        jobs = eval_sets[name]
        rows = []
        for label, policy in [
            ("FCFS", FCFSPolicy()),
            ("Optimization(GA)", GAOptimizer(GAConfig(population=12,
                                                      generations=8))),
            ("ScalarRL", scalar),
            ("MRSch", agent),
        ]:
            r = evaluate(policy, res, jobs, window=10)
            rows.append(metric_row(label, r))
        out["scenarios"][name] = {
            "rows": rows,
            "kiviat": kiviat_scores(rows),
        }
    save_json("scheduling", out)
    return out


def summarize(out) -> str:
    lines = []
    for name, data in out["scenarios"].items():
        k = data["kiviat"]
        best = max(k, key=k.get)
        fcfs = [r for r in data["rows"] if r["method"] == "FCFS"][0]
        mrsch = [r for r in data["rows"] if r["method"] == "MRSch"][0]
        wait_gain = (fcfs["avg_wait"] - mrsch["avg_wait"]) / max(
            fcfs["avg_wait"], 1e-9)
        lines.append(f"{name}: best={best} kiviat={k} "
                     f"MRSch wait cut vs FCFS={wait_gain:.1%}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(summarize(run()))
