"""Fig. 3: MLP vs CNN state module ablation."""
from __future__ import annotations

from repro.core import evaluate
from repro.workloads import build_curriculum, build_scenarios

from .common import kiviat_scores, metric_row, mini_setup, save_json, train_mrsch


def run(quick: bool = True, seed: int = 0):
    cfg, res = mini_setup(seed=seed)
    train_cfg, _ = mini_setup(seed=seed + 1, duration_days=3.0)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=3, n_real=1, n_synth=2,
                           jobs_per_set=260, seed=seed)
    sets = cur.ordered("sampled_real_synthetic")
    eval_jobs = build_scenarios(cfg, names=("S2",), seed=seed + 7)["S2"]

    rows = []
    for module in ("mlp", "cnn"):
        agent = train_mrsch(res, sets, quick=quick, state_module=module)
        r = evaluate(agent, res, eval_jobs)
        rows.append(metric_row(module.upper(), r))
    out = {"rows": rows, "kiviat": kiviat_scores(rows)}
    save_json("state_module", out)
    return out


if __name__ == "__main__":
    o = run()
    print(o["kiviat"])
