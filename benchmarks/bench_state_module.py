"""Fig. 3: MLP vs CNN state module ablation — plus the NN-backend
microbench (xla vs pallas fused-MLP) over the padded decision batches
the rollout engine actually produces.

CLI:
    python -m benchmarks.bench_state_module                   # Fig. 3
    python -m benchmarks.bench_state_module --backend pallas  # backend
        microbench: forward + grad timings per batch shape, speedup vs
        xla, written to results/bench/BENCH_state_module.json; add
        --update-baseline to refresh the committed perf-trajectory
        baseline benchmarks/baselines/BENCH_state_module.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import evaluate
from repro.workloads import build_curriculum, build_scenarios

from .common import kiviat_scores, metric_row, mini_setup, save_json, train_mrsch

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")

# Mini (quick) and paper-scale state-module MLP shapes [in, h1, h2, out].
QUICK_SIZES = [712, 1024, 256, 128]
FULL_SIZES = [11410, 4000, 1000, 512]
# Padded decision-batch widths: _greedy_rows pads a rollout round to the
# next power of two, so these are the M shapes the kernel really sees
# (1 = sequential select, 8/16 = typical lane counts, 64 = train batch).
BATCH_WIDTHS = (1, 8, 16, 64)


def _time_fn(fn, *args, iters: int = 5):
    import jax
    jax.block_until_ready(fn(*args))              # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def backend_microbench(quick: bool = True, seed: int = 0,
                       backend: str = "pallas", iters: int = 5,
                       baseline_path: str | None = None):
    """Forward + gradient timings of the DFP state-module MLP on the
    requested backend vs the xla reference, per padded batch width.

    Always writes results/bench/BENCH_state_module.json (gitignored
    scratch); refreshes the committed baseline only when
    ``baseline_path`` is given (CLI: --update-baseline)."""
    import jax
    import jax.numpy as jnp

    from repro.nn.backend import mlp_forward, resolve_backend
    from repro.nn.modules import mlp_init

    resolve_backend(backend)
    sizes = QUICK_SIZES if quick else FULL_SIZES
    params = mlp_init(jax.random.PRNGKey(seed), sizes)

    def make_fns(bk):
        fwd = jax.jit(lambda p, x: mlp_forward(
            p, x, final_activation="leaky_relu", backend=bk))
        loss = jax.jit(jax.grad(lambda p, x: mlp_forward(
            p, x, final_activation="leaky_relu", backend=bk).sum()))
        return fwd, loss

    shapes = []
    for width in BATCH_WIDTHS:
        x = jax.random.normal(jax.random.fold_in(
            jax.random.PRNGKey(seed), width), (width, sizes[0]), jnp.float32)
        row = {"batch": width, "sizes": sizes}
        for bk in dict.fromkeys(("xla", backend)):   # no double-timing xla
            fwd, grad = make_fns(bk)
            row[f"{bk}_fwd_us"] = round(_time_fn(fwd, params, x,
                                                 iters=iters) * 1e6, 1)
            row[f"{bk}_grad_us"] = round(_time_fn(grad, params, x,
                                                  iters=iters) * 1e6, 1)
        if backend != "xla":
            row["fwd_speedup_vs_xla"] = round(
                row["xla_fwd_us"] / max(row[f"{backend}_fwd_us"], 1e-9), 3)
            row["grad_speedup_vs_xla"] = round(
                row["xla_grad_us"] / max(row[f"{backend}_grad_us"], 1e-9), 3)
        shapes.append(row)

    out = {
        "bench": "state_module_backend",
        "backend": backend,
        "platform": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "quick": quick,
        "iters": iters,
        "shapes": shapes,
        "note": ("interpret-mode Pallas on CPU is expected to trail XLA; "
                 "the committed baseline tracks the trajectory so compiled "
                 "TPU runs have a reference point"),
    }
    save_json("BENCH_state_module", out)
    if baseline_path:
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        with open(baseline_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


def run(quick: bool = True, seed: int = 0, backend: str | None = None):
    if backend:
        return backend_microbench(quick=quick, seed=seed, backend=backend)
    cfg, res = mini_setup(seed=seed)
    train_cfg, _ = mini_setup(seed=seed + 1, duration_days=3.0)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=3, n_real=1, n_synth=2,
                           jobs_per_set=260, seed=seed)
    sets = cur.ordered("sampled_real_synthetic")
    eval_jobs = build_scenarios(cfg, names=("S2",), seed=seed + 7)["S2"]

    rows = []
    for module in ("mlp", "cnn"):
        agent = train_mrsch(res, sets, quick=quick, state_module=module)
        r = evaluate(agent, res, eval_jobs)
        rows.append(metric_row(module.upper(), r))
    out = {"rows": rows, "kiviat": kiviat_scores(rows)}
    save_json("state_module", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default=None, choices=("xla", "pallas"),
                    help="run the NN-backend microbench instead of Fig. 3")
    ap.add_argument("--update-baseline", action="store_true",
                    help="also refresh the committed "
                         "benchmarks/baselines/BENCH_state_module.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.backend:
        o = backend_microbench(
            quick=not args.full, seed=args.seed, backend=args.backend,
            baseline_path=os.path.join(BASELINE_DIR,
                                       "BENCH_state_module.json")
            if args.update_baseline else None)
    else:
        o = run(quick=not args.full, seed=args.seed)
    if args.backend:
        for row in o["shapes"]:
            print(f"batch={row['batch']:>3} "
                  f"xla fwd={row['xla_fwd_us']}us "
                  f"{args.backend} fwd={row[f'{args.backend}_fwd_us']}us "
                  f"speedup={row.get('fwd_speedup_vs_xla', 1.0)}x "
                  f"(grad {row.get('grad_speedup_vs_xla', 1.0)}x)")
    else:
        print(o["kiviat"])
