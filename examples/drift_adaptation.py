"""Workload drift (§V-D): watch policies face a mid-trace demand shift.

    PYTHONPATH=src python examples/drift_adaptation.py [--scenario NAME]

Builds a drifting registry scenario (default: ``drift-bb-surge`` — at
mid-trace, 85% of jobs suddenly request burst buffer, +25% sizes), cuts
it at the shift boundary into phases, and walks each policy through the
phases via the lockstep engine's refill hook.  The per-phase table shows
the distribution shift arriving (BB utilization jumps) and how each
policy's wait/slowdown respond.  Pass any ``drift-*`` registry name to
try the other §V-D shifts; ``--list`` prints the whole registry.
"""
import argparse

from repro.core import AgentConfig, MRSchAgent
from repro.eval import default_policies
from repro.workloads import (ThetaConfig, build_jobs, get_scenario,
                             run_phases, scenario_names, segment_jobs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="drift-bb-surge",
                    help="a drift-family registry scenario")
    ap.add_argument("--phases", type=int, default=2)
    ap.add_argument("--days", type=float, default=1.5)
    ap.add_argument("--list", action="store_true",
                    help="print the scenario registry and exit")
    args = ap.parse_args()

    if args.list:
        for name in scenario_names():
            spec = get_scenario(name)
            print(f"{name:20s} [{spec.family}] {spec.description}")
        return

    cfg = ThetaConfig.mini(seed=0, duration_days=args.days, jobs_per_day=160)
    res = cfg.resources()
    spec = get_scenario(args.scenario)
    print(f"{args.scenario}: {spec.description}\n")
    jobs = build_jobs(args.scenario, cfg, seed=1)
    phases = segment_jobs(jobs, args.phases)
    print(f"{len(jobs)} jobs -> {args.phases} phases "
          f"({', '.join(str(len(p)) for p in phases)} jobs)\n")

    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(256, 64), state_out=32, module_hidden=16))
    # (train the agent for paper-faithful adaptation; the drift mechanics
    # and the per-phase reporting are identical either way)

    print(f"{'policy':10s} {'phase':>5s} {'node_util':>9s} {'bb_util':>8s} "
          f"{'wait_min':>9s} {'slowdown':>9s} {'unstarted':>9s}")
    for name, factory in default_policies(res, agent=agent).items():
        pol = factory()
        for pr in sorted(run_phases(pol, res, [phases]),
                         key=lambda p: p.phase):
            m = pr.result.metrics
            print(f"{name:10s} {pr.phase:5d} {m.utilization['node']:9.3f} "
                  f"{m.utilization['bb']:8.3f} {m.avg_wait / 60:9.1f} "
                  f"{m.avg_slowdown:9.2f} {pr.result.n_unstarted:9d}")


if __name__ == "__main__":
    main()
