"""Scenario sweep: S1-S5 x 3 seeds in one batched rollout.

    PYTHONPATH=src python examples/scenario_sweep.py [--sequential]

All 15 environments advance in lockstep through the VectorSimulator, so
every round of pending scheduling decisions is answered by a single jitted
DFP forward pass instead of 15 separate ones.  Runs in about a minute on
one CPU core; pass --sequential to time the classic one-trace-at-a-time
loop for comparison.
"""
import argparse
from collections import defaultdict

from repro.core import AgentConfig, MRSchAgent
from repro.workloads import ThetaConfig, build_sweep, run_sweep

SCENARIOS = ("S1", "S2", "S3", "S4", "S5")
SEEDS = (1, 2, 3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sequential", action="store_true",
                    help="also time the unbatched loop for comparison")
    ap.add_argument("--days", type=float, default=1.0)
    args = ap.parse_args()

    cfg = ThetaConfig.mini(seed=0, duration_days=args.days, jobs_per_day=180)
    res = cfg.resources()
    tasks = build_sweep(cfg, scenarios=SCENARIOS, seeds=SEEDS)

    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(512, 128), state_out=64, module_hidden=32))
    # (train the agent first for paper-faithful numbers; the sweep mechanics
    # and the batching speedup are identical either way)

    out = run_sweep(res, tasks, agent, vector=len(tasks))
    print(f"[{out['mode']}] {out['n_tasks']} envs, {out['decisions']} "
          f"decisions in {out['wall_seconds']:.1f}s "
          f"({out['decisions_per_sec']:.0f} decisions/s)")

    per_scenario = defaultdict(list)
    for row in out["tasks"]:
        per_scenario[row["scenario"]].append(row)
    print(f"{'scenario':9s} {'node_util':>9s} {'bb_util':>8s} "
          f"{'wait_min':>9s} {'slowdown':>9s}")
    for name in SCENARIOS:
        rows = per_scenario[name]
        mean = lambda k: sum(r[k] for r in rows) / len(rows)
        print(f"{name:9s} {mean('util_node'):9.3f} {mean('util_bb'):8.3f} "
              f"{mean('avg_wait') / 60:9.1f} {mean('avg_slowdown'):9.2f}")

    if args.sequential:
        seq = run_sweep(res, tasks, agent, vector=0)
        print(f"[sequential] same sweep: {seq['wall_seconds']:.1f}s "
              f"({seq['decisions_per_sec']:.0f} decisions/s) -> batched "
              f"speedup {seq['wall_seconds'] / out['wall_seconds']:.2f}x")


if __name__ == "__main__":
    main()
