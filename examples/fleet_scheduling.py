"""MRSch as the framework's fleet scheduler (first-class integration).

Jobs are (arch x shape) cells from the assigned matrix — each demands a
pod slice of chips, burst-buffer TB for checkpoint staging, and a power
envelope.  The identical MRSch agent used in the paper reproduction
gang-schedules them.

    PYTHONPATH=src python examples/fleet_scheduling.py
"""
from repro.launch.scheduler import (FleetSpec, job_demands, make_fleet_agent,
                                    schedule_fleet, synth_fleet_trace)


def main():
    fleet = FleetSpec()
    print("fleet:", fleet)
    for cell in [("deepseek-v3-671b", "train_4k"),
                 ("gemma-2b", "decode_32k"),
                 ("nemotron-4-340b", "prefill_32k")]:
        print(f"  demands {cell}: {job_demands(*cell, fleet)}")

    jobs = synth_fleet_trace(fleet, 80, seed=42)
    agent = make_fleet_agent(fleet, train_jobs=120, episodes=3)
    for policy in ("fcfs", "mrsch"):
        r = schedule_fleet(jobs, fleet, policy,
                           agent=agent if policy == "mrsch" else None)
        m = r.metrics
        print(f"{policy:6s} chips_util={m.utilization['chips']:.3f} "
              f"bb_util={m.utilization['bb']:.3f} "
              f"power_util={m.utilization['power']:.3f} "
              f"wait={m.avg_wait / 3600:.2f}h slow={m.avg_slowdown:.2f}")


if __name__ == "__main__":
    main()
