"""Train a reduced LM from the architecture zoo end-to-end on CPU, with
checkpoint/restart fault tolerance (kill it mid-run and re-invoke: it
resumes from the last checkpoint).

    PYTHONPATH=src python examples/lm_pretrain.py --arch stablelm-1.6b \
        --steps 30

Any of the 10 ``--arch`` ids works; the config is the reduced same-family
variant (full configs are exercised via the AOT dry-run).
"""
import argparse

from repro.configs import ARCH_NAMES, smoke_config
from repro.configs.shapes import InputShape
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default="results/lm_ckpt")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    shape = InputShape("example", args.seq, args.batch, "train")
    run = train_loop(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt,
                     ckpt_every=10, log_every=5)
    print(f"ran {run.steps} steps (restored_from={run.restored_from}); "
          f"loss {run.losses[0]:.3f} -> {run.losses[-1]:.3f} "
          f"in {run.wall_s:.0f}s")


if __name__ == "__main__":
    main()
