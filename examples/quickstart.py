"""Quickstart: train a small MRSch agent and compare it against FCFS.

    PYTHONPATH=src python examples/quickstart.py

Runs in a few minutes on one CPU core (mini Theta-like cluster: 256 nodes,
80 burst-buffer units).
"""
import time

from repro.core import AgentConfig, FCFSPolicy, MRSchAgent, evaluate, train_agent
from repro.sim import run_trace
from repro.workloads import ThetaConfig, build_scenarios, sampled_jobsets


def main():
    cfg = ThetaConfig.mini(seed=0, duration_days=1.5, jobs_per_day=240)
    res = cfg.resources()
    trace = build_scenarios(cfg, names=("S4",))["S4"]   # heavy BB contention

    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(512, 128), state_out=64, module_hidden=32,
        grad_steps_per_episode=16, batch_size=32, eps_decay=0.9))

    t0 = time.time()
    train_agent(agent, res, sampled_jobsets(trace, 4, 200, seed=1))
    print(f"trained in {time.time() - t0:.0f}s "
          f"(replay rows: {agent.replay.rows}, eps: {agent.epsilon:.2f})")

    for name, policy in [("FCFS", FCFSPolicy()), ("MRSch", agent)]:
        r = evaluate(policy, res, trace)
        m = r.metrics
        print(f"{name:6s} node_util={m.utilization['node']:.3f} "
              f"bb_util={m.utilization['bb']:.3f} "
              f"wait={m.avg_wait / 60:.1f}min slowdown={m.avg_slowdown:.2f}")


if __name__ == "__main__":
    main()
