"""Serve a reduced LM with batched greedy decoding (KV/MLA/SSM caches).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-1.3b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, smoke_config
from repro.launch.serve import generate
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.input_mode == "embeddings":
        print(f"{args.arch} uses a stubbed modality frontend; serving demo "
              f"uses token mode archs — switching to gemma-2b")
        cfg = smoke_config("gemma-2b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    out = generate(cfg, params, prompts, max_new_tokens=args.new_tokens)
    print("generated:", out["tokens"].shape,
          f"decode throughput {out['decode_tps']:.1f} tok/s (CPU)")


if __name__ == "__main__":
    main()
