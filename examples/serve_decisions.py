"""Online decision serving: replay a registry scenario through the service.

    PYTHONPATH=src python examples/serve_decisions.py [--scenario S1]
        [--checkpoint agent.npz] [--watch-dir ckpts/] [--max-wait-ms 0]

Starts a ``DecisionService`` on a trained (``--checkpoint``) or randomly
initialized MRSch agent, replays a scenario from the workload registry
through it (``ServiceSim`` — the identical trajectory a direct
``Simulator`` run produces), and prints the scheduling metrics plus the
end-to-end request latency histogram.  With ``--watch-dir`` a
``CheckpointWatcher`` polls for new checkpoints and hot-swaps them into
the service while it answers requests — drop a ``CheckpointManager``
save into the directory from another process to watch a zero-downtime
policy update.
"""
import argparse

import numpy as np

from repro.core import AgentConfig, MRSchAgent
from repro.serve import CheckpointWatcher, DecisionService, ServeConfig, ServiceSim
from repro.workloads import ThetaConfig, scenario_names


def latency_histogram(lat_s, bins=12, width=46):
    """Text histogram of request latencies (log-spaced buckets)."""
    ms = np.asarray(lat_s) * 1e3
    edges = np.logspace(np.log10(max(ms.min(), 1e-3)),
                        np.log10(ms.max() + 1e-9), bins + 1)
    counts, _ = np.histogram(ms, bins=edges)
    peak = max(int(counts.max()), 1)
    lines = []
    for i, c in enumerate(counts):
        bar = "#" * max(int(round(c / peak * width)), 1 if c else 0)
        lines.append(f"{edges[i]:8.2f}-{edges[i + 1]:8.2f} ms "
                     f"{c:6d} {bar}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="S1",
                    help=f"registry scenario ({', '.join(scenario_names()[:6])}, ...)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--days", type=float, default=0.5)
    ap.add_argument("--checkpoint", default=None,
                    help="agent .npz from MRSchAgent.save (random init if omitted)")
    ap.add_argument("--watch-dir", default=None,
                    help="CheckpointManager directory to hot-reload from")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=0.0)
    args = ap.parse_args()

    cfg = ThetaConfig.mini(seed=0, duration_days=args.days, jobs_per_day=160)
    res = cfg.resources()
    # Same architecture examples/train_scheduler.py trains and saves, so
    # its results/mrsch_agent.npz loads here (load validates shapes).
    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(1024, 256), state_out=128, module_hidden=64))
    if args.checkpoint:
        agent.load(args.checkpoint)
        print(f"loaded {args.checkpoint}")

    svc_cfg = ServeConfig(max_batch=args.max_batch,
                          max_wait_s=args.max_wait_ms / 1e3)
    with DecisionService(agent, svc_cfg) as svc:
        watcher = None
        if args.watch_dir:
            watcher = CheckpointWatcher(svc, args.watch_dir,
                                        poll_interval_s=0.5).start()
        ssim = ServiceSim(svc, res, track_latency=True)
        result = ssim.run_scenario(args.scenario, cfg, seed=args.seed)
        if watcher is not None:
            watcher.stop()
            print(f"watcher: {watcher.stats()}")

    row = result.metrics.as_row()
    print(f"\n[{args.scenario}/seed{args.seed}] {result.decisions} decisions, "
          f"{row['n_jobs']:.0f} jobs, makespan {result.makespan / 3600:.1f}h")
    print(f"util_node={row['util_node']:.3f} util_bb={row['util_bb']:.3f} "
          f"avg_wait={row['avg_wait'] / 60:.1f}min "
          f"avg_slowdown={row['avg_slowdown']:.2f}")
    st = svc.stats()
    print(f"service: {st['requests']} requests in {st['batches']} batches "
          f"(mean {st['mean_batch']}), buckets compiled "
          f"{st['buckets']['compiles']} of {len(st['buckets']['buckets'])}, "
          f"reloads={st['reloads']}")
    lat = ssim.latencies_s
    print(f"\nrequest latency (n={len(lat)}, "
          f"p50={np.percentile(lat, 50) * 1e3:.2f}ms, "
          f"p99={np.percentile(lat, 99) * 1e3:.2f}ms):")
    print(latency_histogram(lat))


if __name__ == "__main__":
    main()
