"""End-to-end driver (the paper's kind): curriculum-train the MRSch DFP
agent through sampled -> real -> synthetic jobsets (§III-D), checkpoint
it, and evaluate against all three baselines on held-out S1-S5 traces.

By default the curriculum is collected through the batched rollout
engine: --vector N lanes advance in lockstep, each decision round costs
one jitted epsilon-greedy DFP forward, and a lane that finishes a jobset
immediately trains on it and pulls the next one.  --sequential restores
the paper's one-trace-at-a-time loop (identical trajectories at N=1).

    PYTHONPATH=src python examples/train_scheduler.py [--vector N]
"""
import argparse
import os
import time

from repro.core import (AgentConfig, FCFSPolicy, GAConfig, GAOptimizer,
                        MRSchAgent, ScalarRLConfig, ScalarRLPolicy,
                        TrainConfig, evaluate, train_agent)
from repro.sim import run_trace
from repro.workloads import ThetaConfig, build_curriculum, build_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sets", type=int, default=6)
    ap.add_argument("--jobs-per-set", type=int, default=240)
    ap.add_argument("--vector", type=int, default=4,
                    help="lockstep environment lanes for curriculum "
                         "collection (1 = batched engine, single lane)")
    ap.add_argument("--sequential", action="store_true",
                    help="use the classic one-trace-at-a-time loop")
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"),
                    help="NN execution backend (pallas = fused-MLP "
                         "kernels; see docs/pallas_backend.md)")
    ap.add_argument("--out", default="results/mrsch_agent.npz")
    args = ap.parse_args()

    cfg = ThetaConfig.mini(seed=0, duration_days=2.0, jobs_per_day=260)
    res = cfg.resources()
    train_cfg = ThetaConfig.mini(seed=1, duration_days=3.0, jobs_per_day=260)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=args.sets // 2,
                           n_real=args.sets // 3 or 1,
                           n_synth=args.sets // 3 or 1,
                           jobs_per_set=args.jobs_per_set)

    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(1024, 256), state_out=128, module_hidden=64,
        grad_steps_per_episode=24, batch_size=48, eps_decay=0.95,
        backend=args.backend))
    train_config = None if args.sequential else TrainConfig(
        n_envs=max(1, args.vector), verbose=True)
    t0 = time.time()
    log = train_agent(agent, res, cur.ordered("sampled_real_synthetic"),
                      verbose=True, config=train_config)
    mode = "sequential" if args.sequential else f"vector{args.vector}"
    print(f"curriculum training [{mode}]: {time.time() - t0:.0f}s, "
          f"{log.decisions} decisions ({log.decisions_per_sec:.0f}/s), "
          f"final loss "
          f"{log.episode_losses[-1] if log.episode_losses else None}")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    agent.save(args.out)
    print("agent checkpoint:", args.out)

    scalar = ScalarRLPolicy(res, ScalarRLConfig(hidden=(512, 128)))
    scalar.training = True
    for js in cur.ordered("sampled_real_synthetic"):
        run_trace(res, js, scalar)
        scalar.end_episode()
    scalar.training = False

    for sname, jobs in build_scenarios(cfg, seed=7).items():
        print(f"--- {sname}")
        for label, policy in [
            ("FCFS", FCFSPolicy()),
            ("GA", GAOptimizer(GAConfig(population=12, generations=8))),
            ("ScalarRL", scalar),
            ("MRSch", agent),
        ]:
            m = evaluate(policy, res, jobs).metrics
            print(f"  {label:9s} node={m.utilization['node']:.3f} "
                  f"bb={m.utilization['bb']:.3f} "
                  f"wait={m.avg_wait / 60:.1f}min slow={m.avg_slowdown:.2f}")


if __name__ == "__main__":
    main()
