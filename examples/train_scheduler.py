"""End-to-end driver (the paper's kind): curriculum-train the MRSch DFP
agent through sampled -> real -> synthetic jobsets (§III-D), checkpoint
it, and evaluate against all three baselines on held-out S1-S5 traces.

    PYTHONPATH=src python examples/train_scheduler.py [--episodes N]
"""
import argparse
import os
import time

from repro.core import (AgentConfig, FCFSPolicy, GAConfig, GAOptimizer,
                        MRSchAgent, ScalarRLConfig, ScalarRLPolicy, evaluate,
                        train_agent)
from repro.sim import run_trace
from repro.workloads import ThetaConfig, build_curriculum, build_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sets", type=int, default=6)
    ap.add_argument("--jobs-per-set", type=int, default=240)
    ap.add_argument("--out", default="results/mrsch_agent.npz")
    args = ap.parse_args()

    cfg = ThetaConfig.mini(seed=0, duration_days=2.0, jobs_per_day=260)
    res = cfg.resources()
    train_cfg = ThetaConfig.mini(seed=1, duration_days=3.0, jobs_per_day=260)
    trace = build_scenarios(train_cfg, names=("S2",))["S2"]
    cur = build_curriculum(train_cfg, trace, n_sampled=args.sets // 2,
                           n_real=args.sets // 3 or 1,
                           n_synth=args.sets // 3 or 1,
                           jobs_per_set=args.jobs_per_set)

    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(1024, 256), state_out=128, module_hidden=64,
        grad_steps_per_episode=24, batch_size=48, eps_decay=0.95))
    t0 = time.time()
    log = train_agent(agent, res, cur.ordered("sampled_real_synthetic"),
                      verbose=True)
    print(f"curriculum training: {time.time() - t0:.0f}s, "
          f"final loss {log.episode_losses[-1] if log.episode_losses else None}")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    agent.save(args.out)
    print("agent checkpoint:", args.out)

    scalar = ScalarRLPolicy(res, ScalarRLConfig(hidden=(512, 128)))
    scalar.training = True
    for js in cur.ordered("sampled_real_synthetic"):
        run_trace(res, js, scalar)
        scalar.end_episode()
    scalar.training = False

    for sname, jobs in build_scenarios(cfg, seed=7).items():
        print(f"--- {sname}")
        for label, policy in [
            ("FCFS", FCFSPolicy()),
            ("GA", GAOptimizer(GAConfig(population=12, generations=8))),
            ("ScalarRL", scalar),
            ("MRSch", agent),
        ]:
            m = evaluate(policy, res, jobs).metrics
            print(f"  {label:9s} node={m.utilization['node']:.3f} "
                  f"bb={m.utilization['bb']:.3f} "
                  f"wait={m.avg_wait / 60:.1f}min slow={m.avg_slowdown:.2f}")


if __name__ == "__main__":
    main()
