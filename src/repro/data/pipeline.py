"""Data pipeline: deterministic synthetic token streams + input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a cell — weak-type-correct, shardable, no device allocation
(the dry-run contract).  ``make_batch`` materializes the same structure
with a deterministic PRNG for smoke tests and the end-to-end examples.

For ``[vlm]``/``[audio]`` archs the modality frontend is a stub per the
assignment: the pipeline supplies precomputed patch/frame *embeddings* of
the backbone's d_model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    pad_id: int = 0


def _token_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.n_codebooks > 1:
        return (batch, seq, cfg.n_codebooks)
    return (batch, seq)


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one (arch x input-shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        B_, S_ = B, 1
    else:
        B_, S_ = B, S
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.input_mode == "embeddings":
        specs["embeddings"] = jax.ShapeDtypeStruct((B_, S_, cfg.d_model), dtype)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct(_token_shape(cfg, B_, S_),
                                               jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(_token_shape(cfg, B, S),
                                               jnp.int32)
    return specs


def make_batch(cfg: ModelConfig, shape: InputShape, step: int = 0,
               data: DataConfig = DataConfig(), dtype=jnp.float32
               ) -> Dict[str, jnp.ndarray]:
    """Materialized batch matching ``input_specs`` (deterministic)."""
    rng = np.random.default_rng(data.seed * 100_003 + step)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        B_, S_ = B, 1
    else:
        B_, S_ = B, S
    out: Dict[str, jnp.ndarray] = {}
    if cfg.input_mode == "embeddings":
        out["embeddings"] = jnp.asarray(
            rng.standard_normal((B_, S_, cfg.d_model), np.float32), dtype)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, _token_shape(cfg, B_, S_)),
            jnp.int32)
    if shape.kind == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, _token_shape(cfg, B, S)),
            jnp.int32)
    return out


def synthetic_batch_iter(cfg: ModelConfig, shape: InputShape,
                         data: DataConfig = DataConfig(),
                         dtype=jnp.float32) -> Iterator[Dict[str, jnp.ndarray]]:
    step = 0
    while True:
        yield make_batch(cfg, shape, step, data, dtype)
        step += 1
