from .pipeline import DataConfig, input_specs, make_batch, synthetic_batch_iter
