"""MRSch as the framework's fleet scheduler (the paper's technique as a
first-class feature).

A TPU fleet runs many training/serving jobs.  Each job requests:
  * chips       — a pod slice (gang-scheduled, rigid, like HPC jobs)
  * burst buffer— host-side staging TB for checkpoints / dataset shards
  * power       — kW envelope under the facility budget

which is exactly the paper's multi-resource setting (CPU nodes / BB /
power) with renamed units, so the *same* ``MRSchAgent`` (identical code
path, window + reservation + EASY backfilling) schedules the fleet.
Job demand vectors are derived from the dry-run cost model: chips from the
HBM footprint, BB from checkpoint size, power from the chip envelope.
"""
from __future__ import annotations

import argparse
import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..configs import SHAPES, all_configs, get_config
from ..core import AgentConfig, FCFSPolicy, GAOptimizer, MRSchAgent, evaluate, train_agent
from ..distributed.costs import cell_costs
from ..sim import Job, ResourceSpec, run_trace
from ..workloads.jobsets import sampled_jobsets


@dataclass(frozen=True)
class FleetSpec:
    chips: int = 512                 # two pods of 256
    chip_unit: int = 8               # schedulable unit = 8-chip host
    bb_tb: int = 400                 # shared staging burst buffer
    power_budget_kw: int = 160       # facility envelope for this fleet
    hbm_gb_per_chip: float = 16.0
    watts_per_chip: float = 250.0

    def resources(self) -> List[ResourceSpec]:
        return [
            ResourceSpec("chips", self.chips // self.chip_unit, "host"),
            ResourceSpec("bb", self.bb_tb, "TB"),
            ResourceSpec("power", self.power_budget_kw, "kW"),
        ]


def job_demands(arch: str, shape_name: str, fleet: FleetSpec) -> Dict[str, int]:
    """Demand vector for one (arch x shape) job from the cost model."""
    cfg = get_config(arch)
    costs = cell_costs(cfg, SHAPES[shape_name])
    state_bytes = costs.param_bytes * (3.0 if SHAPES[shape_name].kind == "train"
                                       else 1.2)
    chips = max(8, 1 << math.ceil(math.log2(max(
        state_bytes / (fleet.hbm_gb_per_chip * 1e9 * 0.7), 1))))
    chips = min(chips, fleet.chips)
    hosts = max(1, chips // fleet.chip_unit)
    bb = max(1, int(math.ceil(3 * costs.param_bytes / 1e12)))   # 3 checkpoints
    power = max(1, int(math.ceil(chips * fleet.watts_per_chip / 1000.0)))
    return {"chips": hosts, "bb": bb, "power": power}


def synth_fleet_trace(fleet: FleetSpec, n_jobs: int = 200, seed: int = 0,
                      mean_iat_s: float = 900.0,
                      mean_runtime_s: float = 3 * 3600.0) -> List[Job]:
    """A fleet workload: random (arch x shape) cells arriving as jobs."""
    rng = np.random.default_rng(seed)
    cells = [(a, s) for a in all_configs() for s in ("train_4k", "prefill_32k",
                                                     "decode_32k")]
    jobs = []
    t = 0.0
    for i in range(n_jobs):
        t += rng.exponential(mean_iat_s)
        arch, sname = cells[rng.integers(len(cells))]
        runtime = float(np.clip(rng.lognormal(math.log(mean_runtime_s), 0.9),
                                300, 48 * 3600))
        walltime = min(runtime * rng.uniform(1.1, 2.0), 72 * 3600)
        jobs.append(Job(jid=i, submit=t, runtime=runtime, walltime=walltime,
                        demands=job_demands(arch, sname, fleet)))
    return jobs


def make_fleet_agent(fleet: FleetSpec, train_jobs: int = 400,
                     episodes: int = 6, seed: int = 0) -> MRSchAgent:
    """Train an MRSch agent on synthetic fleet traces (fast, CPU-sized)."""
    res = fleet.resources()
    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(512, 256), state_out=128, module_hidden=64,
        grad_steps_per_episode=24, batch_size=48, seed=seed))
    sets = [synth_fleet_trace(fleet, train_jobs // 2, seed=seed + i)
            for i in range(episodes)]
    train_agent(agent, res, sets)
    return agent


def schedule_fleet(jobs: Sequence[Job], fleet: FleetSpec,
                   policy: str = "mrsch", agent: Optional[MRSchAgent] = None):
    res = fleet.resources()
    if policy == "mrsch":
        agent = agent or make_fleet_agent(fleet)
        return evaluate(agent, res, jobs)
    if policy == "fcfs":
        return run_trace(res, jobs, FCFSPolicy())
    if policy == "ga":
        return run_trace(res, jobs, GAOptimizer())
    raise ValueError(policy)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=150)
    ap.add_argument("--policy", default="mrsch",
                    choices=["mrsch", "fcfs", "ga"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    fleet = FleetSpec()
    jobs = synth_fleet_trace(fleet, args.jobs, seed=args.seed + 1000)
    result = schedule_fleet(jobs, fleet, args.policy)
    print(json.dumps({"policy": args.policy,
                      **{k: round(v, 4)
                         for k, v in result.metrics.as_row().items()}}))


if __name__ == "__main__":
    main()
