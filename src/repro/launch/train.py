"""End-to-end training driver (runs real steps — CPU-sized configs in the
examples, production configs on a pod).

Features: pjit'd train step under the sharding rules, deterministic data
pipeline, fault tolerance (async checkpointing + automatic restore +
preemption-signal save), and metrics logging.  ``python -m
repro.launch.train --arch gemma-2b --smoke`` runs a reduced config.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import SHAPES, InputShape, get_config, smoke_config
from ..data.pipeline import DataConfig, make_batch
from ..distributed.sharding import default_rules, param_shardings, use_rules
from ..models import transformer
from ..optim import OptConfig, make_schedule, opt_init
from .mesh import make_host_mesh
from .steps import _bind_rules, make_train_step


@dataclass
class TrainRun:
    steps: int
    losses: list
    wall_s: float
    restored_from: Optional[int]


def train_loop(cfg, shape: InputShape, *, steps: int = 20,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
               mesh=None, dtype=jnp.float32, opt: Optional[OptConfig] = None,
               log_every: int = 5, seed: int = 0,
               resume: bool = True) -> TrainRun:
    mesh = mesh or make_host_mesh()
    rules = default_rules(mesh)
    opt = opt or OptConfig(lr=1e-3, weight_decay=0.0)
    sched = make_schedule("cosine", peak=opt.lr, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)

    with use_rules(rules):
        params = transformer.init_params(jax.random.PRNGKey(seed), cfg, dtype)
        pshard = param_shardings(params, rules)
        params = jax.device_put(params, pshard)
        opt_state = opt_init(params, opt)

    step_fn = jax.jit(_bind_rules(
        make_train_step(cfg, opt, remat=True, lr_schedule=sched), rules),
        donate_argnums=(0, 1))

    start_step = 0
    restored = None
    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if manager is not None and resume:
        try:
            (state, manifest) = manager.restore_latest(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = manifest["step"]
            restored = start_step
        except FileNotFoundError:
            pass

    # Preemption safety: SIGTERM triggers a synchronous save before exit.
    interrupted = {}
    if manager is not None:
        def _on_term(signum, frame):
            interrupted["now"] = True
        try:
            signal.signal(signal.SIGTERM, _on_term)
        except ValueError:
            pass                      # non-main thread (tests)

    losses = []
    t0 = time.time()
    step = start_step
    for step in range(start_step, steps):
        batch = make_batch(cfg, shape, step, DataConfig(seed=seed), dtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if manager is not None and (step + 1) % ckpt_every == 0:
            manager.save_async({"params": params, "opt": opt_state}, step + 1)
        if interrupted:
            manager.save({"params": params, "opt": opt_state}, step + 1)
            print(f"[train] preempted at step {step + 1}; checkpoint flushed")
            break
    if manager is not None:
        manager.wait()
    return TrainRun(steps=step + 1 - start_step, losses=losses,
                    wall_s=time.time() - t0, restored_from=restored)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = InputShape("cli", args.seq, args.batch, "train")
    run = train_loop(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt)
    print(json.dumps({"steps": run.steps, "final_loss": run.losses[-1],
                      "wall_s": run.wall_s}))


if __name__ == "__main__":
    main()
