"""jit-able train / prefill / decode steps + their sharding plumbing."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape
from ..data.pipeline import input_specs
from ..distributed.sharding import Rules, param_pspecs, use_rules
from ..models import transformer
from ..optim import OptConfig, opt_init, opt_update

CACHE_AXES = {
    "k": (None, "batch", "kv_seq", "kv_heads", None),
    "v": (None, "batch", "kv_seq", "kv_heads", None),
    "c": (None, "batch", "kv_seq", None),
    "rope": (None, "batch", "kv_seq", None),
    "state": (None, "batch", "heads", None, None),
    "conv": (None, "batch", None, None),
}


def batch_pspec(rules: Rules, specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in specs.items():
        axes = ["batch"] + [None] * (v.ndim - 1)
        out[k] = rules.spec(axes, v.shape)
    return out


def cache_pspecs(cache_tree, rules: Rules):
    flat = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    treedef = jax.tree_util.tree_structure(cache_tree)
    specs = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        axes = CACHE_AXES.get(name, (None,) * leaf.ndim)
        axes = tuple(axes)[: leaf.ndim]
        if len(axes) < leaf.ndim:
            axes = axes + (None,) * (leaf.ndim - len(axes))
        specs.append(rules.spec(axes, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _bind_rules(fn, rules: Optional[Rules]):
    """Make logical-axis ``shard()`` constraints active while ``fn`` is
    *traced* (tracing happens at ``.lower()`` time, which may be outside
    any ``use_rules`` block)."""
    if rules is None:
        return fn

    @functools.wraps(fn)
    def inner(*a, **k):
        with use_rules(rules):
            return fn(*a, **k)

    return inner


# ------------------------------------------------------------------ steps
def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    unroll: bool = False, remat: bool = True,
                    lr_schedule=None, microbatches: int = 1):
    """``microbatches > 1`` splits the batch and accumulates grads over a
    python loop (activation memory / microbatches; flops stay visible to
    HLO cost analysis, unlike a lax.scan accumulation)."""
    def train_step(params, opt_state, batch):
        def loss_fn(p, b):
            return transformer.loss(p, cfg, b, unroll=unroll, remat=remat)

        if microbatches > 1:
            B = jax.tree_util.tree_leaves(batch)[0].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = B // microbatches
            loss_val = 0.0
            grads = None
            for i in range(microbatches):
                sub = jax.tree.map(lambda t: t[i * mb:(i + 1) * mb], batch)
                l, g = jax.value_and_grad(loss_fn)(params, sub)
                g = jax.tree.map(lambda t: t.astype(jnp.float32) / microbatches, g)
                grads = g if grads is None else jax.tree.map(
                    jnp.add, grads, g)
                loss_val = loss_val + l / microbatches
        else:
            loss_val, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = lr_schedule(opt_state["step"]) if lr_schedule else None
        new_params, new_opt, gnorm = opt_update(grads, opt_state, params,
                                                opt_cfg, lr=lr)
        return new_params, new_opt, {"loss": loss_val, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False):
    def prefill_step(params, batch):
        logits = transformer.forward(params, cfg, batch, unroll=unroll,
                                     remat=False)
        return logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig, unroll: bool = False):
    def serve_step(params, batch, cache, pos):
        logits, new_cache = transformer.decode_step(params, cfg, batch, cache,
                                                    pos, unroll=unroll)
        return logits[:, -1], new_cache

    return serve_step


# ------------------------------------------------------------ cell builder
def build_cell(cfg: ModelConfig, shape: InputShape, rules: Rules,
               opt_cfg: Optional[OptConfig] = None, unroll: bool = False,
               remat: bool = True, dtype=jnp.bfloat16,
               microbatches: int = 1):
    """Return (jitted_fn, example_args as ShapeDtypeStructs) for one cell,
    with in/out shardings resolved under ``rules``."""
    mesh = rules.mesh
    if opt_cfg is None:
        big = cfg.param_count()[0] > 50e9
        opt_cfg = OptConfig(factored=big,
                            m_dtype=jnp.bfloat16 if big else jnp.float32)

    with use_rules(rules):
        pshapes = jax.eval_shape(
            lambda: transformer.init_params(jax.random.PRNGKey(0), cfg, dtype))
        pspecs = param_pspecs(pshapes, rules)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        specs = input_specs(cfg, shape, dtype)
        bspecs = batch_pspec(rules, specs)
        bshard = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}

        if shape.kind == "train":
            oshapes = jax.eval_shape(lambda: opt_init(pshapes, opt_cfg))
            ospecs = param_pspecs_for_opt(oshapes, pspecs)
            oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                                  is_leaf=lambda x: isinstance(x, P))
            fn = _bind_rules(
                make_train_step(cfg, opt_cfg, unroll=unroll, remat=remat,
                                microbatches=microbatches),
                rules)
            jitted = jax.jit(
                fn,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            args = (pshapes, oshapes, specs)
        elif shape.kind == "prefill":
            fn = _bind_rules(make_prefill_step(cfg, unroll=unroll), rules)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard),
                             out_shardings=None)
            args = (pshapes, specs)
        else:  # decode
            cshapes = jax.eval_shape(
                lambda: transformer.init_cache(cfg, shape.global_batch,
                                               shape.seq_len, dtype))
            cspecs = cache_pspecs(cshapes, rules)
            cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                  is_leaf=lambda x: isinstance(x, P))
            fn = _bind_rules(make_decode_step(cfg, unroll=unroll), rules)
            jitted = jax.jit(
                fn,
                in_shardings=(pshard, bshard, cshard, None),
                out_shardings=(None, cshard),
                donate_argnums=(2,),
            )
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            args = (pshapes, specs, cshapes, pos)
    return jitted, args


def param_pspecs_for_opt(opt_shapes, pspecs):
    """Optimizer leaves inherit the param spec when shapes match (m, v);
    factored vr/vc drop the factored dim's axis; scalars replicate."""
    def match(path_spec, leaf):
        return path_spec

    # opt_shapes = {"step": (), "leaves": tree-of-{m,v|vr,vc}}
    import jax.tree_util as jtu

    def leaf_specs(param_spec, state):
        out = {}
        for k, s in state.items():
            if s.ndim == len(param_spec):
                out[k] = param_spec
            else:
                out[k] = P(*([None] * s.ndim))
        return out

    leaves = jax.tree.map(
        leaf_specs, pspecs, opt_shapes["leaves"],
        is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "leaves": leaves}
