"""Serving driver: batched prefill + decode with a KV/SSM cache.

``generate`` runs greedy decoding for a batch of prompts with the same
jit'd ``serve_step`` the dry-run lowers, so serving behaviour and the
decode cells' roofline describe the same program.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_config
from ..distributed.sharding import default_rules, param_shardings, use_rules
from ..models import transformer
from .mesh import make_host_mesh
from .steps import _bind_rules, make_decode_step


def generate(cfg, params, prompts: jnp.ndarray, *, max_new_tokens: int = 16,
             max_len: Optional[int] = None, rules=None,
             dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """prompts (B, S0) int32 -> {'tokens': (B, S0+new), 'decode_tps': float}.
    Prefill is performed incrementally through the decode step (correct for
    every cache family: KV, MLA-compressed, SSM state)."""
    B, S0 = prompts.shape
    max_len = max_len or (S0 + max_new_tokens)
    cache = transformer.init_cache(cfg, B, max_len, dtype)
    step_fn = jax.jit(_bind_rules(make_decode_step(cfg), rules),
                      donate_argnums=(2,))

    tokens = prompts
    logits = None
    for pos in range(S0):
        logits, cache = step_fn(params, {"tokens": tokens[:, pos:pos + 1]},
                                cache, jnp.int32(pos))
    t0 = time.time()
    for pos in range(S0, S0 + max_new_tokens):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, cache = step_fn(params, {"tokens": nxt}, cache,
                                jnp.int32(pos))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    return {"tokens": tokens,
            "decode_tps": B * max_new_tokens / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    rules = default_rules(mesh)
    with use_rules(rules):
        params = transformer.init_params(jax.random.PRNGKey(0), cfg,
                                         jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    out = generate(cfg, params, prompts, max_new_tokens=args.new_tokens,
                   rules=rules)
    print(json.dumps({"shape": list(out["tokens"].shape),
                      "decode_tps": round(float(out["decode_tps"]), 2)}))


if __name__ == "__main__":
    main()
