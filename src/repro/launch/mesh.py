"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices; everything else sees the real
device count.
"""
from __future__ import annotations

import jax

from ..distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); 2 pods -> (2,16,16) with a
    leading "pod" axis for cross-pod data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    dp = max(n // model_parallel, 1)
    return make_mesh((dp, model_parallel), ("data", "model"))
