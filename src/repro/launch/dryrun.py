"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

MUST be the very first two lines (jax locks the device count on first
init): force 512 placeholder host devices for the production meshes.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from dataclasses import replace

import jax

from ..configs import SHAPE_ORDER, SHAPES, all_configs, cell_supported, get_config
from ..distributed.compat import cost_analysis
from ..distributed.costs import cell_costs, flash_correction
from ..distributed.hlo_analysis import V5E, collective_stats, roofline_terms
from ..distributed.sharding import RULE_SETS, default_rules
from .mesh import make_production_mesh
from .steps import build_cell

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/dryrun")


def _truncated(cfg, n_units: int):
    """Reduced-depth config with the same per-unit composition."""
    if cfg.family == "hybrid":
        return replace(cfg, n_layers=cfg.hybrid.attn_period * n_units)
    prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    return replace(cfg, n_layers=prefix + n_units)


def _n_units(cfg) -> float:
    if cfg.family == "hybrid":
        return cfg.n_layers / cfg.hybrid.attn_period
    prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    return cfg.n_layers - prefix


def _lower_compile(cfg, shape, rules, unroll, microbatches=1):
    jitted, args = build_cell(cfg, shape, rules, unroll=unroll,
                              microbatches=microbatches)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def run_cell(arch: str, sname: str, multi_pod: bool, extrapolate: bool = True,
             rules_fn=default_rules, tag: str = "",
             microbatches: int = 1) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[sname]
    ok, reason = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": sname,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_fn(mesh)
    n_chips = 512 if multi_pod else 256

    try:
        compiled, times = _lower_compile(cfg, shape, rules, unroll=False,
                                         microbatches=microbatches)
    except Exception as e:
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec
    ma = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    rec.update(
        status="ok", **times,
        mem=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            total_hbm_gb=(ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes
                          - ma.alias_size_in_bytes) / 1e9,
        ),
        scanned_flops=float(ca.get("flops", 0.0)),
        scanned_bytes=float(ca.get("bytes accessed", 0.0)),
    )

    if extrapolate and not multi_pod:
        try:
            f, b, w, nops = [], [], [], []
            for n in (1, 2):
                cfg_n = _truncated(cfg, n)
                comp_n, _ = _lower_compile(cfg_n, shape, rules, unroll=True,
                                           microbatches=microbatches)
                ca_n = cost_analysis(comp_n)
                st = collective_stats(comp_n.as_text())
                f.append(float(ca_n.get("flops", 0.0)))
                b.append(float(ca_n.get("bytes accessed", 0.0)))
                w.append(st.wire_bytes)
                nops.append(st.count())
            units = _n_units(cfg)
            flops_dev = f[0] + (units - 1) * (f[1] - f[0])
            bytes_dev_raw = b[0] + (units - 1) * (b[1] - b[0])
            wire_dev = w[0] + (units - 1) * (w[1] - w[0])
            corr = flash_correction(cfg, shape)
            flops_dev += corr["flops"] / n_chips
            bytes_dev_raw += corr["bytes"] / n_chips
            # XLA:CPU legalizes bf16 to f32, doubling reported HBM traffic
            # relative to the TPU program; the roofline uses the
            # bf16-adjusted estimate (raw kept alongside).
            bytes_dev = bytes_dev_raw * 0.5
            costs = cell_costs(cfg, shape)
            terms = roofline_terms(flops_dev, bytes_dev, wire_dev)
            rec.update(
                hlo_flops_per_device=flops_dev,
                hlo_bytes_per_device=bytes_dev,
                hlo_bytes_per_device_raw_f32=bytes_dev_raw,
                wire_bytes_per_device=wire_dev,
                collective_ops_L1=nops[0], collective_ops_L2=nops[1],
                flash_corr_flops=corr["flops"] / n_chips,
                model_flops_global=costs.model_flops_global,
                model_flops_per_device=costs.model_flops_global / n_chips,
                useful_ratio=(costs.model_flops_global / n_chips)
                / max(flops_dev, 1.0),
                roofline=terms,
            )
        except Exception as e:
            rec.update(extrapolation_error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="single arch (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--rules", default="baseline", choices=list(RULE_SETS))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true", help="recompute cached")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(all_configs())
    shapes = [args.shape] if args.shape else SHAPE_ORDER
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for sname in shapes:
            for multi in meshes:
                cell_id = f"{arch}__{sname}__{'multi' if multi else 'single'}"
                if args.rules != "baseline":
                    cell_id += f"__{args.rules}"
                if args.microbatches > 1:
                    cell_id += f"__mb{args.microbatches}"
                path = os.path.join(args.out, cell_id + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as fh:
                        rec = json.load(fh)
                    print(f"[cached] {cell_id}: {rec['status']}")
                    continue
                t0 = time.time()
                rec = run_cell(arch, sname, multi,
                               extrapolate=not args.no_extrapolate,
                               rules_fn=RULE_SETS[args.rules],
                               tag=args.rules,
                               microbatches=args.microbatches)
                rec["wall_s"] = time.time() - t0
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                line = f"[{rec['status']:7s}] {cell_id} ({rec['wall_s']:.0f}s)"
                if rec["status"] == "ok":
                    line += (f" mem={rec['mem']['total_hbm_gb']:.2f}GB/dev"
                             f" compile={rec['compile_s']:.0f}s")
                    if "roofline" in rec:
                        r = rec["roofline"]
                        line += (f" dom={r['dominant']}"
                                 f" frac={r['roofline_fraction']:.2f}")
                elif rec["status"] == "failed":
                    failures += 1
                    line += " " + rec.get("error", "")[:160]
                print(line, flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
