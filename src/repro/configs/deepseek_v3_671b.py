"""DeepSeek-V3 671B: MLA + MoE (1 shared + 256 routed, top-8), MTP.
[arXiv:2412.19437; hf]"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,                      # dense FFN width of the 3 leading layers
    vocab_size=129_280,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_expert=2048,
                  first_dense_layers=3),
    act="silu", glu=True, rope_theta=10_000.0,
    mtp_depth=1,
    notes="MTP auxiliary head (mtp_depth=1) available; off in dry-run cells",
)
