"""MusicGen-medium: decoder-only over EnCodec tokens (4 codebooks,
delay pattern at the data layer).  EnCodec frontend is a stub —
input_specs() supplies frame embeddings.  [arXiv:2306.05284; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    act="gelu", glu=False,
    input_mode="embeddings", n_codebooks=4,
    notes="4 parallel codebook heads (vocab 2048 each)",
)
