"""ChatGLM3-6B: dense, GQA kv=2, 2d (half-rotary) RoPE.
[arXiv:2406.12793; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65_024,
    act="silu", glu=True, rope_fraction=0.5, rope_theta=10_000.0,
)
