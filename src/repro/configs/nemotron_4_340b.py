"""Nemotron-4-340B: dense, GQA kv=8, squared-ReLU MLP (no GLU).
[arXiv:2402.16819; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab_size=256_000,
    head_dim=192,
    act="relu2", glu=False, rope_theta=10_000.0,
)
