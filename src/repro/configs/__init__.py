"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import HybridConfig, MLAConfig, ModelConfig, MoEConfig, SSMConfig
from .shapes import SHAPE_ORDER, SHAPES, InputShape, all_cells, cell_supported

_MODULES = {
    "deepseek-v2-lite-16b": ".deepseek_v2_lite_16b",
    "deepseek-v3-671b": ".deepseek_v3_671b",
    "internvl2-26b": ".internvl2_26b",
    "zamba2-7b": ".zamba2_7b",
    "stablelm-1.6b": ".stablelm_1_6b",
    "chatglm3-6b": ".chatglm3_6b",
    "nemotron-4-340b": ".nemotron_4_340b",
    "gemma-2b": ".gemma_2b",
    "musicgen-medium": ".musicgen_medium",
    "mamba2-1.3b": ".mamba2_1_3b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(_MODULES[name], __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


# Reduced same-family configs for CPU smoke tests (small widths, few layers,
# tiny vocab) — full configs are only exercised via the AOT dry-run.
def smoke_config(name: str) -> ModelConfig:
    from dataclasses import replace
    cfg = get_config(name)
    kw = dict(n_layers=min(cfg.n_layers, 4), d_model=64,
              vocab_size=512, max_seq_len=512)
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) or 1,
                  head_dim=16, d_ff=128)
    if cfg.mla is not None:
        kw["mla"] = replace(cfg.mla, kv_lora_rank=32,
                            q_lora_rank=(48 if cfg.mla.q_lora_rank else None),
                            qk_nope_head_dim=16, qk_rope_head_dim=8,
                            v_head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, n_routed=8, top_k=2, d_expert=32,
                            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
        kw["d_ff"] = 128
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.hybrid is not None:
        kw["hybrid"] = replace(cfg.hybrid, attn_period=2, shared_d_ff=128,
                               shared_n_heads=4, shared_n_kv_heads=4)
        kw["n_layers"] = 4
    return cfg.scaled(**kw)


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "InputShape", "SHAPES", "SHAPE_ORDER", "all_cells", "cell_supported",
    "ARCH_NAMES", "get_config", "all_configs", "smoke_config",
]
