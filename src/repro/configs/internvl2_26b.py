"""InternVL2-26B backbone (InternLM2-20B): 48L GQA kv=8.  ViT frontend is a
stub — input_specs() supplies precomputed patch embeddings.
[arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92_553,
    act="silu", glu=True, rope_theta=1_000_000.0,
    input_mode="embeddings",
    notes="InternViT frontend stubbed; backbone-only per assignment",
)
