"""Mamba2-1.3B: pure SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
)
