"""Model configuration schema for the architecture zoo.

Every assigned architecture is a frozen :class:`ModelConfig`; the generic
decoder stack in ``repro.models.transformer`` is driven entirely by these
fields — there is no per-architecture model code.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int                     # per-expert FFN width
    first_dense_layers: int = 1       # leading layers use a dense FFN
    capacity_factor: float = 1.25
    router_softmax_after_topk: bool = False
    d_shared_expert: Optional[int] = None  # defaults to d_expert * n_shared


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None     # None -> direct q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared attention/MLP blocks cycled between SSM spans."""
    attn_period: int = 6              # one shared block per this many SSM layers
    n_shared_blocks: int = 2          # alternating shared transformer blocks
    shared_d_ff: int = 14336
    shared_n_heads: int = 32
    shared_n_kv_heads: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    act: str = "silu"                  # silu | gelu | relu2
    glu: bool = True                   # gated FFN (SwiGLU / GeGLU)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0         # fraction of head_dim that rotates
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    input_mode: str = "tokens"         # tokens | embeddings (vlm/audio stub)
    n_codebooks: int = 1               # musicgen parallel codebook heads
    max_seq_len: int = 524_288
    mtp_depth: int = 0                 # DeepSeek-V3 multi-token prediction
    notes: str = ""

    # ------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this config decode at 500k context without quadratic cost
        growth / a dense per-layer KV cache?  (SSM state or hybrid.)"""
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, i: int) -> str:
        """'attn' | 'attn_moe' | 'ssm' for layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            return "ssm"               # shared attn blocks are interleaved
        if self.moe is not None and i >= self.moe.first_dense_layers:
            return "attn_moe"
        return "attn"

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced config of the same family (smoke tests)."""
        return replace(self, **kw)

    # Rough parameter counts (for roofline MODEL_FLOPS and memory planning).
    def param_count(self) -> Tuple[int, int]:
        """Returns (total_params, active_params_per_token)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, dh = self.n_heads, self.n_kv_heads, self.resolved_head_dim
        total = V * D * (1 if self.tie_embeddings else 2)
        active = total
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "ssm":
                s = self.ssm
                d_in = s.expand * D
                nheads = d_in // s.head_dim
                in_proj = D * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                blk = in_proj + d_in * D + d_in * 2  # out_proj + norms
                total += blk
                active += blk
            else:
                if self.mla is not None:
                    m = self.mla
                    qdim = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    if m.q_lora_rank:
                        q = D * m.q_lora_rank + m.q_lora_rank * qdim
                    else:
                        q = D * qdim
                    kv = D * (m.kv_lora_rank + m.qk_rope_head_dim) \
                        + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                    attn = q + kv + H * m.v_head_dim * D
                else:
                    attn = D * H * dh + 2 * D * KV * dh + H * dh * D
                total += attn
                active += attn
                if kind == "attn_moe":
                    e = self.moe
                    per_exp = D * e.d_expert * (3 if self.glu else 2)
                    shared_w = e.d_shared_expert or (e.d_expert * e.n_shared)
                    shared = D * shared_w * (3 if self.glu else 2)
                    router = D * e.n_routed
                    total += e.n_routed * per_exp + shared + router
                    active += e.top_k * per_exp + shared + router
                else:
                    ffn = D * F * (3 if self.glu else 2)
                    total += ffn
                    active += ffn
        if self.hybrid is not None:
            h = self.hybrid
            dh_s = D // h.shared_n_heads
            blk = (D * h.shared_n_heads * dh_s * 2
                   + 2 * D * h.shared_n_kv_heads * dh_s
                   + D * h.shared_d_ff * (3 if self.glu else 2))
            total += h.n_shared_blocks * blk
            n_uses = self.n_layers // h.attn_period
            active += n_uses * blk
        return int(total), int(active)
