"""Zamba2-7B: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]"""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32_000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid=HybridConfig(attn_period=6, n_shared_blocks=2, shared_d_ff=14336,
                        shared_n_heads=32, shared_n_kv_heads=32),
    act="silu", glu=True,
    notes="81 Mamba2 layers; 2 alternating shared attn+MLP blocks every 6",
)
