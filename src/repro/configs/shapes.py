"""Assigned input shapes and the (arch x shape) cell matrix.

  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> prefill (serve)
  decode_32k   1 new token, KV len 32768, global_batch 128 -> serve_step
  long_500k    1 new token, KV len 524288, global_batch 1  -> serve_step

``long_500k`` needs sub-quadratic sequence mixing: it runs for SSM/hybrid
archs and is skipped (recorded, not silently dropped) for pure
full-attention archs — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(supported, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k dense KV cache is "
                       "out of spec; see DESIGN.md §Arch-applicability")
    return True, ""


def all_cells(configs: Dict[str, ModelConfig]):
    """Yield (arch, shape, supported, reason) for the full matrix."""
    for arch, cfg in configs.items():
        for sname in SHAPE_ORDER:
            ok, reason = cell_supported(cfg, SHAPES[sname])
            yield arch, sname, ok, reason
