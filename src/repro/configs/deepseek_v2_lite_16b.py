"""DeepSeek-V2-Lite 16B: MLA + MoE (2 shared + 64 routed, top-6).
[arXiv:2405.04434; hf].  The assignment line lists both "64e" and
"160 routed"; the published V2-Lite config is 64 routed (160 is V2-full) —
we follow the leading "64e" spec (see DESIGN.md)."""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,                      # dense FFN width of layer 0
    vocab_size=102_400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                  first_dense_layers=1),
    act="silu", glu=True, rope_theta=10_000.0,
    notes="MLA kv_lora=512; first layer dense FFN",
)
