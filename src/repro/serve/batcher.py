"""Micro-batching request queue (max-batch / max-wait coalescing policy).

Concurrent clients submit payloads; a single worker thread drains the
queue and hands each group to a ``process`` callable in one call.  The
coalescing policy is the classic serving one:

* a batch closes as soon as ``max_batch`` payloads are queued, or
* ``max_wait_s`` after the batch's first payload was enqueued (a head
  that already waited out its budget behind the in-flight batch
  dispatches immediately) — ``max_wait_s=0`` (the default) dispatches
  greedily: whatever is queued the moment the worker frees up forms the
  next batch.  Under concurrent
  load requests pile up behind the in-flight batch, so steady-state
  batches grow to the offered concurrency without any artificial delay,
  and an idle service answers a lone request at pure inference latency.

One worker thread does ALL processing, so ``process`` never runs
concurrently with itself — jitted JAX dispatch stays single-threaded —
and a ``process`` failure is delivered to exactly the tickets of that
batch, never lost.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence


class Ticket:
    """A pending result; ``result()`` blocks until the batch resolves.

    After resolution ``meta`` carries per-request serving telemetry set
    by the worker (``queue_wait_s`` — seconds from enqueue to batch
    dispatch — and ``batch_size`` — how many requests shared the batch).
    """

    __slots__ = ("payload", "enqueued_at", "meta", "_event", "_result",
                 "_error")

    def __init__(self, payload: Any):
        self.payload = payload
        self.enqueued_at = time.monotonic()
        self.meta: Optional[Dict[str, Any]] = None
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None

    def _resolve(self, result: Any) -> None:
        self._result = result
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("decision request timed out")
        if self._error is not None:
            raise self._error
        return self._result


class MicroBatcher:
    """Coalesce concurrent submissions into batched ``process`` calls."""

    def __init__(self, process: Callable[[List[Any]], Sequence[Any]],
                 max_batch: int = 16, max_wait_s: float = 0.0,
                 on_batch: Optional[Callable[[int, List[float], int],
                                             None]] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self._process = process
        # Telemetry observer, called from the worker thread after every
        # successful batch: on_batch(batch_size, per_request_waits_s,
        # queue_depth_after_take).  Failures are swallowed so a broken
        # metrics sink can never take serving down.
        self._on_batch = on_batch
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: List[Ticket] = []
        self._thread: Optional[threading.Thread] = None
        self._running = False
        # -- instrumentation (read under the lock or after stop())
        self.batches = 0
        self.requests = 0
        self.batch_hist: Dict[int, int] = {}   # batch size -> count

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MicroBatcher":
        with self._lock:
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="mrsch-microbatcher")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop accepting work; the worker drains what is already queued."""
        with self._cond:
            if not self._running:
                return
            self._running = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ submit
    def submit(self, payload: Any) -> Ticket:
        ticket = Ticket(payload)
        with self._cond:
            if not self._running:
                raise RuntimeError("batcher is not running (call start())")
            self._queue.append(ticket)
            self._cond.notify()
        return ticket

    # ------------------------------------------------------------ worker
    def _take_batch(self) -> Optional[List[Ticket]]:
        """Block for the next batch; None once stopped and drained."""
        with self._cond:
            while self._running and not self._queue:
                self._cond.wait()
            if not self._queue:
                return None                     # stopped and drained
            if self.max_wait_s > 0:
                # Deadline anchors at the FIRST payload's enqueue: a batch
                # whose head already queued behind the in-flight batch for
                # max_wait is ripe and dispatches immediately, instead of
                # paying a second wait from worker pickup.
                deadline = self._queue[0].enqueued_at + self.max_wait_s
                while (self._running and len(self._queue) < self.max_batch):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
            batch = self._queue[: self.max_batch]
            del self._queue[: len(batch)]
            return batch

    def _loop(self) -> None:
        while (batch := self._take_batch()) is not None:
            dispatched = time.monotonic()
            waits = [max(0.0, dispatched - t.enqueued_at) for t in batch]
            try:
                results = self._process([t.payload for t in batch])
                if len(results) != len(batch):
                    raise RuntimeError(
                        f"process returned {len(results)} results for a "
                        f"batch of {len(batch)}")
            except BaseException as e:          # delivered, never lost
                for t in batch:
                    t._fail(e)
                continue
            with self._lock:
                self.batches += 1
                self.requests += len(batch)
                self.batch_hist[len(batch)] = \
                    self.batch_hist.get(len(batch), 0) + 1
                depth = len(self._queue)
            n = len(batch)
            for t, r, w in zip(batch, results, waits):
                t.meta = {"queue_wait_s": w, "batch_size": n}
                t._resolve(r)
            if self._on_batch is not None:
                try:
                    self._on_batch(n, waits, depth)
                except Exception:
                    pass

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, object]:
        with self._lock:
            hist = dict(sorted(self.batch_hist.items()))
            batches, requests = self.batches, self.requests
        return {
            "batches": batches,
            "requests": requests,
            "mean_batch": round(requests / batches, 3) if batches else 0.0,
            "max_batch_seen": max(hist) if hist else 0,
            "batch_hist": hist,
        }
