"""The online scheduling-decision service (ROADMAP: serve heavy traffic).

``DecisionService`` answers concurrent scheduling-decision requests —
cluster state + queue snapshot as a ``SchedContext``, plus an optional
per-request goal-vector override — with the trained DFP policy:

    client threads                 worker thread (one, owns all JAX calls)
    submit(ctx [, goal])  ──►  MicroBatcher (max-batch / max-wait)
      encode row                   │  stack rows, pad to shape bucket
      [state|meas|goal|valid]      ▼
                               greedy_actions_packed(params, dfp, packed)
      ticket.result() ◄──      one jitted forward per batch

Requests are encoded in the *client* thread (numpy, cheap) so the worker
does nothing but stack, pad, and dispatch; padding goes to a fixed set
of power-of-two bucket widths (``buckets.BucketCache``) so steady-state
serving never retraces, whatever batch widths the traffic produces.

Parameters hot-swap atomically (``update_params``, driven by
``reload.CheckpointWatcher``): the worker snapshots the param reference
once per batch, so in-flight batches finish on the old tree while every
later batch sees the new one — zero-downtime policy updates.  The swap
validates the incoming tree against the service's template
(``checkpoint.check_leaves_compat``), so a checkpoint from a different
architecture is rejected and serving continues on the current params.

The decision function is pure (greedy, no exploration, no recorder
writes), so answers are bit-identical to ``MRSchAgent.select`` in
evaluation mode on the same context — ``replay.ServiceSim`` pins that.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..checkpoint import check_leaves_compat
from ..core.dfp import greedy_actions_packed
from ..core.encoding import (decision_row_dim, encode_decision_row,
                             pad_decision_rows)
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL, Tracer
from ..sim.simulator import SchedContext
from .batcher import MicroBatcher, Ticket
from .buckets import BucketCache


@dataclass(frozen=True)
class ServeConfig:
    """Knobs of the decision service.

    ``max_wait_s=0`` dispatches greedily (an idle service answers a lone
    request at pure inference latency; concurrent load coalesces behind
    the in-flight batch); raise it to trade a bounded wait for fuller
    batches.  ``warmup`` pre-traces every bucket width at ``start()`` so
    the first real request never pays a compile stall.
    """
    max_batch: int = 16
    max_wait_s: float = 0.0
    warmup: bool = True
    timeout_s: float = 120.0          # decide()/decide_many() wait bound


@dataclass(frozen=True)
class DecisionResponse:
    """A decision plus its per-request serving telemetry.

    ``queue_wait_s`` — seconds the request sat queued before its batch
    dispatched; ``batch_size`` — how many requests shared the batch;
    ``width`` — the padded bucket width the batch dispatched at.
    """
    action: int
    queue_wait_s: float
    batch_size: int
    width: int


class DecisionService:
    """Micro-batched greedy DFP inference with hot-reloadable params.

    ``registry`` (a ``repro.obs.MetricsRegistry``) receives serving
    telemetry — request/batch/reload counters, queue-depth and
    bucket-hit-rate gauges, batch-size and queue-wait histograms.
    ``tracer`` receives ``serve.dispatch`` and ``ckpt.reload``
    ``mrsch.trace/v1`` events.  Both default to no-ops.
    """

    def __init__(self, agent, config: ServeConfig = ServeConfig(), *,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Tracer = NULL):
        self.config = config
        self.registry = registry
        self.tracer = tracer
        self.enc = agent.enc
        self.dfp = agent.dfp
        self.n_actions = agent.config.window
        self._params = agent.params          # snapshot ref, swapped atomically
        self._params_step: Optional[int] = None
        self._reloads = 0
        self._reload_lock = threading.Lock()
        self._buckets = BucketCache(config.max_batch)
        self._batcher = MicroBatcher(self._process,
                                     max_batch=config.max_batch,
                                     max_wait_s=config.max_wait_s,
                                     on_batch=self._on_batch)
        self._row_dim = decision_row_dim(self.enc, self.n_actions)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "DecisionService":
        self._batcher.start()
        if self.config.warmup:
            self.warmup()
        return self

    def stop(self) -> None:
        self._batcher.stop()

    def __enter__(self) -> "DecisionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self) -> None:
        """Pre-trace the jitted forward at every bucket width."""
        empty = np.zeros((0, self._row_dim), dtype=np.float32)
        for w in self._buckets.widths:
            packed = pad_decision_rows(empty, w, self.enc)
            self._buckets.record(packed.shape[0])
            np.asarray(greedy_actions_packed(self._params, self.dfp, packed))

    # ------------------------------------------------------------ requests
    def _encode(self, ctx: SchedContext,
                goal: Optional[np.ndarray] = None) -> np.ndarray:
        """One packed decision row (layout: encoding.encode_decision_row)."""
        m = self.enc.n_resources
        if goal is not None:
            goal = np.asarray(goal, dtype=np.float32)
            if goal.shape != (m,):
                raise ValueError(
                    f"goal override must have shape ({m},) — one weight per "
                    f"resource {tuple(self.enc.resource_names)} — got "
                    f"{goal.shape}")
        row = np.zeros(self._row_dim, dtype=np.float32)
        encode_decision_row(self.enc, ctx, self.n_actions, out=row, goal=goal)
        return row

    def submit(self, ctx: SchedContext,
               goal: Optional[np.ndarray] = None) -> Ticket:
        """Enqueue one decision request; returns a ``Ticket`` whose
        ``result()`` is the selected window index."""
        return self._batcher.submit(self._encode(ctx, goal))

    def decide(self, ctx: SchedContext,
               goal: Optional[np.ndarray] = None) -> int:
        """Blocking single decision (submit + wait)."""
        return self.submit(ctx, goal).result(self.config.timeout_s)

    def decide_full(self, ctx: SchedContext,
                    goal: Optional[np.ndarray] = None) -> DecisionResponse:
        """Blocking decision carrying per-request serving telemetry."""
        ticket = self.submit(ctx, goal)
        action = int(ticket.result(self.config.timeout_s))
        meta = ticket.meta or {}
        batch_size = int(meta.get("batch_size", 1))
        return DecisionResponse(
            action=action,
            queue_wait_s=float(meta.get("queue_wait_s", 0.0)),
            batch_size=batch_size,
            width=self._buckets.width_for(batch_size))

    def decide_many(self, ctxs: Sequence[SchedContext],
                    goals: Optional[Sequence] = None) -> np.ndarray:
        """Submit a group of requests, then wait for all of them."""
        if goals is None:
            goals = [None] * len(ctxs)
        elif len(goals) != len(ctxs):
            raise ValueError(f"decide_many: {len(ctxs)} contexts but "
                             f"{len(goals)} goals")
        tickets = [self.submit(c, g) for c, g in zip(ctxs, goals)]
        return np.asarray([t.result(self.config.timeout_s) for t in tickets],
                          dtype=np.int32)

    # ------------------------------------------------------------ inference
    def _process(self, rows: List[np.ndarray]) -> List[int]:
        # One reference read: the whole batch scores on one param tree,
        # however many hot-reloads land while it is in flight.
        params = self._params
        n = len(rows)
        width = self._buckets.width_for(n)
        packed = pad_decision_rows(np.asarray(rows, dtype=np.float32), width,
                                   self.enc)
        # Account the shape actually dispatched (not the computed bucket),
        # so broken/bypassed padding shows up as retraces in the stats and
        # fails the no-retrace test + CI gate instead of hiding.
        self._buckets.record(packed.shape[0])
        acts = np.asarray(greedy_actions_packed(params, self.dfp, packed))
        return [int(x) for x in acts[:n]]

    def _on_batch(self, n: int, waits: List[float], depth: int) -> None:
        """Worker-thread telemetry hook (see MicroBatcher.on_batch)."""
        width = self._buckets.width_for(n)
        self.tracer.dispatch(n, width, max(waits) if waits else 0.0)
        reg = self.registry
        if reg is None:
            return
        reg.counter("serve_requests_total").inc(n)
        reg.counter("serve_batches_total").inc()
        reg.counter("serve_batch_rows_total", {"width": width}).inc(n)
        reg.gauge("serve_queue_depth").set(depth)
        reg.histogram("serve_batch_size",
                      buckets=self._buckets.widths).observe(n)
        wait_hist = reg.histogram("serve_queue_wait_seconds")
        for w in waits:
            wait_hist.observe(w)
        b = self._buckets.stats()
        hit = (b["bucket_hits"] / b["dispatches"]) if b["dispatches"] else 0.0
        reg.gauge("serve_bucket_hit_rate").set(hit)

    # ------------------------------------------------------------ hot reload
    @property
    def params(self):
        """The currently served parameter tree (swap via update_params)."""
        return self._params

    @property
    def params_step(self) -> Optional[int]:
        return self._params_step

    def update_params(self, params, step: Optional[int] = None) -> None:
        """Atomically swap the served parameters (zero-downtime reload).

        The incoming tree must match the service's current tree leaf for
        leaf (count/shape/dtype) and in structure; an incompatible tree
        raises ``ValueError`` and the service keeps serving the current
        parameters.  In-flight batches finish on the tree they snapshot;
        every batch formed after the swap scores on the new one.
        """
        old_flat, old_def = jax.tree_util.tree_flatten(self._params)
        new_flat, new_def = jax.tree_util.tree_flatten(params)
        if new_def != old_def:
            raise ValueError(
                f"update_params: incompatible tree structure — got "
                f"{new_def}, expected {old_def}")
        check_leaves_compat(old_flat, new_flat, context="update_params")
        with self._reload_lock:
            self._params = params            # atomic reference swap
            self._params_step = step
            self._reloads += 1
        self.tracer.ckpt_reload(step if step is not None else -1)
        if self.registry is not None:
            self.registry.counter("serve_reloads_total").inc()

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, object]:
        with self._reload_lock:
            reloads, step = self._reloads, self._params_step
        return {
            **self._batcher.stats(),
            "buckets": self._buckets.stats(),
            "reloads": reloads,
            "params_step": step,
        }
