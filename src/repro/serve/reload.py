"""Checkpoint hot-reload: watch a ``CheckpointManager`` directory and
atomically swap the served parameters (zero-downtime policy updates).

The watcher leans on the store's atomicity guarantees: ``save_pytree``
commits via write-to-``.tmp`` + rename, so ``latest_step`` never names a
half-written checkpoint, and a step GC'd between listing and reading is
retried on the next poll instead of killing the watcher.  A checkpoint
that restores but does not match the service's parameter tree (a
different architecture dropped into the watched directory) is rejected
by ``DecisionService.update_params`` — the incident is recorded and the
service keeps serving the parameters it has.

``check_once`` is the synchronous single poll (deterministic tests, or
callers with their own scheduler); ``start``/``stop`` run it on a
background thread every ``poll_interval_s``.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from ..checkpoint import latest_step, restore_pytree
from .service import DecisionService


class CheckpointWatcher:
    """Poll a checkpoint directory; hot-swap new steps into a service."""

    def __init__(self, service: DecisionService, directory: str,
                 poll_interval_s: float = 1.0):
        self.service = service
        self.directory = directory
        self.poll_interval_s = float(poll_interval_s)
        self._loaded: Optional[int] = service.params_step
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._rejected = 0
        self._errors = 0

    # ------------------------------------------------------------ one poll
    def check_once(self) -> Optional[int]:
        """Load and swap in the newest unseen step; None when current.

        Never raises on transient store races (checkpoint GC'd mid-read);
        an incompatible checkpoint is counted as rejected and skipped —
        ``check_once`` will not retry it until a newer step appears.
        """
        step = None
        try:
            step = latest_step(self.directory)
            if step is None or (self._loaded is not None
                                and step <= self._loaded):
                return None
            params, _manifest = restore_pytree(self.service.params,
                                               self.directory, step)
            self.service.update_params(params, step=step)
        except OSError:
            with self._lock:
                self._errors += 1        # racing the store's GC; next poll
            return None
        except (ValueError, KeyError):
            # Wrong architecture — or a stray step_* entry breaking the
            # directory listing itself (step is still None then).
            with self._lock:
                self._rejected += 1
            if step is not None:
                self._loaded = step      # don't re-reject every poll
            return None
        self._loaded = step
        return step

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "CheckpointWatcher":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.poll_interval_s):
                try:
                    self.check_once()
                except Exception:        # never let a poll kill the watcher
                    with self._lock:
                        self._errors += 1

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="mrsch-ckpt-watcher")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "CheckpointWatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"loaded_step": self._loaded, "rejected": self._rejected,
                    "transient_errors": self._errors}
