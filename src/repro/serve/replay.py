"""Replay through the service: the determinism bridge to the simulator.

``ServicePolicy`` is a ``SchedulingPolicy`` facade whose ``select`` /
``select_batch`` route every decision through a ``DecisionService``, so
the existing ``Simulator`` and ``VectorSimulator`` machinery — and every
harness built on them — can be driven end-to-end through the serving
stack.  The service's decision function is the same packed greedy
forward the agent uses, so a service-routed replay produces
``ScheduleMetrics`` bit-identical to direct ``agent.select`` replay on
the same trace (pinned in ``tests/test_serve.py``): the serving layer
adds concurrency and batching, never different decisions.

``ServiceSim`` bundles the cluster spec + the shared
``SimConfig.for_engine`` plumbing (the same constructor the
sweep/drift/matrix harnesses use) into one replay entry point for
traces and registry scenarios.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

from ..sim.cluster import ResourceSpec
from ..sim.job import Job
from ..sim.simulator import SchedContext, SimConfig, SimResult, Simulator
from ..sim.vector import VectorSimulator
from .service import DecisionService


class ServicePolicy:
    """Route a scheduling policy's decisions through a DecisionService.

    A ``repro.core.Policy`` whose device-resident stages are absent
    (``init_state``/``score_window`` are ``None``): decisions go through
    a live service, so only the host engines can drive it —
    ``supports_device`` is False by construction and ``DeviceSimulator``
    rejects it with a clear error instead of tracing a network hop.

    With ``track_latency=True`` every ``select`` records its end-to-end
    request latency (seconds) into ``latencies_s`` — the example/bench
    histogram source.  ``select_batch`` submits the whole group before
    waiting, so a lockstep round's requests coalesce in the batcher.
    """

    init_state = None
    score_window = None

    def __init__(self, service: DecisionService, track_latency: bool = False):
        self.service = service
        self.track_latency = track_latency
        self.latencies_s: List[float] = []

    def select(self, ctx: SchedContext) -> int:
        if not self.track_latency:
            return self.service.decide(ctx)
        t0 = time.perf_counter()
        action = self.service.decide(ctx)
        self.latencies_s.append(time.perf_counter() - t0)
        return action

    def select_batch(self, ctxs: Sequence[SchedContext]) -> np.ndarray:
        t0 = time.perf_counter()
        actions = self.service.decide_many(ctxs)
        if self.track_latency:
            dt = time.perf_counter() - t0
            self.latencies_s.extend([dt] * len(ctxs))
        return actions


class ServiceSim:
    """Drive the simulator(s) through a running decision service."""

    def __init__(self, service: DecisionService,
                 resources: Sequence[ResourceSpec], window: int = 10,
                 backfill: bool = True, track_latency: bool = False):
        self.service = service
        self.resources = list(resources)
        self.sim_cfg = SimConfig.for_engine("vector", window=window,
                                            backfill=backfill)
        self.policy = ServicePolicy(service, track_latency=track_latency)

    def run_trace(self, jobs: Sequence[Job]) -> SimResult:
        """Sequential replay of one trace, every decision served."""
        return Simulator(self.resources, jobs, self.policy,
                         self.sim_cfg).run()

    def run_traces(self, jobsets: Sequence[Sequence[Job]]) -> List[SimResult]:
        """Lockstep replay of N traces; each round's decisions coalesce
        into (at most) one service batch."""
        vec = VectorSimulator.from_jobsets(self.resources, jobsets,
                                           self.policy, self.sim_cfg)
        return vec.run()

    def run_scenario(self, name: str, theta, seed: int = 1,
                     **overrides) -> SimResult:
        """Replay one registry scenario through the service."""
        from ..workloads.registry import build_jobs
        return self.run_trace(build_jobs(name, theta, seed=seed, **overrides))

    @property
    def latencies_s(self) -> List[float]:
        return self.policy.latencies_s
