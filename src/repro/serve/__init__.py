"""Online scheduling-decision service: micro-batched DFP inference with
hot-reloadable checkpoints.  See docs/serving.md."""
from .batcher import MicroBatcher, Ticket
from .buckets import BucketCache, bucket_widths
from .reload import CheckpointWatcher
from .replay import ServicePolicy, ServiceSim
from .service import DecisionResponse, DecisionService, ServeConfig

__all__ = [
    "MicroBatcher", "Ticket", "BucketCache", "bucket_widths",
    "CheckpointWatcher", "ServicePolicy", "ServiceSim",
    "DecisionResponse", "DecisionService", "ServeConfig",
]
