"""Shape-bucket compile cache for padded batched inference.

``greedy_actions_packed`` is jitted with the DFP config static, so XLA
retraces once per distinct input *shape*.  A serving workload offers an
arbitrary mix of batch widths; padding every batch up to one of a small
fixed set of bucket widths (powers of two up to ``max_batch``) keeps the
jit cache finite — after one pass over the buckets (or an explicit
``warmup``) steady-state serving never retraces, whatever widths the
micro-batcher produces.

The cache tracks which widths have been dispatched, so the service can
report compile events vs. bucket hits and tests can pin the no-retrace
property without reaching into JAX internals.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Tuple


def bucket_widths(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to (and including) the padded ``max_batch``."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    out: List[int] = []
    w = 1
    while w < max_batch:
        out.append(w)
        w <<= 1
    out.append(w)                  # smallest power of two >= max_batch
    return tuple(out)


class BucketCache:
    """Pick padded widths and account for compile-cache behaviour."""

    def __init__(self, max_batch: int):
        self.widths = bucket_widths(max_batch)
        self._lock = threading.Lock()
        self._seen: Dict[int, int] = {}     # width -> dispatch count

    def width_for(self, n: int) -> int:
        """Smallest bucket that fits ``n`` rows."""
        if n < 1:
            raise ValueError(f"batch must have >= 1 rows, got {n}")
        for w in self.widths:
            if n <= w:
                return w
        raise ValueError(
            f"batch of {n} exceeds the largest bucket {self.widths[-1]}")

    def record(self, width: int) -> bool:
        """Account one dispatch at ``width``; True when it is the first
        (i.e. the jitted callee traces/compiles for this shape)."""
        with self._lock:
            first = width not in self._seen
            self._seen[width] = self._seen.get(width, 0) + 1
            return first

    def stats(self) -> Dict[str, object]:
        with self._lock:
            seen = dict(sorted(self._seen.items()))
        dispatches = sum(seen.values())
        return {
            "buckets": list(self.widths),
            "compiled_widths": list(seen),
            "compiles": len(seen),
            "dispatches": dispatches,
            "bucket_hits": dispatches - len(seen),
        }
