"""Compiled-HLO analysis: collective traffic + roofline terms.

``collective_stats`` parses a compiled module's text and models per-device
wire bytes per collective (documented, simple ring models):

  all-gather        S_result * (n-1)/n      received per device
  reduce-scatter    S_operand * (n-1)/n
  all-reduce        2 * S * (n-1)/n         (ring RS + AG)
  all-to-all        S * (n-1)/n
  collective-permute S                      (one hop)

where n = participants per replica group.  Sizes come from the printed
shapes; scan bodies appear once in the text, so the dry-run takes its
collective totals from the unrolled L=1/L=2 extrapolation lowers (exact),
and full-depth compiles are used for memory analysis only.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64)\[([\d,]*)\]")
# XLA:CPU legalizes bf16 arithmetic to f32, so compiled-module shapes show
# f32 where the TPU program carries bf16.  For the TPU roofline we count
# floating-point collective payloads at 2 bytes/element ("bf16-adjusted");
# raw CPU bytes are kept alongside for transparency.
_DTYPE_BYTES_BF16ADJ = dict(_DTYPE_BYTES, f32=2, f64=2)
_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str, bf16_adjusted: bool = False) -> int:
    table = _DTYPE_BYTES_BF16ADJ if bf16_adjusted else _DTYPE_BYTES
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * table.get(dt, 4)
    return total


@dataclass
class CollectiveStats:
    ops: List[dict] = field(default_factory=list)

    @property
    def wire_bytes(self) -> float:
        return sum(o["wire_bytes"] for o in self.ops)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o["kind"]] = out.get(o["kind"], 0.0) + o["wire_bytes"]
        return out

    def count(self) -> int:
        return len(self.ops)


def collective_stats(hlo_text: str, bf16_adjusted: bool = True
                     ) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = re.search(
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", line)
        if not m or "=" not in line:
            continue
        if m.group(2) == "-done":
            continue                          # counted at -start
        kind = m.group(1)
        # Result type sits between '=' and the op name:
        #   %ag = bf16[16,2048]{...} all-gather(bf16[1,2048] %x), ...
        eq = line.index("=")
        result_bytes = _shape_bytes(line[eq + 1: m.start(1)], bf16_adjusted)
        # group size
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire = 2 * result_bytes * frac
        elif kind == "reduce-scatter":
            wire = result_bytes * n * frac    # operand = result * n
        elif kind == "collective-permute":
            wire = result_bytes
        else:                                  # all-gather / all-to-all
            wire = result_bytes * frac
        # result printed is the GLOBAL logical shape in SPMD modules;
        # per-device share:
        stats.ops.append({"kind": kind, "bytes": result_bytes,
                          "group": n, "wire_bytes": wire})
    return stats


@dataclass(frozen=True)
class Hardware:
    """TPU v5e-class target (per chip)."""
    peak_bf16_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9               # per link
    hbm_gb: float = 16.0


V5E = Hardware()


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float, hw: Hardware = V5E
                   ) -> Dict[str, float]:
    t_c = flops_per_device / hw.peak_bf16_flops
    t_m = bytes_per_device / hw.hbm_bw
    t_n = wire_bytes_per_device / hw.ici_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    bound = max(t_c, t_m, t_n)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom[0],
        "roofline_fraction": t_c / bound if bound > 0 else 0.0,
    }
