"""Logical-axis sharding rules.

Models annotate activations with *logical* axes (``shard(x, "batch", None,
"heads", None)``); parameters get PartitionSpecs from :func:`param_pspec`.
The mapping logical axis -> mesh axes lives in one place (:class:`Rules`)
and is installed with :func:`use_rules`, so swapping a sharding strategy is
a one-object change (this is the lever most §Perf iterations pull).

Divisibility is respected automatically: a logical axis only maps to a mesh
axis when the dimension divides the mesh-axis size (e.g. gemma-2b's 8 query
heads stay unsharded on a model=16 mesh instead of failing to lower).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class Rules:
    """Logical axis -> mesh axis (or tuple for combined axes)."""
    mapping: Dict[str, MeshAxes] = field(default_factory=dict)
    mesh: Optional[Mesh] = None

    def resolve(self, logical: Optional[str], dim: Optional[int] = None) -> MeshAxes:
        if logical is None or self.mesh is None:
            return None
        axes = self.mapping.get(logical)
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        # Keep the largest prefix of mesh axes that divides the dim.
        if dim is not None:
            total = 1
            kept = []
            for a in axes:
                n = self.mesh.shape[a]
                if dim % (total * n) == 0:
                    kept.append(a)
                    total *= n
                else:
                    break
            axes = tuple(kept)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axes to a PartitionSpec.  A mesh axis may appear
        on at most one dim; when two logical axes resolve to the same mesh
        axis (e.g. act_seq and vocab both -> model), the leftmost wins."""
        dims = shape if shape is not None else [None] * len(logical_axes)
        used = set()
        out = []
        for ax, d in zip(logical_axes, dims):
            r = self.resolve(ax, d)
            axes = (r,) if isinstance(r, str) else (r or ())
            kept = tuple(a for a in axes if a not in used)
            used.update(kept)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)


def default_rules(mesh: Mesh) -> Rules:
    """Baseline strategy: batch over (pod, data); fsdp param shard over
    data; tensor parallel (heads / mlp / experts / vocab) over model."""
    axes = dict(
        batch=("pod", "data") if "pod" in mesh.axis_names else ("data",),
        fsdp=("data",),
        heads=("model",),
        kv_heads=("model",),
        mlp=("model",),
        experts=("model",),
        vocab=("model",),
        seq=None,
        embed=None,
        act_seq=None,       # residual-stream S stays unsharded (baseline)
        kv_seq=None,        # decode caches replicated over model (baseline)
    )
    return Rules(mapping=axes, mesh=mesh)


def optimized_rules(mesh: Mesh) -> Rules:
    """§Perf strategy: baseline + sequence parallelism (residual stream S
    sharded over model — shrinks remat saves 16x and turns the per-layer
    2xAllReduce into ReduceScatter+AllGather) + decode KV caches sharded
    over model along the sequence axis."""
    base = default_rules(mesh)
    mapping = dict(base.mapping)
    mapping.update(act_seq=("model",), kv_seq=("model",))
    return Rules(mapping=mapping, mesh=mesh)


def serve_rules(mesh: Mesh) -> Rules:
    """Inference strategy: weights are *resident*, never fsdp-gathered —
    experts shard over (model x data) (e.g. one of DeepSeek-V3's 256
    experts per chip on a 256-chip pod), dense/attention weights over
    model only; decode caches shard their sequence axis over model."""
    base = default_rules(mesh)
    mapping = dict(base.mapping)
    mapping.update(fsdp=None, experts=("model", "data"),
                   act_seq=("model",), kv_seq=("model",))
    return Rules(mapping=mapping, mesh=mesh)


RULE_SETS = {"baseline": default_rules, "opt": optimized_rules,
             "serve": serve_rules}


def tp_row_matmul(h, w, out_shard_axes=("batch", "act_seq", None)):
    """Row-parallel TP matmul with an explicit reduce-scatter epilogue.

    h (B, S, F) with F sharded over "model"; w (F, D) with rows sharded
    over "model".  Computes the local partial product and finishes with
    ``psum_scatter`` over the sequence — the Megatron-SP schedule.  GSPMD
    on XLA:CPU emits AllReduce(+slice) here (the AR->ReduceScatter pass is
    TPU-only), which doubles wire bytes; shard_map pins the collective.

    Falls back to a plain matmul when no suitable rules/mesh are active.
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return h @ w
    mesh = rules.mesh
    if "model" not in mesh.shape:
        return h @ w
    n_model = mesh.shape["model"]
    B, S, F = h.shape
    D = w.shape[-1]
    seq_axes = rules.mapping.get("act_seq")
    if (seq_axes != ("model",) or S % n_model or F % n_model
            or w.shape[0] != F):
        return h @ w
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if B % n_batch:
        batch_axes, n_batch = (), 1

    def body(h_loc, w_loc):
        partial = h_loc @ w_loc                       # (B_loc, S, D)
        return jax.lax.psum_scatter(partial, "model", scatter_dimension=1,
                                    tiled=True)       # (B_loc, S/16, D)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes or None, None, "model"), P("model", None)),
        out_specs=P(batch_axes or None, "model", None),
        check_vma=False,
    )(h, w)


_state = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextmanager
def use_rules(rules: Optional[Rules]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x, *logical_axes):
    """Constrain an activation's sharding by logical axes (no-op when no
    rules are installed, e.g. single-device smoke tests)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return None
    return NamedSharding(rules.mesh, rules.spec(logical_axes, shape))


# ---------------------------------------------------------------- params
# Parameter logical axes are declared per path fragment; ``param_pspecs``
# walks a pytree of ShapeDtypeStructs and returns matching PartitionSpecs.
PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # name fragment -> logical axes per dim (excluding a stacked L prefix)
    "embed/table": ("vocab", "fsdp"),
    "lm_head/w": ("fsdp", "vocab"),
    "attn/wq": ("fsdp", "heads"),
    "attn/wk": ("fsdp", "kv_heads"),
    "attn/wv": ("fsdp", "kv_heads"),
    "attn/wo": ("heads", "fsdp"),
    "mla/w_dq": ("fsdp", None),
    "mla/w_uq": (None, "heads"),
    "mla/w_dkv": ("fsdp", None),
    "mla/w_uk": (None, "heads"),
    "mla/w_uv": (None, "heads"),
    "mla/wo": ("heads", "fsdp"),
    "mlp/w_gate": ("fsdp", "mlp"),
    "mlp/w_up": ("fsdp", "mlp"),
    "mlp/w_down": ("mlp", "fsdp"),
    "moe/router": ("fsdp", None),
    "moe/w_gate": ("experts", "fsdp", None),
    "moe/w_up": ("experts", "fsdp", None),
    "moe/w_down": ("experts", None, "fsdp"),
    "shared/w_gate": ("fsdp", "mlp"),
    "shared/w_up": ("fsdp", "mlp"),
    "shared/w_down": ("mlp", "fsdp"),
    "ssm/w_x": ("fsdp", "heads"),
    "ssm/w_z": ("fsdp", "heads"),
    "ssm/w_B": ("fsdp", None),
    "ssm/w_C": ("fsdp", None),
    "ssm/w_dt": ("fsdp", None),
    "ssm/conv": (None, "heads"),
    "ssm/out_proj": ("heads", "fsdp"),
    "ssm/A_log": (None,),
    "ssm/D": (None,),
    "ssm/dt_bias": (None,),
    "norm/scale": (None,),
    "scale": (None,),
}


def _match_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    best = None
    for frag, axes in PARAM_AXES.items():
        if path.endswith(frag) or f"/{frag}" in path or frag in path:
            if best is None or len(frag) > len(best[0]):
                best = (frag, axes)
    if best is None:
        return (None,) * ndim
    axes = best[1]
    if len(axes) < ndim:                       # stacked layer prefix dims
        axes = (None,) * (ndim - len(axes)) + tuple(axes)
    return axes[:ndim]


def param_pspecs(params, rules: Rules):
    """Pytree of PartitionSpecs matching ``params`` (ShapeDtypeStructs or
    arrays) under ``rules``."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for path, leaf in flat:
        spath = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        axes = _match_axes(spath, leaf.ndim)
        specs.append(rules.spec(axes, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, rules: Rules):
    specs = param_pspecs(params, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
