"""Analytic cost model per (arch x shape) cell.

Two uses:
  1. MODEL_FLOPS for the roofline's usefulness ratio (6*N*D dense /
     6*N_active*D MoE for training; 2*N_active per generated token for
     inference) plus exact attention/SSD terms.
  2. Corrections for HLO undercounting: the long-context prefill path runs
     flash attention as a ``lax.scan`` over KV blocks whose body XLA:CPU
     cost analysis counts once; ``flash_correction`` returns the missing
     (n_blocks - 1) x body flops/bytes so corrected HLO totals are exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape

FLASH_BLOCK_K = 1024
DENSE_ATTN_THRESHOLD = 2048


@dataclass(frozen=True)
class CellCosts:
    model_flops_global: float        # useful flops, whole step, all chips
    attn_flops_global: float         # quadratic/SSD part included above
    param_bytes: float               # bf16 params
    notes: str = ""


def _attn_flops_per_layer(cfg: ModelConfig, B: int, S: int, causal=True):
    """QK^T + PV flops for one full-attention layer (causal halves it)."""
    if cfg.mla is not None:
        dh_qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dh_v = cfg.mla.v_head_dim
        H = cfg.n_heads
    else:
        dh_qk = dh_v = cfg.resolved_head_dim
        H = cfg.n_heads
    full = 2 * B * H * S * S * (dh_qk + dh_v)
    return full / 2 if causal else full


def _ssd_flops_per_layer(cfg: ModelConfig, B: int, S: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    P, N, Q = s.head_dim, s.d_state, s.chunk
    nc = S // Q
    intra = 2 * B * nc * Q * Q * H * (N + P) / 2        # causal-ish half
    states = 2 * B * nc * Q * H * N * P                 # chunk states
    inter = 2 * B * nc * Q * H * N * P                  # C . H_prev
    return intra + states + inter


def cell_costs(cfg: ModelConfig, shape: InputShape) -> CellCosts:
    B, S = shape.global_batch, shape.seq_len
    total, active = cfg.param_count()
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * active * tokens
        mult = 3.0                                      # fwd+bwd on attn too
        S_eff = S
    elif shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * active * tokens
        mult = 1.0
        S_eff = S
    else:  # decode: one token against an S-long cache
        tokens = B * 1
        base = 2.0 * active * tokens
        mult = 1.0
        S_eff = S
    attn = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "ssm":
            if shape.kind == "decode":
                s = cfg.ssm
                d_in = s.expand * cfg.d_model
                H = d_in // s.head_dim
                attn += 4.0 * B * H * s.head_dim * s.d_state
            else:
                attn += _ssd_flops_per_layer(cfg, B, S_eff) * mult
        else:
            if shape.kind == "decode":
                # one query row against the cache
                if cfg.mla is not None:
                    d_eff = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                    attn += 2.0 * B * cfg.n_heads * S_eff * (
                        d_eff + cfg.mla.kv_lora_rank)
                else:
                    attn += 2.0 * B * cfg.n_heads * S_eff * \
                        2 * cfg.resolved_head_dim
            else:
                attn += _attn_flops_per_layer(cfg, B, S_eff) * mult
    if cfg.hybrid is not None:
        n_inv = cfg.n_layers // cfg.hybrid.attn_period
        for _ in range(n_inv):
            if shape.kind == "decode":
                dh = cfg.d_model // cfg.hybrid.shared_n_heads
                attn += 2.0 * B * cfg.hybrid.shared_n_heads * S_eff * 2 * dh
            else:
                attn += _attn_flops_per_layer(cfg, B, S_eff) * mult
    return CellCosts(
        model_flops_global=base + attn,
        attn_flops_global=attn,
        param_bytes=2.0 * total,
    )


def flash_correction(cfg: ModelConfig, shape: InputShape,
                     block_k: int = FLASH_BLOCK_K) -> Dict[str, float]:
    """Missing (global) flops/bytes when the scan-flash path lowers.

    Applies only to full-attention layers with S > DENSE_ATTN_THRESHOLD in
    train/prefill cells.  The scan body does attention of all S queries
    against one KV block; HLO counts it once; true count is n_blocks.
    Bytes are modeled kernel-ideally (q, k, v, o single pass) because the
    TPU execution path is the Pallas flash kernel.
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode" or S <= DENSE_ATTN_THRESHOLD:
        return {"flops": 0.0, "bytes": 0.0}
    n_layers_attn = sum(
        1 for i in range(cfg.n_layers) if cfg.layer_kind(i) != "ssm")
    if cfg.hybrid is not None:
        n_layers_attn += cfg.n_layers // cfg.hybrid.attn_period
    if n_layers_attn == 0:
        return {"flops": 0.0, "bytes": 0.0}
    mult = 3.0 if shape.kind == "train" else 1.0
    n_blocks = -(-S // block_k)
    per_layer_full = _attn_flops_per_layer(cfg, B, S, causal=False)
    body = per_layer_full / n_blocks
    missing_flops = (n_blocks - 1) * body * n_layers_attn * mult
    if cfg.mla is not None:
        H, dh = cfg.n_heads, (cfg.mla.qk_nope_head_dim
                              + cfg.mla.qk_rope_head_dim + cfg.mla.v_head_dim)
    else:
        H, dh = cfg.n_heads, 3 * cfg.resolved_head_dim
    qkvo_bytes = 2.0 * B * S * H * dh * (2 if shape.kind == "prefill" else 4)
    return {"flops": missing_flops,
            "bytes": qkvo_bytes * n_layers_attn}
