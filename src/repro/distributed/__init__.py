from .sharding import (Rules, current_rules, default_rules, named_sharding,
                       param_pspecs, param_shardings, shard, use_rules)
