"""Aliases for jax APIs that moved between 0.4.x and current releases.

The distributed code targets the current jax surface (``jax.shard_map``,
``jax.sharding.AxisType``); environments pinned to jax 0.4.x still carry
those under their old names/signatures.  Everything version-dependent goes
through here so call sites stay on the modern spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, /, *, mesh=None, in_specs=None, out_specs=None,
                  check_vma=True, **kwargs):
        # 0.4.x spells check_vma as check_rep.
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict.

    0.4.x returns a one-entry list of per-program dicts; current jax
    returns the dict directly.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the argument exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
