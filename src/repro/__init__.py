"""repro: MRSch (multi-resource HPC scheduling via Direct Future Prediction)
rebuilt as a production-grade multi-pod JAX framework."""
__version__ = "1.0.0"
