"""Structured event tracing — the ``mrsch.trace/v1`` schema.

One trace is a JSONL file: a header line ``{"schema": "mrsch.trace/v1",
"meta": {...}}`` followed by one compact-JSON event per line.  Events are
flat dicts with at least ``ev`` (event kind), ``env`` (environment index,
``-1`` for host-side events) and ``t`` (simulation seconds, or wall
seconds since tracer creation for host events).

The taxonomy (see docs/observability.md):

===================  =======================================================
``sched.decision``   agent picked window slot ``a`` -> job ``jid``;
                     ``q`` = queue length, ``fit`` = 1 if it started now
``sched.reserve``    non-fitting pick reserved at earliest fit (EASY shadow)
``sched.backfill``   backfill pass finished; ``n`` jobs jumped the queue
``job.queued``       job became visible to the scheduler
``job.start``        attempt started (``bf`` = 1 when backfilled)
``job.finish``       terminal success
``job.fail``         terminal failure (requeue bound exhausted / cascade)
``job.requeue``      attempt killed, job re-entered the queue (``n``-th kill)
``fault.drain``      ``units`` units of ``res`` drained (fault injection)
``fault.restore``    drained units restored
``ckpt.reload``      serving params hot-swapped to checkpoint ``step``
``serve.dispatch``   micro-batch of ``n`` requests dispatched at padded
                     ``width``; ``wait_s`` = max queue wait in the batch
``prof.span``        named wall-clock phase of ``dur_s`` seconds
===================  =======================================================

Parity contract: the three engines (sequential / vector / device) emit
**byte-identical** canonical streams for the same scenario and seed.  To
make that possible every simulation timestamp is canonicalized to its
float32 value at record time (the device engine's clock is f32), and
:func:`canonical_events` imposes one total order that is independent of
engine interleaving.  Wall-clock events (``ckpt.reload``,
``serve.dispatch``, ``prof.span``) are emitted only by harnesses — never
by an engine — and sort after all simulation events.

The default :data:`NULL` tracer (an instance of the no-op base
:class:`Tracer`) keeps instrumented paths allocation-free when
observability is off; `benchmarks/bench_obs.py` gates its cost at <= 2 %
of decision latency.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

TRACE_SCHEMA = "mrsch.trace/v1"

__all__ = [
    "TRACE_SCHEMA", "Tracer", "NullTracer", "NULL", "BufferTracer",
    "canonical_events", "trace_lines", "write_trace", "read_trace",
    "to_chrome",
]


def _t32(t: float) -> float:
    """Canonical trace timestamp: the exact f32 value, as a Python float."""
    return float(np.float32(t))


class Tracer:
    """No-op tracer: every typed emit method does nothing.

    Engines and services call these methods unconditionally; with the
    default instance the calls are plain attribute lookups + empty-body
    invocations (no allocation, no branching at call sites).  Subclass
    and override to record (:class:`BufferTracer`) or stream elsewhere.
    """

    __slots__ = ()

    #: True when emits are recorded — lets hot paths skip building
    #: *derived* payloads (never required for correctness).
    enabled = False

    # -- scheduler events (simulation time) ------------------------------
    def decision(self, env: int, t: float, a: int, jid: int, q: int,
                 fit: int) -> None:
        pass

    def reserve(self, env: int, t: float, jid: int) -> None:
        pass

    def backfill(self, env: int, t: float, n: int) -> None:
        pass

    # -- job lifecycle events (simulation time) --------------------------
    def job_queued(self, env: int, t: float, jid: int) -> None:
        pass

    def job_start(self, env: int, t: float, jid: int, bf: int = 0) -> None:
        pass

    def job_finish(self, env: int, t: float, jid: int) -> None:
        pass

    def job_fail(self, env: int, t: float, jid: int) -> None:
        pass

    def job_requeue(self, env: int, t: float, jid: int, n: int) -> None:
        pass

    # -- fault events (simulation time) ----------------------------------
    def drain(self, env: int, t: float, res: str, units: int) -> None:
        pass

    def restore(self, env: int, t: float, res: str, units: int) -> None:
        pass

    # -- host-side events (wall time; harnesses only) --------------------
    def ckpt_reload(self, step: int) -> None:
        pass

    def dispatch(self, n: int, width: int, wait_s: float) -> None:
        pass

    def span(self, name: str, dur_s: float) -> None:
        pass


#: Back-compat alias: the base class *is* the null tracer.
NullTracer = Tracer

#: Module-wide default used by every instrumented constructor.
NULL = Tracer()


class BufferTracer(Tracer):
    """Records every event as a flat dict in :attr:`events`.

    ``meta`` is free-form run metadata embedded in the JSONL header by
    :func:`write_trace` (e.g. the env -> (policy, scenario, seed) map the
    matrix runner fills in).
    """

    __slots__ = ("events", "meta", "_wall0")

    enabled = True

    def __init__(self) -> None:
        import time
        self.events: List[Dict] = []
        self.meta: Dict = {}
        self._wall0 = time.perf_counter()

    def _wall(self) -> float:
        import time
        return round(time.perf_counter() - self._wall0, 6)

    # -- scheduler --------------------------------------------------------
    def decision(self, env, t, a, jid, q, fit):
        self.events.append({"ev": "sched.decision", "env": int(env),
                            "t": _t32(t), "a": int(a), "jid": int(jid),
                            "q": int(q), "fit": int(fit)})

    def reserve(self, env, t, jid):
        self.events.append({"ev": "sched.reserve", "env": int(env),
                            "t": _t32(t), "jid": int(jid)})

    def backfill(self, env, t, n):
        self.events.append({"ev": "sched.backfill", "env": int(env),
                            "t": _t32(t), "n": int(n)})

    # -- lifecycle --------------------------------------------------------
    def job_queued(self, env, t, jid):
        self.events.append({"ev": "job.queued", "env": int(env),
                            "t": _t32(t), "jid": int(jid)})

    def job_start(self, env, t, jid, bf=0):
        self.events.append({"ev": "job.start", "env": int(env),
                            "t": _t32(t), "jid": int(jid), "bf": int(bf)})

    def job_finish(self, env, t, jid):
        self.events.append({"ev": "job.finish", "env": int(env),
                            "t": _t32(t), "jid": int(jid)})

    def job_fail(self, env, t, jid):
        self.events.append({"ev": "job.fail", "env": int(env),
                            "t": _t32(t), "jid": int(jid)})

    def job_requeue(self, env, t, jid, n):
        self.events.append({"ev": "job.requeue", "env": int(env),
                            "t": _t32(t), "jid": int(jid), "n": int(n)})

    # -- faults -----------------------------------------------------------
    def drain(self, env, t, res, units):
        self.events.append({"ev": "fault.drain", "env": int(env),
                            "t": _t32(t), "res": str(res),
                            "units": int(units)})

    def restore(self, env, t, res, units):
        self.events.append({"ev": "fault.restore", "env": int(env),
                            "t": _t32(t), "res": str(res),
                            "units": int(units)})

    # -- host-side --------------------------------------------------------
    def ckpt_reload(self, step):
        self.events.append({"ev": "ckpt.reload", "env": -1,
                            "t": self._wall(), "step": int(step)})

    def dispatch(self, n, width, wait_s):
        self.events.append({"ev": "serve.dispatch", "env": -1,
                            "t": self._wall(), "n": int(n),
                            "width": int(width),
                            "wait_s": round(float(wait_s), 6)})

    def span(self, name, dur_s):
        self.events.append({"ev": "prof.span", "env": -1,
                            "t": self._wall(), "name": str(name),
                            "dur_s": round(float(dur_s), 6)})


# --------------------------------------------------------------------------
# Canonical ordering + serialization
# --------------------------------------------------------------------------
#: Phase rank of simulation events inside one (env, timestamp) group:
#: attempt-end transitions, then queue entries, then drains, restores and
#: finally the decision pass (whose internal emission order is already
#: deterministic and must be preserved — the sort is stable).
_PHASE = {
    "job.finish": 0, "job.fail": 0, "job.requeue": 0,
    "job.queued": 1,
    "fault.drain": 2,
    "fault.restore": 3,
    "sched.decision": 4, "job.start": 4, "sched.reserve": 4,
    "sched.backfill": 4,
}


def canonical_events(events: Iterable[Dict]) -> List[Dict]:
    """One total order over simulation events, independent of how engine
    rounds interleaved environments.  Sort key: (env, t, phase), with
    end/queued/fault phases sub-ordered by (kind, jid) and the decision
    pass kept in (stable) emission order.  Host-side wall-clock events
    keep their emission order after all simulation events."""
    sim, host = [], []
    for e in events:
        (sim if e["ev"] in _PHASE else host).append(e)

    def key(e: Dict) -> Tuple:
        p = _PHASE[e["ev"]]
        sub = (e["ev"], e.get("jid", -1)) if p < 4 else ("", -1)
        return (e["env"], e["t"], p, sub)

    return sorted(sim, key=key) + host


def _dump(obj: Dict) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def trace_lines(events: Iterable[Dict],
                meta: Optional[Dict] = None) -> List[str]:
    """Full canonical serialization: header line + one line per event."""
    header = {"schema": TRACE_SCHEMA, "meta": meta if meta else {}}
    return [_dump(header)] + [_dump(e) for e in canonical_events(events)]


def write_trace(events: Iterable[Dict], path,
                meta: Optional[Dict] = None) -> Path:
    """Write a canonical ``mrsch.trace/v1`` JSONL file."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text("\n".join(trace_lines(events, meta)) + "\n",
                 encoding="utf-8")
    return p


def read_trace(path) -> Tuple[Dict, List[Dict]]:
    """Read a JSONL trace -> (meta, events).  Validates the header."""
    lines = Path(path).read_text(encoding="utf-8").splitlines()
    if not lines:
        raise ValueError(f"empty trace file: {path}")
    header = json.loads(lines[0])
    if header.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"not a {TRACE_SCHEMA} trace: header {header!r} in {path}")
    return header.get("meta", {}), [json.loads(ln) for ln in lines[1:] if ln]


# --------------------------------------------------------------------------
# Chrome-trace (Perfetto-loadable) export
# --------------------------------------------------------------------------
def to_chrome(events: Sequence[Dict], meta: Optional[Dict] = None) -> Dict:
    """Convert a trace to the Chrome trace-event JSON format.

    Job attempts become complete ("X") slices (pid = env, tid = jid,
    ``ts``/``dur`` in microseconds of simulation time); scheduler and
    fault events become instants ("i"); ``prof.span`` becomes wall-clock
    slices on the synthetic ``host`` process.  Load the output in
    https://ui.perfetto.dev.
    """
    out: List[Dict] = []
    open_start: Dict[Tuple[int, int], Tuple[float, int]] = {}

    def us(t: float) -> float:
        return round(t * 1e6, 3)

    for e in canonical_events(events):
        ev, env, t = e["ev"], e["env"], e["t"]
        if ev == "job.start":
            open_start[(env, e["jid"])] = (t, e.get("bf", 0))
        elif ev in ("job.finish", "job.fail", "job.requeue"):
            start = open_start.pop((env, e["jid"]), None)
            if start is not None:
                t0, bf = start
                out.append({"ph": "X", "pid": env, "tid": e["jid"],
                            "name": f"job {e['jid']}", "cat": "job",
                            "ts": us(t0), "dur": us(t - t0),
                            "args": {"backfilled": bf, "outcome": ev}})
            if ev != "job.finish":
                out.append({"ph": "i", "pid": env, "tid": e["jid"],
                            "name": ev, "cat": "job", "ts": us(t),
                            "s": "t", "args": {k: v for k, v in e.items()
                                               if k not in ("ev", "env",
                                                            "t")}})
        elif ev == "prof.span":
            out.append({"ph": "X", "pid": -1, "tid": 0, "name": e["name"],
                        "cat": "phase", "ts": us(t - e["dur_s"]),
                        "dur": us(e["dur_s"])})
        else:
            out.append({"ph": "i", "pid": env, "tid": 0, "name": ev,
                        "cat": ev.split(".", 1)[0], "ts": us(t), "s": "t",
                        "args": {k: v for k, v in e.items()
                                 if k not in ("ev", "env", "t")}})
    # Attempts still running at trace end: zero-length open slices.
    for (env, jid), (t0, bf) in sorted(open_start.items()):
        out.append({"ph": "X", "pid": env, "tid": jid, "name": f"job {jid}",
                    "cat": "job", "ts": us(t0), "dur": 0.0,
                    "args": {"backfilled": bf, "outcome": "running"}})
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA, "meta": meta or {}}}
