"""Profiling hooks: named trace annotations + wall-clock spans.

Two complementary mechanisms:

- :func:`named_scope` — ``jax.named_scope``: a *trace-time* name-stack
  entry, so HLO ops compiled from a region carry the name and a
  ``jax.profiler`` device trace attributes kernel time to it.  Zero
  runtime cost after compilation; this is what wraps every Pallas kernel
  dispatch (``fused_mlp``, ``flash_attention``, ``window_pack``).
- :func:`annotate` — ``jax.profiler.TraceAnnotation``: a *host-side*
  profiler annotation for engine phases (device rollout, vector policy
  dispatch, serve micro-batch, train step), visible on the Python
  timeline of a captured profile.

Both degrade to no-op context managers when the underlying jax API is
unavailable, so instrumented code never needs to guard.

:func:`span` is the tracer-facing counterpart: it measures a wall-clock
phase and emits a ``prof.span`` event, which `tools/trace_report.py`
aggregates into the per-phase time table.
"""
from __future__ import annotations

import contextlib
import time
from typing import ContextManager

from .trace import NULL, Tracer

__all__ = ["annotate", "named_scope", "span"]

try:  # pragma: no cover - import guard
    import jax
except Exception:  # pragma: no cover
    jax = None


def annotate(name: str) -> ContextManager:
    """Host-side ``jax.profiler.TraceAnnotation(name)`` (no-op fallback)."""
    prof = getattr(jax, "profiler", None) if jax is not None else None
    cls = getattr(prof, "TraceAnnotation", None) if prof is not None else None
    if cls is None:  # pragma: no cover - jax always has it in CI
        return contextlib.nullcontext()
    return cls(name)


def named_scope(name: str) -> ContextManager:
    """Trace-time ``jax.named_scope(name)`` (no-op fallback)."""
    fn = getattr(jax, "named_scope", None) if jax is not None else None
    if fn is None:  # pragma: no cover
        return contextlib.nullcontext()
    return fn(name)


@contextlib.contextmanager
def span(tracer: Tracer, name: str):
    """Time a wall-clock phase; emit ``prof.span`` + a profiler
    annotation.  Safe (and free) with the NULL tracer."""
    with annotate(f"mrsch.{name}"):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            (tracer or NULL).span(name, time.perf_counter() - t0)
