"""Unified telemetry layer: structured event tracing (``mrsch.trace/v1``),
metrics registry with Prometheus-style exposition, and profiling hooks.

See docs/observability.md for the event taxonomy and how to read a
trace.  Everything is off by default: engines take ``tracer=NULL``,
services take ``registry=None``, and the instrumented paths stay
allocation-free (gated by ``benchmarks/bench_obs.py``).
"""
from .metrics import (Counter, Gauge, Histogram, JsonlFlusher,
                      MetricsRegistry)
from .profiling import annotate, named_scope, span
from .trace import (NULL, TRACE_SCHEMA, BufferTracer, NullTracer, Tracer,
                    canonical_events, read_trace, to_chrome, trace_lines,
                    write_trace)

__all__ = [
    "TRACE_SCHEMA", "Tracer", "NullTracer", "NULL", "BufferTracer",
    "canonical_events", "trace_lines", "write_trace", "read_trace",
    "to_chrome",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "JsonlFlusher",
    "annotate", "named_scope", "span",
]
