"""Metrics registry: counters / gauges / histograms with Prometheus-style
text exposition and a periodic JSONL flusher.

Thread-safe (one lock per registry — serving and training touch metrics
from worker threads).  Instruments take an optional ``labels`` dict;
each distinct label set is its own time series, exactly like Prometheus
children::

    reg = MetricsRegistry()
    reg.counter("serve_requests_total").inc(3)
    reg.gauge("train_loss", labels={"lane": "0"}).set(0.12)
    reg.histogram("serve_queue_wait_seconds").observe(0.004)
    print(reg.to_prometheus())

``snapshot()`` returns a plain dict for JSON emission; ``JsonlFlusher``
appends one snapshot line per interval (or per manual ``flush()``) so
long-running training/serving processes leave a metrics trail next to
their ``mrsch.trace/v1`` event trace.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "JsonlFlusher",
    "DEFAULT_BUCKETS",
]

#: Default histogram buckets (seconds-flavored, Prometheus-style).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Mapping[str, object]]) -> _LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: _LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("_v", "_lock")

    def __init__(self) -> None:
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._v += v

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    """Last-set value."""

    __slots__ = ("_v", "_lock")

    def __init__(self) -> None:
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._v += v

    @property
    def value(self) -> float:
        return self._v


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each ``le``
    bucket counts observations <= its bound; ``+Inf`` == count)."""

    __slots__ = ("buckets", "_counts", "_count", "_sum", "_min", "_max",
                 "_lock")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._counts = [0] * len(self.buckets)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            for i, b in enumerate(self.buckets):
                if v <= b:
                    self._counts[i] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def summary(self) -> Dict[str, float]:
        n = self._count
        return {
            "count": n, "sum": round(self._sum, 9),
            "mean": round(self._sum / n, 9) if n else 0.0,
            "min": self._min if n else 0.0,
            "max": self._max if n else 0.0,
        }


class MetricsRegistry:
    """Named, labeled instruments + exposition.

    ``counter``/``gauge``/``histogram`` create-or-return the child for
    (name, labels); name collisions across instrument kinds are errors.
    """

    def __init__(self, prefix: str = "mrsch") -> None:
        self.prefix = prefix
        self._lock = threading.Lock()
        self._kinds: Dict[str, str] = {}
        self._children: Dict[str, Dict[_LabelKey, object]] = {}

    def _get(self, kind: str, name: str,
             labels: Optional[Mapping] = None, **kw):
        key = _label_key(labels)
        with self._lock:
            have = self._kinds.get(name)
            if have is None:
                self._kinds[name] = kind
                self._children[name] = {}
            elif have != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {have}")
            series = self._children[name]
            child = series.get(key)
            if child is None:
                cls = {"counter": Counter, "gauge": Gauge,
                       "histogram": Histogram}[kind]
                child = cls(**kw)
                series[key] = child
            return child

    def counter(self, name: str,
                labels: Optional[Mapping] = None) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, labels: Optional[Mapping] = None) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, labels: Optional[Mapping] = None,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get("histogram", name, labels, buckets=buckets)

    # -- exposition -------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (type comments + samples)."""
        lines: List[str] = []
        with self._lock:
            items = sorted(self._kinds.items())
            children = {n: dict(s) for n, s in self._children.items()}
        for name, kind in items:
            full = f"{self.prefix}_{name}" if self.prefix else name
            lines.append(f"# TYPE {full} {kind}")
            for key, child in sorted(children[name].items()):
                ls = _label_str(key)
                if kind == "histogram":
                    assert isinstance(child, Histogram)
                    cum_pairs = list(zip(child.buckets, child._counts))
                    for b, c in cum_pairs:
                        lb = _label_str(key + (("le", f"{b:g}"),))
                        lines.append(f"{full}_bucket{lb} {c}")
                    inf_lb = _label_str(key + (("le", "+Inf"),))
                    lines.append(f"{full}_bucket{inf_lb} {child.count}")
                    lines.append(f"{full}_sum{ls} {child.sum:g}")
                    lines.append(f"{full}_count{ls} {child.count}")
                else:
                    lines.append(f"{full}{ls} {child.value:g}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict:
        """Plain-dict view: {name: {label_str or "": value|summary}}."""
        out: Dict = {}
        with self._lock:
            items = sorted(self._kinds.items())
            children = {n: dict(s) for n, s in self._children.items()}
        for name, kind in items:
            series = {}
            for key, child in sorted(children[name].items()):
                k = _label_str(key)
                if kind == "histogram":
                    series[k] = child.summary()
                else:
                    series[k] = child.value
            out[name] = series
        return out


class JsonlFlusher:
    """Periodically append registry snapshots to a JSONL file.

    Use as a context manager (starts/stops the daemon thread) or call
    :meth:`flush` manually.  Each line: ``{"ts": <unix seconds>,
    "metrics": {...}}``.
    """

    def __init__(self, registry: MetricsRegistry, path,
                 interval_s: float = 10.0) -> None:
        self.registry = registry
        self.path = Path(path)
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def flush(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        line = json.dumps({"ts": round(time.time(), 3),
                           "metrics": self.registry.snapshot()},
                          sort_keys=True, separators=(",", ":"))
        with self.path.open("a", encoding="utf-8") as f:
            f.write(line + "\n")

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.flush()

    def start(self) -> "JsonlFlusher":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="mrsch-metrics-flusher", daemon=True)
            self._thread.start()
        return self

    def stop(self, final_flush: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_flush:
            self.flush()

    def __enter__(self) -> "JsonlFlusher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
