"""RL co-scheduler variant: score node-sharing pairs in the window.

After *A HPC Co-Scheduler with Reinforcement Learning* (Souza,
Pelckmans, Tordsson, arXiv:2401.09706): the co-scheduler's core signal
is how well two jobs share the machine — pairs whose combined
multi-resource footprint packs tightly without oversubscription are
scheduled together.  Here every window slot is scored by its best
pairing partner: ``pair(i, j)`` rewards combined per-resource demand
approaching (but not exceeding) the full machine and penalizes
oversubscription, so a job complementary to another waiting job
outranks one that would strand capacity.  A fixed-seed network adds
the learned residual (untrained in CI, like the other RL entrants),
and waiting time plus an FCFS prior keep the ordering anchored.

Pure ``score_window`` over the classic state layout: demand fractions
for all W tokens are in the leading section, so the W x W pair matrix
is one broadcast — batched on ``VectorSimulator`` and device-capable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.encoding import EncodingConfig, encode_state
from ..core.policy_api import WindowPolicy
from ..nn.modules import mlp_apply, mlp_init
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SchedContext


@dataclass(frozen=True)
class CoSchedConfig:
    window: int = 10
    hidden: Tuple[int, ...] = (64, 32)
    seed: int = 0
    pair_weight: float = 1.0         # co-scheduling complementarity weight
    over_penalty: float = 2.0        # oversubscribed pair penalty
    wait_weight: float = 0.5         # aging term (queued time, normalized)
    net_scale: float = 0.1           # learned residual weight
    fcfs_weight: float = 0.02


class CoSchedPolicy(WindowPolicy):
    """Best-pairing-partner window scorer with a learned residual."""

    def __init__(self, resources: Sequence[ResourceSpec],
                 config: CoSchedConfig = CoSchedConfig()):
        self.config = config
        self.enc = EncodingConfig(
            window=config.window,
            resource_names=tuple(r.name for r in resources),
            capacities=tuple(r.capacity for r in resources))
        self.params = mlp_init(
            jax.random.PRNGKey(config.seed),
            [self.enc.state_dim, *config.hidden, config.window])

    def init_state(self):
        return self.params

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        cfg, enc = self.config, self.enc
        W, jd, R = enc.window, enc.job_dim, enc.n_resources
        tok = obs[..., : W * jd].reshape(*obs.shape[:-1], W, jd)
        d = tok[..., :R]                               # (..., W, R) fractions
        queued = tok[..., R + 1]
        combined = d[..., :, None, :] + d[..., None, :, :]   # (..., W, W, R)
        packed = jnp.minimum(combined, 1.0).mean(-1)         # fill quality
        over = jnp.maximum(combined - 1.0, 0.0).sum(-1)      # oversubscription
        pair = packed - cfg.over_penalty * over
        # A slot may not pair with itself; empty slots (zero demand) offer
        # no pairing gain and are masked out by the engines anyway.
        eye = jnp.eye(W, dtype=bool)
        best_pair = jnp.where(eye, -jnp.inf, pair).max(-1)
        logits = mlp_apply(policy_state, obs[..., : enc.state_dim])
        fcfs = -cfg.fcfs_weight * jnp.arange(W, dtype=jnp.float32)
        return (cfg.pair_weight * best_pair + cfg.wait_weight * queued
                + cfg.net_scale * logits + fcfs)

    def _encode_rows(self, ctxs: Sequence[SchedContext],
                     n_actions: int) -> np.ndarray:
        return np.stack([encode_state(self.enc, c) for c in ctxs])
