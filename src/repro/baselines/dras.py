"""DRAS-style hierarchical agent: window select + reserve/backfill head.

After *Deep Reinforcement Agent for Scheduling in HPC* (Fan & Lan et
al., arXiv:2102.06243): DRAS is a two-level neural network mirroring
the reserve/backfill structure of production schedulers — a first
level picks jobs from the queue window, a second level decides how
aggressively to backfill short jobs behind the current reservation.

Here both levels read the classic MRSch state vector: the select
network produces per-slot logits, and the backfill head produces one
gate in ``[0, 1]`` that scales a shortest-job-first bonus — a high
gate reproduces DRAS's backfill level favoring jobs that slip into
reservation shadows, a low gate degrades to the level-1 ordering.  An
FCFS positional prior anchors the untrained network (the CI tournament
runs untrained instances, exactly like the matrix's CI agent; the
paper-faithful comparison loads trained weights).

Pure ``score_window`` + fixed-seed parameters make the policy
deterministic, batched, and device-capable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.encoding import EncodingConfig, encode_state
from ..core.policy_api import WindowPolicy
from ..nn.modules import mlp_apply, mlp_init
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SchedContext


@dataclass(frozen=True)
class DRASConfig:
    window: int = 10
    hidden: Tuple[int, ...] = (64, 32)
    seed: int = 0
    net_scale: float = 0.1           # level-1 logits weight
    fcfs_weight: float = 0.05        # positional prior anchoring the ordering
    backfill_scale: float = 1.0      # SJF bonus reach of the level-2 gate


class DRASPolicy(WindowPolicy):
    """Two-level (select net + backfill-gate head) window scorer."""

    def __init__(self, resources: Sequence[ResourceSpec],
                 config: DRASConfig = DRASConfig()):
        self.config = config
        self.enc = EncodingConfig(
            window=config.window,
            resource_names=tuple(r.name for r in resources),
            capacities=tuple(r.capacity for r in resources))
        k_sel, k_gate = jax.random.split(jax.random.PRNGKey(config.seed))
        sd = self.enc.state_dim
        self.params = {
            "select": mlp_init(k_sel, [sd, *config.hidden, config.window]),
            "gate": mlp_init(k_gate, [sd, config.hidden[-1], 1]),
        }

    def init_state(self):
        return self.params

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        cfg, enc = self.config, self.enc
        W, jd, R = enc.window, enc.job_dim, enc.n_resources
        state = obs[..., : enc.state_dim]
        logits = mlp_apply(policy_state["select"], state)        # level 1
        gate = jax.nn.sigmoid(
            mlp_apply(policy_state["gate"], state))              # level 2
        tok = obs[..., : W * jd].reshape(*obs.shape[:-1], W, jd)
        wall = tok[..., R]                         # walltime / time_scale
        sjf = -wall * cfg.backfill_scale           # short jobs backfill first
        fcfs = -cfg.fcfs_weight * jnp.arange(W, dtype=jnp.float32)
        return cfg.net_scale * logits + gate * sjf + fcfs

    def _encode_rows(self, ctxs: Sequence[SchedContext],
                     n_actions: int) -> np.ndarray:
        # Both levels consume the state section only.
        return np.stack([encode_state(self.enc, c) for c in ctxs])
