"""Priority Rules Based (PRB) dispatcher with EWT priorities.

After accasim's PRB scheduler (SNIPPETS.md snippet 1; Borghesi,
Collina, Lombardi, Milano, Benini, *Power Capping in High Performance
Computing Systems*, CP 2015): each queued job carries an Estimated
Waiting Time derived from its request class, and its dispatch priority
is the elapsed wait normalized by that EWT — jobs that have waited
longer than their class predicts float to the front, while wide/long
requests (whose classes expect long waits) cannot starve narrow ones.

The EWT model is the linear request-class proxy used throughout that
line of work: ``EWT = base + a * walltime + b * sum_r demand_frac_r``
(bigger asks expect to wait longer).  Reservation + EASY backfilling
come from the simulator, as for every policy in the zoo — PRB only
changes the selection order.

Expressed as a pure ``score_window`` over the classic state layout
(``repro.core.encoding``): each window token already carries
``[P_1..P_R, walltime_norm, queued_norm]``, which is everything the
priority needs, so the policy batches on ``VectorSimulator`` and is
device-capable with no host state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from ..core.encoding import EncodingConfig, encode_state
from ..core.policy_api import WindowPolicy
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SchedContext


@dataclass(frozen=True)
class PRBConfig:
    window: int = 10
    base_ewt_s: float = 3600.0       # class EWT floor (1 h)
    walltime_factor: float = 0.5     # EWT seconds per requested walltime second
    demand_factor: float = 4.0       # EWT hours per unit of summed demand frac
    min_wait_s: float = 60.0         # wait floor so fresh jobs still rank


class PRBPolicy(WindowPolicy):
    """EWT-normalized priority selection over the window."""

    def __init__(self, resources: Sequence[ResourceSpec],
                 config: PRBConfig = PRBConfig()):
        self.config = config
        self.enc = EncodingConfig(
            window=config.window,
            resource_names=tuple(r.name for r in resources),
            capacities=tuple(r.capacity for r in resources))

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        cfg, enc = self.config, self.enc
        W, jd, R = enc.window, enc.job_dim, enc.n_resources
        tok = obs[..., : W * jd].reshape(*obs.shape[:-1], W, jd)
        demand = tok[..., :R].sum(-1)                  # summed demand fraction
        wall = tok[..., R]                             # walltime / time_scale
        queued = tok[..., R + 1]                       # wait / time_scale
        ts = enc.time_scale
        ewt = (cfg.base_ewt_s / ts
               + cfg.walltime_factor * wall
               + cfg.demand_factor * 3600.0 / ts * demand)
        prio = (queued + cfg.min_wait_s / ts) / ewt
        # FCFS tiebreak: equal priorities resolve in queue order.
        return prio - 1e-6 * jnp.arange(W, dtype=jnp.float32)

    def _encode_rows(self, ctxs: Sequence[SchedContext],
                     n_actions: int) -> np.ndarray:
        # Only the window tokens feed the priority; skip meas/goal work.
        return np.stack([encode_state(self.enc, c) for c in ctxs])
