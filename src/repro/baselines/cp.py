"""Constraint/optimization dispatcher: window packing as a small ILP.

After accasim's hybrid constraint-programming scheduler (SNIPPETS.md
snippet 1): each scheduling round poses the current window as a
packing problem — pick the subset of window jobs maximizing summed
dispatch value subject to the cluster's free multi-resource capacities
— and dispatches from the optimal subset.  Job value combines the
EWT-normalized priority PRB uses (so the two accasim dispatchers share
a priority model) with a utilization term rewarding big asks that the
free pool can absorb.

The solve is exact for small windows: all ``2^W`` subsets are
enumerated with one vectorized mask product (W <= ``exact_window``,
the paper-standard W=10 costs a 1024-row matmul per decision).  Wider
windows fall back to the classic greedy LP-relaxation ordering (value
per weighted unit of scarce demand) plus one swap-improvement pass.

The dispatcher is stateless — every decision re-solves from the
context alone — so one instance batches across ``VectorSimulator``
lanes via the host ``select_batch`` loop.  It has no pure traced form
(the solve is combinatorial), so like ``GAOptimizer`` it reports
``supports_device() == False``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from ..sim.simulator import SchedContext

_MASKS: Dict[int, np.ndarray] = {}   # W -> (2^W, W) subset masks


def _subset_masks(w: int) -> np.ndarray:
    m = _MASKS.get(w)
    if m is None:
        m = ((np.arange(1 << w)[:, None] >> np.arange(w)) & 1
             ).astype(np.float64)
        _MASKS[w] = m
    return m


@dataclass(frozen=True)
class CPConfig:
    window: int = 10
    exact_window: int = 12           # enumerate subsets up to this W
    base_ewt_s: float = 3600.0       # shared EWT priority model (see prb.py)
    walltime_factor: float = 0.5
    demand_factor: float = 4.0
    min_wait_s: float = 60.0
    util_weight: float = 0.5         # value bonus per unit of demand fraction
    swap_passes: int = 1             # improvement passes in greedy mode


class CPDispatcher:
    """Optimal-subset window dispatcher (host-side stages only)."""

    # No pure traced form: the engines must use the host stages.
    init_state = None
    score_window = None

    def __init__(self, config: CPConfig = CPConfig()):
        self.config = config

    # ----------------------------------------------------------- valuation
    def _values(self, ctx: SchedContext, fracs: np.ndarray) -> np.ndarray:
        cfg = self.config
        demand = fracs.sum(axis=1)
        wall = np.array([j.walltime for j in ctx.window])
        wait = np.array([max(ctx.now - j.submit, 0.0) for j in ctx.window])
        ewt = (cfg.base_ewt_s + cfg.walltime_factor * wall
               + cfg.demand_factor * 3600.0 * demand)
        value = (wait + cfg.min_wait_s) / ewt + cfg.util_weight * demand
        # FCFS tiebreak keeps the solve deterministic under equal values.
        return value - 1e-9 * np.arange(len(ctx.window))

    def _solve(self, free: np.ndarray, fracs_units: np.ndarray,
               values: np.ndarray) -> np.ndarray:
        """Boolean chosen-mask maximizing sum(values) within ``free``."""
        n = len(values)
        if n <= self.config.exact_window:
            masks = _subset_masks(n)
            feasible = (masks @ fracs_units <= free + 1e-9).all(axis=1)
            totals = np.where(feasible, masks @ values, -np.inf)
            return masks[int(np.argmax(totals))] > 0.5
        # Greedy LP-relaxation: value per weighted unit of scarce demand.
        scarce = 1.0 / np.maximum(free, 1.0)
        density = values / (fracs_units @ scarce + 1e-9)
        order = np.argsort(-density, kind="stable")
        chosen = np.zeros(n, bool)
        residual = free.astype(np.float64).copy()
        for i in order:
            if (fracs_units[i] <= residual + 1e-9).all():
                chosen[i] = True
                residual -= fracs_units[i]
        for _ in range(self.config.swap_passes):
            improved = False
            for i in np.argsort(-values, kind="stable"):
                if chosen[i]:
                    continue
                for k in np.argsort(values, kind="stable"):
                    if not chosen[k] or values[k] >= values[i]:
                        continue
                    if (fracs_units[i] - fracs_units[k]
                            <= residual + 1e-9).all():
                        chosen[k] = False
                        chosen[i] = True
                        residual += fracs_units[k] - fracs_units[i]
                        improved = True
                        break
            if not improved:
                break
        return chosen

    # ------------------------------------------------------------- stages
    def _select_one(self, ctx: SchedContext) -> int:
        names = ctx.cluster.names
        caps = np.array([max(ctx.cluster.capacities[n], 1) for n in names],
                        dtype=np.float64)
        free = np.array([ctx.cluster.free[n] for n in names], dtype=np.float64)
        units = np.array([[j.demands.get(n, 0) for n in names]
                          for j in ctx.window], dtype=np.float64)
        values = self._values(ctx, units / caps)
        chosen = self._solve(free, units, values)
        if chosen.any():
            # Dispatch the most valuable member of the optimal subset; the
            # simulator starts it and re-asks, so the round re-solves with
            # the residual capacity.
            return int(np.argmax(np.where(chosen, values, -np.inf)))
        # Nothing fits: hand the highest-priority job to the reservation +
        # EASY-backfill machinery.
        return int(np.argmax(values))

    def select(self, ctx: SchedContext) -> int:
        return self._select_one(ctx)

    def select_batch(self, ctxs: Sequence[SchedContext]) -> np.ndarray:
        return np.array([self._select_one(c) for c in ctxs], dtype=np.int32)
