"""Literature baseline zoo (ROADMAP: baseline zoo + policy tournament).

Every entrant implements the unified ``repro.core.policy_api`` protocol,
so the evaluation matrix and the standing tournament
(``repro.eval.tournament``) run them batched on ``VectorSimulator``
exactly like the paper's own four methods:

* ``PRBPolicy``    — Priority Rules Based backfill with Estimated
                     Waiting Time priorities (accasim's PRB dispatcher,
                     after Borghesi et al., CP 2015).
* ``CPDispatcher`` — constraint/optimization dispatcher: each round's
                     window packing solved as a small ILP (exact subset
                     enumeration for W <= ``exact_window``, greedy
                     density relaxation + swap pass beyond), after
                     accasim's hybrid-CP scheduler.
* ``DRASPolicy``   — DRAS-style two-level agent: a window-select
                     network plus a reserve/backfill head
                     (Fan & Lan, arXiv:2102.06243).
* ``CoSchedPolicy``— RL co-scheduler variant scoring node-sharing
                     pairs: complementary window jobs boost each other
                     (after arXiv:2401.09706).

See ``docs/baselines.md`` for each policy's knobs and provenance.
"""
from .cosched import CoSchedConfig, CoSchedPolicy
from .cp import CPConfig, CPDispatcher
from .dras import DRASConfig, DRASPolicy
from .prb import PRBConfig, PRBPolicy

__all__ = [
    "PRBConfig", "PRBPolicy",
    "CPConfig", "CPDispatcher",
    "DRASConfig", "DRASPolicy",
    "CoSchedConfig", "CoSchedPolicy",
]
