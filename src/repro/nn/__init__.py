from .backend import (BACKENDS, dense_forward, mlp_forward, resolve_backend)
from .modules import (conv1d_apply, conv1d_init, count_params, dense_apply,
                      dense_init, glorot_init, he_init, leaky_relu, mlp_apply,
                      mlp_init)
from .optim import AdamState, adam_init, adam_update
