"""Queue-as-tokens attention state encoder (ROADMAP: set encoder over the
*entire* job queue).

The paper's §IV-B state vector caps observation at the first W queued
jobs.  This module removes the cap: every waiting job (up to a generous
``queue_cap``) becomes one token of per-job features, the cluster
context (free fractions + mean time-to-free per resource) is injected as
an always-valid token 0, and a small pre-norm transformer stack runs
non-causal attention masked to the true queue length — on the
``"pallas"`` backend through the flash-attention kernel with its fused
custom-VJP backward (``repro.kernels.flash_attention.ops.mha``), on
``"xla"`` through the dense masked reference.

Pooling into the DFP state vector keeps both halves of the story:

* permutation-equivariant summary — the context-token output plus the
  masked mean over job tokens sees the WHOLE queue and is invariant to
  how much padding the buffer carries;
* slot identity — the first W job-token embeddings are read out
  positionally (zeroed where invalid), because the DFP action stream
  scores exactly those window slots and must know which token sits in
  which slot.

Token features, queue length and context features are laid out flat in
the state vector by ``repro.core.encoding`` (``state_module ==
"attention"``); this module only consumes that layout.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import mha
from ..kernels.flash_attention.ref import attention_ref
from .backend import dense_forward, resolve_backend
from .modules import Params, dense_init, mlp_init

LN_EPS = 1e-5


@dataclass(frozen=True)
class QueueEncoderConfig:
    """Static architecture of the queue encoder.

    ``queue_cap`` (Q) is the padded token-buffer size; parameters do NOT
    depend on it, so the same checkpoint runs under any buffer size (the
    padding-invariance property test pins this).  ``window`` (W) is how
    many leading job tokens are read out positionally for the action
    slots — the simulation window.
    """
    queue_cap: int               # Q: job-token buffer size
    job_dim: int                 # per-job feature width (R + 2)
    ctx_dim: int                 # context-token feature width (2R)
    window: int                  # W: positional read-out slots
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    mlp_mult: int = 2
    out_dim: int = 512           # DFP state-feature width

    def __post_init__(self):
        if self.d_model % self.n_heads:
            raise ValueError(f"d_model {self.d_model} not divisible by "
                             f"n_heads {self.n_heads}")
        if self.queue_cap < self.window:
            raise ValueError(f"queue_cap {self.queue_cap} < window "
                             f"{self.window}: the window slots are the "
                             "leading queue tokens")

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def _ln_init(dim: int) -> Params:
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def _ln(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * p["scale"] + p["bias"]


def queue_encoder_init(key: jax.Array, cfg: QueueEncoderConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4 + cfg.n_layers)
    blocks = []
    for i in range(cfg.n_layers):
        bk = jax.random.split(ks[4 + i], 5)
        blocks.append({
            "ln1": _ln_init(d),
            "wq": dense_init(bk[0], d, d),
            "wk": dense_init(bk[1], d, d),
            "wv": dense_init(bk[2], d, d),
            "wo": dense_init(bk[3], d, d),
            "ln2": _ln_init(d),
            "mlp": mlp_init(bk[4], [d, cfg.mlp_mult * d, d]),
        })
    return {
        "tok": dense_init(ks[0], cfg.job_dim, d),
        "ctx": dense_init(ks[1], cfg.ctx_dim, d),
        "blocks": blocks,
        "ln_f": _ln_init(d),
        "out": dense_init(ks[2], d * (2 + cfg.window), cfg.out_dim),
    }


def _dense(layer: Params, x: jnp.ndarray, activation=None, *,
           backend: str, interpret=None) -> jnp.ndarray:
    """dense_forward over arbitrary leading dims (the fused kernel and
    its padding logic are 2-D)."""
    flat = x.reshape(-1, x.shape[-1])
    y = dense_forward(layer, flat, activation, backend=backend,
                      interpret=interpret)
    return y.reshape(*x.shape[:-1], y.shape[-1])


def _attend(q, k, v, lengths, *, backend: str, interpret=None):
    """(B, S, H, hd) self-attention masked to per-batch lengths."""
    B, S, H, hd = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    lens = jnp.repeat(lengths, H)                     # b-major, h-minor
    if backend == "pallas":
        out = mha(qf, kf, vf, lens, interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, causal=False, lengths=lens)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def encode_queue_tokens(params: Params, cfg: QueueEncoderConfig,
                        tokens: jnp.ndarray, qlen: jnp.ndarray,
                        ctx: jnp.ndarray, *, backend: str = "xla",
                        interpret=None) -> jnp.ndarray:
    """Per-token embeddings (B, 1 + Q, d_model); token 0 is the context.

    ``tokens`` (B, Q, job_dim) zero-padded past the queue, ``qlen`` (B,)
    true queue lengths, ``ctx`` (B, ctx_dim).  Attention keys are masked
    to ``1 + qlen`` (context token always valid); queries are computed
    for every slot, so a padded slot's embedding depends only on the
    valid tokens — outputs over the valid region are invariant to
    ``queue_cap`` padding and equivariant under permutations of the
    valid tokens (both property-tested).
    """
    resolve_backend(backend)
    B, Q, _ = tokens.shape
    tok = _dense(params["tok"], tokens, backend=backend, interpret=interpret)
    ctx_t = _dense(params["ctx"], ctx, backend=backend,
                   interpret=interpret)[:, None]
    x = jnp.concatenate([ctx_t, tok], axis=1)         # (B, S = 1 + Q, d)
    S, H, hd = 1 + Q, cfg.n_heads, cfg.head_dim
    lengths = qlen.astype(jnp.float32) + 1.0
    for blk in params["blocks"]:
        h = _ln(blk["ln1"], x)
        qh = _dense(blk["wq"], h, backend=backend,
                    interpret=interpret).reshape(B, S, H, hd)
        kh = _dense(blk["wk"], h, backend=backend,
                    interpret=interpret).reshape(B, S, H, hd)
        vh = _dense(blk["wv"], h, backend=backend,
                    interpret=interpret).reshape(B, S, H, hd)
        a = _attend(qh, kh, vh, lengths, backend=backend,
                    interpret=interpret)
        x = x + _dense(blk["wo"], a.reshape(B, S, cfg.d_model),
                       backend=backend, interpret=interpret)
        h2 = _ln(blk["ln2"], x)
        m = _dense(blk["mlp"]["layers"][0], h2, "leaky_relu",
                   backend=backend, interpret=interpret)
        x = x + _dense(blk["mlp"]["layers"][1], m, backend=backend,
                       interpret=interpret)
    return _ln(params["ln_f"], x)


def queue_state_features(params: Params, cfg: QueueEncoderConfig,
                         state: jnp.ndarray, *, backend: str = "xla",
                         interpret=None) -> jnp.ndarray:
    """Flat attention-layout state (..., state_dim) -> (..., out_dim).

    State layout (``repro.core.encoding``, state_module="attention"):
    ``[Q * job_dim tokens | queue_len | ctx (2R)]``.  Pooled feature =
    [context-token output | masked mean over job tokens | first-W token
    embeddings (zeroed where invalid)] -> dense -> leaky_relu.
    """
    Q, jd, W = cfg.queue_cap, cfg.job_dim, cfg.window
    lead = state.shape[:-1]
    flat = state.reshape(-1, state.shape[-1])
    B = flat.shape[0]
    tokens = flat[:, :Q * jd].reshape(B, Q, jd)
    qlen = flat[:, Q * jd]
    ctx = flat[:, Q * jd + 1:Q * jd + 1 + cfg.ctx_dim]
    h = encode_queue_tokens(params, cfg, tokens, qlen, ctx,
                            backend=backend, interpret=interpret)
    hc = h[:, 0]                                       # (B, d)
    jobs = h[:, 1:]                                    # (B, Q, d)
    valid = (jnp.arange(Q, dtype=jnp.float32)[None, :]
             < qlen[:, None]).astype(h.dtype)          # (B, Q)
    mean = ((jobs * valid[..., None]).sum(axis=1)
            / jnp.maximum(valid.sum(axis=1, keepdims=True), 1.0))
    win = jobs[:, :W] * valid[:, :W, None]
    feat = jnp.concatenate([hc, mean, win.reshape(B, W * cfg.d_model)],
                           axis=-1)
    y = _dense(params["out"], feat, "leaky_relu", backend=backend,
               interpret=interpret)
    return y.reshape(*lead, cfg.out_dim)
