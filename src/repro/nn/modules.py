"""Minimal neural-network library on raw JAX pytrees.

Only what the MRSch agent and baselines need: dense layers, MLPs, a small
conv stack (for the CNN state-module ablation), LeakyReLU, and He/Glorot
initializers.  Params are plain nested dicts so they serialize with the
checkpoint subsystem and shard with ``NamedSharding`` without a framework
dependency.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Params = dict


def he_init(key, shape, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) == 2 else math.prod(shape[:-1])
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(key, shape, dtype) * std


def glorot_init(key, shape, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) == 2 else math.prod(shape[:-1])
    fan_out = shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def leaky_relu(x, negative_slope: float = 0.2):
    return jnp.where(x >= 0, x, negative_slope * x)


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> Params:
    wkey, _ = jax.random.split(key)
    return {
        "w": he_init(wkey, (in_dim, out_dim), dtype),
        "b": jnp.zeros((out_dim,), dtype),
    }


def dense_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["w"] + params["b"]


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32) -> Params:
    """sizes = [in, h1, ..., out]; returns {'layers': [dense, ...]}."""
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        "layers": [
            dense_init(k, sizes[i], sizes[i + 1], dtype)
            for i, k in enumerate(keys)
        ]
    }


def mlp_apply(
    params: Params,
    x: jnp.ndarray,
    activation: Callable = leaky_relu,
    final_activation: Callable | None = None,
) -> jnp.ndarray:
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        x = dense_apply(layer, x)
        if i < n - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


# ---------------------------------------------------------------- CNN ablation
def conv1d_init(key, in_ch: int, out_ch: int, width: int, dtype=jnp.float32):
    return {
        "w": he_init(key, (width, in_ch, out_ch), dtype),
        "b": jnp.zeros((out_ch,), dtype),
    }


def conv1d_apply(params: Params, x: jnp.ndarray, stride: int = 1):
    """x: (batch, length, channels) -> (batch, length', out_channels)."""
    out = jax.lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(stride,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )
    return out + params["b"]


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
