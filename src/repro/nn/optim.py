"""Adam optimizer for the agent networks (small models, host-resident).

The large-model optimizer (ZeRO-sharded AdamW + factored second moment)
lives in ``repro.optim``; this one is intentionally dependency-free and
keeps the MRSch agent self-contained.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.zeros_like, params))


def adam_update(grads, state: AdamState, params, lr=1e-4, b1=0.9, b2=0.999,
                eps=1e-8, weight_decay=0.0, grad_clip=None):
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    t = step.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1 - b1 ** t)
    nu_hat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
        if weight_decay:
            u = u + weight_decay * p
        return p - lr * u

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)
