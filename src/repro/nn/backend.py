"""Pluggable execution backend for dense/MLP forwards.

Two backends run the same ``{'layers': [{'w','b'}, ...]}`` param pytree
(checkpoints are backend-agnostic — switching backends never touches
the parameter layout):

* ``"xla"``    — plain jnp ops (``dense_apply`` + activation), the
                 reference path; bit-identical to the historical
                 ``mlp_apply`` pipeline.
* ``"pallas"`` — every layer runs through the fused matmul+bias+act
                 Pallas kernel (``repro.kernels.fused_mlp``), forward
                 AND backward (custom VJP with fused dgrad/wgrad), so
                 jitted gradient bursts stay inside the kernel layer.
                 Compiled on TPU, interpret-mode fallback elsewhere.

Activations are named (strings), not callables, so the Pallas epilogue
can fuse them; ``None`` means linear.
"""
from __future__ import annotations

from typing import Optional

from ..kernels.fused_mlp.kernel import _apply_activation, _check_activation
from ..kernels.fused_mlp.ops import fused_mlp
from .modules import Params, dense_apply

BACKENDS = ("xla", "pallas")


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown nn backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    return backend


def _named_activation(y, activation: Optional[str], slope: float):
    # Single dispatch table with the Pallas epilogue (kernel.py), so an
    # activation added there is automatically available on both backends.
    if activation is None:
        return y
    _check_activation(activation)
    return _apply_activation(y, activation, slope)


def dense_forward(layer: Params, x, activation: Optional[str] = None, *,
                  slope: float = 0.2, backend: str = "xla",
                  interpret: Optional[bool] = None):
    """One dense layer + optional named activation on the given backend."""
    if resolve_backend(backend) == "pallas":
        return fused_mlp(x, layer["w"], layer["b"],
                         activation=activation or "linear", slope=slope,
                         interpret=interpret)
    return _named_activation(dense_apply(layer, x), activation, slope)


def mlp_forward(params: Params, x, hidden_activation: str = "leaky_relu",
                final_activation: Optional[str] = None, *,
                slope: float = 0.2, backend: str = "xla",
                interpret: Optional[bool] = None):
    """MLP forward with named activations, dispatched per backend.

    ``backend="xla"`` reproduces ``mlp_apply`` (+ optional trailing
    activation) exactly; ``backend="pallas"`` runs each layer through
    the fused kernel.
    """
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        act = hidden_activation if i < n - 1 else final_activation
        x = dense_forward(layer, x, act, slope=slope, backend=backend,
                          interpret=interpret)
    return x
