"""Standing policy tournament: zoo x scenario x seed with a leaderboard.

The paper's §V evidence compares MRSch against FCFS/GA/ScalarRL once;
the related work fields a stronger lineup.  This module runs the full
baseline zoo (``repro.baselines``: PRB-EWT, the CP window-packing
dispatcher, a DRAS-style two-level agent, an RL co-scheduler variant)
plus the paper's four methods as a round-robin on the vector engine —
every entrant over every (scenario, seed) cell, reusing the
``run_matrix`` cell plumbing so traces are shared and rows stay in the
stable matrix schema — and derives the standings:

* per-policy aggregates (mean metrics over cells) — the per-policy
  section CI gates against ``benchmarks/baselines/tournament.json``;
* per-metric ranks (direction-aware: waits rank ascending,
  utilizations descending);
* head-to-head win rates on the per-cell kiviat score;
* MRSch's relative wait improvement over every baseline — the paper's
  "up to 48%" headline, recomputed against the stronger field on
  every run.

Output is a stable ``mrsch.eval.tournament/v1`` JSON plus a rendered
markdown leaderboard (the nightly CI lane appends it to the step
summary).  Everything except ``summary.wall_seconds`` is deterministic
for a fixed seed.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from ..baselines import (CoSchedConfig, CoSchedPolicy, CPConfig, CPDispatcher,
                         DRASConfig, DRASPolicy, PRBConfig, PRBPolicy)
from ..obs.trace import NULL, Tracer
from ..sim.cluster import ResourceSpec
from ..workloads.theta import ThetaConfig
from .matrix import (MatrixConfig, PolicyFactory, default_policies,
                     kiviat_scores, run_matrix)

TOURNAMENT_SCHEMA = "mrsch.eval.tournament/v1"

# Leaderboard row keys, in order (tests pin this; util_<r> columns are
# appended per cluster resource before the trailing improvement column).
LEADERBOARD_CORE = ("rank", "policy", "overall_score", "wins",
                    "h2h_win_rate", "avg_wait", "avg_slowdown", "p95_wait")
LEADERBOARD_TAIL = ("wait_improvement_vs",)

# Metrics ranked per-policy (direction-aware), beyond the util_* columns.
RANK_LOWER = ("avg_wait", "avg_slowdown", "avg_bounded_slowdown", "p95_wait")


@dataclass(frozen=True)
class TournamentConfig:
    scenarios: Tuple[str, ...]
    seeds: Tuple[int, ...] = (1,)
    window: int = 10
    backfill: bool = True
    vector: int = 8
    reference: str = "MRSch"         # policy the improvement figure targets

    def matrix_config(self) -> MatrixConfig:
        return MatrixConfig(scenarios=self.scenarios, seeds=self.seeds,
                            window=self.window, backfill=self.backfill,
                            vector=self.vector)


def zoo_policies(resources: Sequence[ResourceSpec], agent=None,
                 window: int = 10, seed: int = 0,
                 **default_kw) -> Dict[str, PolicyFactory]:
    """The full tournament field: the paper's four methods plus the
    literature zoo.  Stateless/shared entrants reuse one instance;
    ``default_policies`` keeps its own conventions for the originals."""
    out = default_policies(resources, agent=agent, **default_kw)
    prb = PRBPolicy(resources, PRBConfig(window=window))
    out["PRB-EWT"] = lambda: prb
    cp = CPDispatcher(CPConfig(window=window))
    out["CP-Dispatch"] = lambda: cp
    dras = DRASPolicy(resources, DRASConfig(window=window, seed=seed))
    out["DRAS"] = lambda: dras
    cosched = CoSchedPolicy(resources, CoSchedConfig(window=window, seed=seed))
    out["CoSchedRL"] = lambda: cosched
    return out


def leaderboard_columns(resources: Sequence[ResourceSpec]) -> List[str]:
    return (list(LEADERBOARD_CORE)
            + [f"util_{r.name}" for r in resources]
            + list(LEADERBOARD_TAIL))


# ------------------------------------------------------------- standings
def _cell_scores(rows: Sequence[Dict]) -> Dict[Tuple[str, int], Dict[str, float]]:
    """Per-(scenario, seed) kiviat score of every policy present."""
    by_cell: Dict[Tuple[str, int], List[Dict]] = {}
    for r in rows:
        by_cell.setdefault((r["scenario"], r["seed"]), []).append(r)
    return {cell: kiviat_scores(cell_rows, key="policy")
            for cell, cell_rows in by_cell.items()}


def _aggregates(rows: Sequence[Dict], metrics: Sequence[str]
                ) -> Dict[str, Dict[str, float]]:
    agg: Dict[str, Dict[str, List[float]]] = {}
    for r in rows:
        acc = agg.setdefault(r["policy"], {m: [] for m in metrics})
        for m in metrics:
            acc[m].append(float(r[m]))
    return {p: {m: round(sum(v) / len(v), 4) for m, v in acc.items()}
            for p, acc in agg.items()}


def _ranks(agg: Mapping[str, Mapping[str, float]], metric: str,
           lower_is_better: bool) -> Dict[str, int]:
    """1 = best; deterministic tie-break on policy name."""
    order = sorted(agg, key=lambda p: (
        agg[p][metric] if lower_is_better else -agg[p][metric], p))
    return {p: i + 1 for i, p in enumerate(order)}


def _head_to_head(cell_scores: Mapping, policies: Sequence[str]
                  ) -> Dict[str, Dict[str, float]]:
    """h2h[p][q] = fraction of shared cells where p outscores q."""
    h2h: Dict[str, Dict[str, float]] = {}
    for p in policies:
        h2h[p] = {}
        for q in policies:
            if q == p:
                continue
            shared = [s for s in cell_scores.values() if p in s and q in s]
            if not shared:
                continue
            wins = sum(1 for s in shared if s[p] > s[q])
            h2h[p][q] = round(wins / len(shared), 4)
    return h2h


def run_tournament(policies: Mapping[str, PolicyFactory],
                   resources: Sequence[ResourceSpec], theta: ThetaConfig,
                   cfg: TournamentConfig, tracer: Tracer = NULL) -> Dict:
    """Round-robin every policy over every (scenario, seed) cell and
    derive the standings (see module docstring for the sections).
    ``tracer`` is threaded through to ``run_matrix`` (one
    ``mrsch.trace/v1`` stream covering the whole round-robin)."""
    matrix = run_matrix(policies, resources, theta, cfg.matrix_config(),
                        tracer=tracer)
    rows = matrix["rows"]
    util_cols = [f"util_{r.name}" for r in resources]
    metrics = list(RANK_LOWER) + util_cols
    agg = _aggregates(rows, metrics)
    cell_scores = _cell_scores(rows)
    present = sorted(agg)

    overall = {p: round(sum(s[p] for s in cell_scores.values() if p in s)
                        / max(sum(1 for s in cell_scores.values() if p in s),
                              1), 4)
               for p in present}
    wins = {p: sum(1 for s in cell_scores.values()
                   if p in s and s[p] == max(s.values())) for p in present}
    h2h = _head_to_head(cell_scores, present)
    h2h_rate = {p: round(sum(h2h[p].values()) / max(len(h2h[p]), 1), 4)
                for p in present}

    ranks = {m: _ranks(agg, m, lower_is_better=m in RANK_LOWER)
             for m in metrics}

    ref = cfg.reference
    improvement: Dict[str, float] = {}
    if ref in agg:
        for p in present:
            if p == ref:
                continue
            base = max(agg[p]["avg_wait"], 1e-9)
            improvement[p] = round((base - agg[ref]["avg_wait"]) / base, 4)

    lb_order = sorted(present, key=lambda p: (-overall[p], p))
    leaderboard = []
    for i, p in enumerate(lb_order):
        entry = {"rank": i + 1, "policy": p, "overall_score": overall[p],
                 "wins": wins[p], "h2h_win_rate": h2h_rate[p],
                 "avg_wait": agg[p]["avg_wait"],
                 "avg_slowdown": agg[p]["avg_slowdown"],
                 "p95_wait": agg[p]["p95_wait"]}
        for c in util_cols:
            entry[c] = agg[p][c]
        entry["wait_improvement_vs"] = improvement.get(p)
        leaderboard.append(entry)

    return {
        "schema": TOURNAMENT_SCHEMA,
        "columns": matrix["columns"],
        "leaderboard_columns": leaderboard_columns(resources),
        "config": {**matrix["config"], "reference": ref},
        "rows": rows,
        "leaderboard": leaderboard,
        "per_policy": agg,
        "ranks": ranks,
        "head_to_head": h2h,
        "relative_improvement": {
            "reference": ref,
            "vs": improvement,
            "max": round(max(improvement.values()), 4) if improvement else None,
        },
        "summary": {
            **matrix["summary"],
            "n_policies": len(present),
            "leader": lb_order[0] if lb_order else None,
        },
    }


# --------------------------------------------------------------- rendering
def render_leaderboard(t: Dict) -> str:
    """Markdown standings (the nightly lane appends this to the CI step
    summary, so keep it a plain table — no HTML)."""
    cfgt = t["config"]
    ref = t["relative_improvement"]["reference"]
    cols = t["leaderboard_columns"]
    head = {"rank": "#", "policy": "policy", "overall_score": "overall",
            "wins": "wins", "h2h_win_rate": "h2h win%",
            "avg_wait": "wait (s)", "avg_slowdown": "slowdown",
            "p95_wait": "p95 wait (s)",
            "wait_improvement_vs": f"{ref} wait cut"}
    lines = [
        "# Tournament leaderboard",
        "",
        f"{len(t['leaderboard'])} policies x {len(cfgt['scenarios'])} "
        f"scenarios x {len(cfgt['seeds'])} seeds "
        f"({t['summary']['n_cells']} cells); overall = mean per-cell kiviat "
        "score (1 = best on every axis).",
        "",
        "| " + " | ".join(head.get(c, c) for c in cols) + " |",
        "|" + "---|" * len(cols),
    ]
    for e in t["leaderboard"]:
        cells = []
        for c in cols:
            v = e[c]
            if c == "wait_improvement_vs":
                v = "—" if v is None else f"{v:+.1%}"
            elif c == "h2h_win_rate":
                v = f"{v:.0%}"
            elif isinstance(v, float):
                v = f"{v:.4g}"
            cells.append(str(v))
        lines.append("| " + " | ".join(cells) + " |")
    imp = t["relative_improvement"]
    if imp["vs"]:
        best = max(imp["vs"], key=lambda p: imp["vs"][p])
        lines += [
            "",
            f"**{ref} relative wait improvement** (the paper's §V headline, "
            f"re-litigated against the full field): up to "
            f"**{imp['max']:+.1%}** (vs {best}); "
            + ", ".join(f"{p}: {v:+.1%}"
                        for p, v in sorted(imp["vs"].items())) + ".",
        ]
    lines += ["", "## Head-to-head win rate (row beats column)", ""]
    pols = [e["policy"] for e in t["leaderboard"]]
    lines.append("| | " + " | ".join(pols) + " |")
    lines.append("|" + "---|" * (len(pols) + 1))
    for p in pols:
        row = [f"**{p}**"]
        for q in pols:
            row.append("—" if q == p
                       else f"{t['head_to_head'][p].get(q, 0.0):.0%}")
        lines.append("| " + " | ".join(row) + " |")
    fails = t["summary"].get("failures") or []
    if fails:
        lines += ["", "## FAILED policies", ""]
        for f in fails:
            lines.append(f"- **{f['policy']}**: {f['error']} "
                         f"({len(f['cells'])} cells lost)")
    return "\n".join(lines) + "\n"


def save_tournament(t: Dict, json_path: str,
                    md_path: Optional[str] = None) -> Tuple[str, str]:
    """Write the JSON standings plus the rendered leaderboard.md."""
    import json
    os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(t, f, indent=1, default=float)
    md_path = md_path or os.path.join(
        os.path.dirname(json_path), "leaderboard.md")
    with open(md_path, "w") as f:
        f.write(render_leaderboard(t))
    return json_path, md_path
