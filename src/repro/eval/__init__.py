"""Evaluation harnesses pitting MRSch against its baselines at scale."""
from .matrix import (MATRIX_SCHEMA, MatrixConfig, default_policies,
                     eval_factory, kiviat_scores, matrix_columns, matrix_csv, run_matrix,
                     save_matrix)

__all__ = [
    "MATRIX_SCHEMA", "MatrixConfig", "default_policies", "eval_factory",
    "kiviat_scores",
    "matrix_columns", "matrix_csv", "run_matrix", "save_matrix",
]
