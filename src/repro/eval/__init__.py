"""Evaluation harnesses pitting MRSch against its baselines at scale."""
from .matrix import (MATRIX_SCHEMA, MatrixConfig, default_policies,
                     eval_factory, kiviat_scores, matrix_columns, matrix_csv, run_matrix,
                     save_matrix)
from .tournament import (TOURNAMENT_SCHEMA, TournamentConfig,
                         leaderboard_columns, render_leaderboard,
                         run_tournament, save_tournament, zoo_policies)

__all__ = [
    "MATRIX_SCHEMA", "MatrixConfig", "default_policies", "eval_factory",
    "kiviat_scores",
    "matrix_columns", "matrix_csv", "run_matrix", "save_matrix",
    "TOURNAMENT_SCHEMA", "TournamentConfig", "leaderboard_columns",
    "render_leaderboard", "run_tournament", "save_tournament", "zoo_policies",
]
