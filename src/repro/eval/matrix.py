"""Policy x scenario evaluation matrix on the batched rollout engine.

The paper's §V evidence is a grid: every policy (MRSch, FCFS, GA,
ScalarRL) against every workload scenario, one ``ScheduleMetrics`` row
per cell.  This module is the single harness that produces that grid —
for the Table III families, the new registry scenarios, and the §V-D
drift workloads alike — and emits it in a *stable* JSON/CSV schema so CI
can diff runs against committed baselines (``tools/check_bench.py``).

Policies are probed through the ``repro.core.policy_api`` helpers:
``supports_batch`` instances (MRSch, FCFS, ScalarRL) are fanned over
``VectorSimulator`` so every lockstep round costs one batched forward;
stateful sequential policies (GA) run through
``VectorSimulator.from_factory`` with one fresh instance per environment.

Schema stability contract (``MATRIX_SCHEMA`` bumps on change):
``columns`` lists every row key in order; each row is one (policy,
scenario, seed) cell; metric values are rounded to 4 decimals and are
deterministic for a fixed config/seed (no wall-clock noise in rows —
timing lives under ``summary``).
"""
from __future__ import annotations

import io
import json
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.policies import (FCFSPolicy, GAConfig, GAOptimizer,
                             ScalarRLConfig, ScalarRLPolicy)
from ..core.policy_api import supports_batch
from ..obs.profiling import span
from ..obs.trace import NULL, Tracer
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SimConfig, SimResult
from ..sim.vector import VectorSimulator
from ..workloads.registry import build_jobs, get_scenario
from ..workloads.theta import ThetaConfig

MATRIX_SCHEMA = "mrsch.eval.matrix/v1"

CORE_COLUMNS = ("policy", "scenario", "family", "drift", "seed",
                "decisions", "n_unstarted")
METRIC_COLUMNS = ("avg_wait", "avg_slowdown", "avg_bounded_slowdown",
                  "p95_wait", "max_wait", "n_jobs", "makespan",
                  "truncated_jobs",
                  # lifecycle metrics (workflow/fault scenarios) — appended
                  # last: committed baselines prefix-compare their columns
                  "requeues", "n_failed", "failed_node_hours",
                  "completed_work_frac", "pipeline_makespan")

PolicyFactory = Callable[[], object]


@dataclass(frozen=True)
class MatrixConfig:
    scenarios: Tuple[str, ...]
    seeds: Tuple[int, ...] = (1,)
    window: int = 10
    backfill: bool = True
    vector: int = 8                  # lockstep width for batched policies


def matrix_columns(resources: Sequence[ResourceSpec]) -> List[str]:
    """Row keys, in order — the schema CI pins against."""
    return (list(CORE_COLUMNS)
            + [f"util_{r.name}" for r in resources]
            + list(METRIC_COLUMNS))


def default_policies(resources: Sequence[ResourceSpec], agent=None,
                     scalar_rl: Optional[ScalarRLPolicy] = None,
                     ga: GAConfig = GAConfig(population=12, generations=8),
                     ) -> Dict[str, PolicyFactory]:
    """The paper's four methods as matrix-ready factories.

    Pass a trained ``agent`` / ``scalar_rl`` for paper-faithful numbers;
    untrained instances still exercise the full grid (CI smoke).  GA's
    factory returns a FRESH optimizer per environment (its plan cache is
    per-trace).
    """
    out: Dict[str, PolicyFactory] = {"FCFS": FCFSPolicy}
    out["GA"] = lambda: GAOptimizer(ga)
    rl = scalar_rl or ScalarRLPolicy(resources, ScalarRLConfig(hidden=(256, 64)))
    out["ScalarRL"] = lambda: rl
    if agent is not None:
        out["MRSch"] = lambda: agent
    return out


def _row(policy: str, scenario: str, seed: int, result: SimResult,
         resources: Sequence[ResourceSpec]) -> Dict[str, object]:
    spec = get_scenario(scenario)
    row: Dict[str, object] = {
        "policy": policy, "scenario": scenario, "family": spec.family,
        "drift": spec.drift is not None, "seed": seed,
        "decisions": result.decisions, "n_unstarted": result.n_unstarted,
    }
    metrics = result.metrics.as_row()
    for col in matrix_columns(resources)[len(CORE_COLUMNS):]:
        row[col] = round(float(metrics[col]), 4)
    return row


def _check_power(scenarios: Sequence[str],
                 resources: Sequence[ResourceSpec]) -> None:
    names = {r.name for r in resources}
    needy = [s for s in scenarios
             if "power" in get_scenario(s).tags and "power" not in names]
    if needy:
        raise ValueError(
            f"scenarios {needy} carry power demands but the cluster has no "
            "'power' resource — build resources with "
            "cfg.resources(power_budget_kw=cfg.default_power_budget_kw())")


def eval_factory(factory: PolicyFactory) -> PolicyFactory:
    """Wrap a factory so every produced instance is in evaluation mode
    (learning baselines must not train inside the matrix)."""
    def make():
        policy = factory()
        if getattr(policy, "training", False):
            policy.training = False
        return policy
    return make


def run_matrix(policies: Mapping[str, PolicyFactory],
               resources: Sequence[ResourceSpec], theta: ThetaConfig,
               cfg: MatrixConfig, tracer: Tracer = NULL) -> Dict:
    """Evaluate every policy over every (scenario, seed) cell.

    Traces are built once per cell and shared across policies, so every
    policy sees the identical workload.  Policies exposing ``training``
    are forced into evaluation mode for the run (restored afterwards).

    ``tracer`` receives the full ``mrsch.trace/v1`` event stream of every
    cell.  Environment ids are globally unique across the grid —
    ``env = policy_index * n_cells + cell_index`` — and the tracer's
    ``meta["envs"]`` (when it records meta, e.g. ``BufferTracer``) maps
    each id back to its (policy, scenario, seed).  Each policy's grid
    sweep is additionally wrapped in a ``prof.span`` named
    ``policy:<name>`` so per-policy decision latency can be read straight
    from the trace (``tools/trace_report.py``).

    Partial-failure contract: one policy crashing must not silently
    shrink the grid.  Its remaining cells are recorded under
    ``summary.failures`` (with the exception text) while every other
    policy's rows are kept; callers that need a hard stop check
    ``summary.failures`` and exit non-zero (the bench entry points do).
    """
    _check_power(cfg.scenarios, resources)
    t0 = time.perf_counter()
    cells: List[Tuple[str, int]] = [(s, seed) for s in cfg.scenarios
                                    for seed in cfg.seeds]
    traces = {cell: build_jobs(cell[0], theta, seed=cell[1])
              for cell in cells}
    sim_cfg = SimConfig.for_engine("vector", window=cfg.window,
                                   backfill=cfg.backfill)
    meta = getattr(tracer, "meta", None)
    if meta is not None:
        envs = meta.setdefault("envs", {})
        for p, name in enumerate(policies):
            for c, (scenario, seed) in enumerate(cells):
                envs[str(p * len(cells) + c)] = {
                    "policy": name, "scenario": scenario, "seed": seed}
    rows: List[Dict] = []
    failures: List[Dict] = []
    batched_policies = 0
    for p_idx, (name, factory) in enumerate(policies.items()):
        try:
            probe = factory()
        except Exception as e:
            failures.append({"policy": name,
                             "cells": [list(c) for c in cells],
                             "error": f"{type(e).__name__}: {e}"})
            continue
        batched = supports_batch(probe)
        batched_policies += bool(batched)
        # Batched policies share the probe instance, so eval mode is
        # toggled here; factory-path instances are wrapped per env by
        # eval_factory instead.
        was_training = getattr(probe, "training", None) if batched else None
        if was_training:
            probe.training = False
        width = max(cfg.vector, 1)
        try:
            for i in range(0, len(cells), width):
                chunk = cells[i:i + width]
                jobsets = [traces[c] for c in chunk]
                # Scenario fault plans ride alongside the trace: the engine
                # consumes them directly (they are not job attributes).
                flist = [get_scenario(s).faults for s, _ in chunk]
                eids = [p_idx * len(cells) + i + j
                        for j in range(len(chunk))]
                try:
                    if batched:
                        vec = VectorSimulator.from_jobsets(resources, jobsets,
                                                           probe, sim_cfg,
                                                           faults=flist,
                                                           tracer=tracer,
                                                           env_ids=eids)
                    else:
                        vec = VectorSimulator.from_factory(resources, jobsets,
                                                           eval_factory(factory),
                                                           sim_cfg,
                                                           faults=flist,
                                                           tracer=tracer,
                                                           env_ids=eids)
                    with span(tracer, f"policy:{name}"):
                        chunk_results = vec.run()
                except Exception as e:
                    # All cells this policy has not completed are failed —
                    # a crash mid-grid must not read as a smaller grid.
                    failures.append({"policy": name,
                                     "cells": [list(c) for c in cells[i:]],
                                     "error": f"{type(e).__name__}: {e}"})
                    break
                for (scenario, seed), result in zip(chunk, chunk_results):
                    rows.append(_row(name, scenario, seed, result, resources))
        finally:
            if was_training:
                probe.training = was_training
    return {
        "schema": MATRIX_SCHEMA,
        "columns": matrix_columns(resources),
        "config": {
            "scenarios": list(cfg.scenarios), "seeds": list(cfg.seeds),
            "policies": list(policies), "window": cfg.window,
            "backfill": cfg.backfill, "vector": cfg.vector,
            "n_nodes": theta.n_nodes, "bb_units": theta.bb_units,
            "duration_days": theta.duration_days,
            "resources": [r.name for r in resources],
        },
        "rows": rows,
        "summary": {
            "n_cells": len(rows),
            "batched_policies": batched_policies,
            "wins": _wins(rows),
            "failures": failures,
            "n_failed_cells": sum(len(f["cells"]) for f in failures),
            "wall_seconds": round(time.perf_counter() - t0, 3),
        },
    }


def kiviat_scores(rows: Sequence[Dict], key: str = "method") -> Dict[str, float]:
    """Normalized overall score (Fig. 7 area proxy): mean over
    [util_<resource>..., 1/wait, 1/slowdown], each scaled so the best
    method = 1.  The single scorer behind both the per-figure benches
    (``benchmarks.common``) and the matrix ``wins`` summary."""
    axes = [k for k in rows[0] if k.startswith("util_")]
    vals = {}
    for r in rows:
        v = [r[a] for a in axes]
        v.append(1.0 / max(r["avg_wait"], 1e-9))
        v.append(1.0 / max(r["avg_slowdown"], 1e-9))
        vals[r[key]] = np.array(v)
    stack = np.stack(list(vals.values()))
    best = stack.max(axis=0) + 1e-12
    return {m: float((v / best).mean()) for m, v in vals.items()}


def _wins(rows: Sequence[Dict]) -> Dict[str, int]:
    """Per-policy count of (scenario, seed) cells won on the kiviat proxy."""
    by_cell: Dict[Tuple[str, int], List[Dict]] = {}
    for r in rows:
        by_cell.setdefault((r["scenario"], r["seed"]), []).append(r)
    wins: Dict[str, int] = {}
    for cell_rows in by_cell.values():
        scores = kiviat_scores(cell_rows, key="policy")
        winner = max(scores, key=scores.get)
        wins[winner] = wins.get(winner, 0) + 1
    return dict(sorted(wins.items()))


# ------------------------------------------------------------------ output
def matrix_csv(matrix: Dict) -> str:
    """Rows as CSV, header = ``matrix['columns']`` (the stable order)."""
    buf = io.StringIO()
    cols = matrix["columns"]
    buf.write(",".join(cols) + "\n")
    for row in matrix["rows"]:
        buf.write(",".join(str(row[c]) for c in cols) + "\n")
    return buf.getvalue()


def save_matrix(matrix: Dict, json_path: str,
                csv_path: Optional[str] = None) -> Tuple[str, str]:
    """Write the JSON grid plus its CSV twin (defaults to .csv sibling)."""
    os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(matrix, f, indent=1, default=float)
    csv_path = csv_path or os.path.splitext(json_path)[0] + ".csv"
    with open(csv_path, "w") as f:
        f.write(matrix_csv(matrix))
    return json_path, csv_path
