"""Pallas TPU kernel: causal FlashAttention (online softmax).

Grid = (batch*heads, n_q_blocks, n_k_blocks) with the K loop innermost; the
running max m, normalizer l and f32 output accumulator persist in VMEM
scratch across K steps of one (bh, qi) tile.  Causal masking is positional;
blocks entirely above the diagonal contribute nothing (masked to -inf;
the `ops` wrapper also clips the K grid per Q block via masking — on real
TPUs a further win is to skip those blocks with a scalar prefetch grid,
noted in EXPERIMENTS §Perf).

Tiles: q (bq x dh), k/v (bk x dh), MXU-aligned (bq, bk multiples of 128
for bf16; dh 64-256 as the model dictates).

Alongside the causal kernel live the masked non-causal variants backing
the queue-as-tokens encoder (``repro.nn.queue_encoder``): a forward that
masks to a *per-row* KV length and emits log-sum-exp rows
(``mha_fwd_kernel``), and the dq / dkv backward kernels
(``mha_bwd_kernels``) that recompute p from (q, k, lse) flash-style —
wired into a ``jax.custom_vjp`` by ``ops.mha``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, block_q: int, block_k: int, scale: float,
                  causal: bool, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (bq, dh)
    k = k_ref[0]                                     # (bk, dh)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_len
    if causal:
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _lengths_spec(block_q: int, grid_axis: int):
    """BlockSpec for the per-row (BH, 1) lengths input: every (qi, ki)
    step of one batch-head row sees the same scalar."""
    del block_q, grid_axis
    return pl.BlockSpec((1, 1), lambda b, i, j: (b, 0))


def _mha_fwd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                    m_ref, l_ref, acc_ref, *,
                    n_k: int, block_q: int, block_k: int, scale: float):
    """Non-causal forward masked to a per-row KV length, emitting the
    log-sum-exp rows the backward kernels recompute p from.

    Differences from ``_flash_kernel``: the mask bound is a per-(batch,
    head) runtime value rather than a static scalar, and ``p`` is
    multiplied by the mask — when a row is fully masked every score is
    NEG_INF, so ``m_new == NEG_INF`` and ``exp(s - m_new)`` would be 1
    for the masked entries; the multiply keeps ``l == 0`` and the output
    exactly zero (matching the masked reference) instead of garbage.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (bq, dh)
    k = k_ref[0]                                     # (bk, dh)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos.astype(jnp.float32) < len_ref[0, 0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        # Clamp so fully-masked rows store a huge-negative (finite) lse:
        # the backward's exp(s - lse) then stays finite and the mask
        # multiply zeroes it, instead of inf - inf = NaN.
        lse_ref[0] = (m_ref[...] + jnp.log(jnp.maximum(l, 1e-30)))[:, 0]


def mha_fwd_kernel(q, k, v, lengths, *, block_q: int = 128,
                   block_k: int = 128, interpret: bool = False):
    """q (BH, Sq, dh), k/v (BH, Sk, dh) padded to block multiples;
    ``lengths`` (BH,) float32 true KV lengths.  Returns (o, lse)."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    kernel = functools.partial(_mha_fwd_kernel, n_k=n_k, block_q=block_q,
                               block_k=block_k, scale=dh ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            _lengths_spec(block_q, 2),
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # normalizer
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(lengths.reshape(BH, 1).astype(jnp.float32), q, k, v)


def _mha_bwd_dq_kernel(len_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                       delta_ref, dq_ref, acc_ref, *,
                       n_k: int, block_k: int, scale: float):
    """dq for one (bh, qi) tile, accumulated over K blocks:
    p = exp(s - lse) * mask; ds = p * (do @ v^T - delta); dq = ds @ k."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q, k, v = q_ref[0], k_ref[0], v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos.astype(jnp.float32) < len_ref[0, 0]).astype(jnp.float32)
    p = jnp.exp(s - lse_ref[0][:, None]) * mask
    dp = jnp.dot(do_ref[0], v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None]) * scale
    acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _mha_bwd_dkv_kernel(len_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                        delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
                        n_q: int, block_k: int, scale: float):
    """dk/dv for one (bh, ki) tile, accumulated over Q blocks:
    dv = p^T @ do; dk = ds^T @ q (same recomputed p/ds as the dq pass)."""
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q, k, v = q_ref[0], k_ref[0], v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = (pl.program_id(1) * k.shape[0]
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    mask = (kpos.astype(jnp.float32) < len_ref[0, 0]).astype(jnp.float32)
    p = jnp.exp(s - lse_ref[0][:, None]) * mask
    do = do_ref[0]
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None]) * scale
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def mha_bwd_kernels(q, k, v, do, lse, delta, lengths, *,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Backward of ``mha_fwd_kernel``: returns (dq, dk, dv).

    All sequence axes must already be padded to block multiples; ``do``
    must be zero in padded query rows (the ops wrapper pads with zeros),
    so padded rows contribute nothing to dk/dv.
    """
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = dh ** -0.5
    lens2 = lengths.reshape(BH, 1).astype(jnp.float32)

    dq = pl.pallas_call(
        functools.partial(_mha_bwd_dq_kernel, n_k=n_k, block_k=block_k,
                          scale=scale),
        grid=(BH, n_q, n_k),
        in_specs=[
            _lengths_spec(block_q, 2),
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),  # q
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),  # k
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),  # v
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),  # do
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),         # lse
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),         # delta
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, dh), jnp.float32)],
        interpret=interpret,
    )(lens2, q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_mha_bwd_dkv_kernel, n_q=n_q, block_k=block_k,
                          scale=scale),
        grid=(BH, n_k, n_q),
        in_specs=[
            _lengths_spec(block_q, 2),
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, j, 0)),  # q
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, i, 0)),  # k
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, i, 0)),  # v
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, j, 0)),  # do
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, j)),         # lse
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, j)),         # delta
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sk, dh), k.dtype),
            jax.ShapeDtypeStruct((BH, Sk, dh), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, dh), jnp.float32),
            pltpu.VMEM((block_k, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lens2, q, k, v, do, lse, delta)
    return dq, dk, dv


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           kv_len: int | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q (BH, Sq, dh), k/v (BH, Sk, dh) — padded to block multiples by ops.
    ``kv_len`` = true (unpadded) KV length for masking."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = dh ** -0.5
    kernel = functools.partial(
        _flash_kernel, n_k=n_k, block_q=block_q, block_k=block_k,
        scale=scale, causal=causal, seq_len=kv_len or Sk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # normalizer
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
