"""Pallas TPU kernel: causal FlashAttention (online softmax).

Grid = (batch*heads, n_q_blocks, n_k_blocks) with the K loop innermost; the
running max m, normalizer l and f32 output accumulator persist in VMEM
scratch across K steps of one (bh, qi) tile.  Causal masking is positional;
blocks entirely above the diagonal contribute nothing (masked to -inf;
the `ops` wrapper also clips the K grid per Q block via masking — on real
TPUs a further win is to skip those blocks with a scalar prefetch grid,
noted in EXPERIMENTS §Perf).

Tiles: q (bq x dh), k/v (bk x dh), MXU-aligned (bq, bk multiples of 128
for bf16; dh 64-256 as the model dictates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, block_q: int, block_k: int, scale: float,
                  causal: bool, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (bq, dh)
    k = k_ref[0]                                     # (bk, dh)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_len
    if causal:
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           kv_len: int | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q (BH, Sq, dh), k/v (BH, Sk, dh) — padded to block multiples by ops.
    ``kv_len`` = true (unpadded) KV length for masking."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = dh ** -0.5
    kernel = functools.partial(
        _flash_kernel, n_k=n_k, block_q=block_q, block_k=block_k,
        scale=scale, causal=causal, seq_len=kv_len or Sk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # normalizer
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
