"""jit'd public wrapper: GQA-aware flash attention.

Flattens (B, H) -> BH, repeats KV heads to query heads (simple v1 GQA;
a grouped-DOT kernel that avoids the repeat is a recorded §Perf follow-up),
pads sequence lengths to block multiples, and calls the Pallas kernel
(interpret mode on CPU, compiled on TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...obs.profiling import named_scope
from .kernel import flash_attention_kernel, mha_bwd_kernels, mha_fwd_kernel


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q (B, Sq, H, dh); k/v (B, Sk, KV, dh); returns (B, Sq, H, dh)."""
    with named_scope("mrsch.kernel.flash_attention"):
        return _flash_attention_impl(q, k, v, causal=causal, block_q=block_q,
                                     block_k=block_k, interpret=interpret)


def _flash_attention_impl(q, k, v, *, causal, block_q, block_k, interpret):
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, dh)

    pad_q = (-Sq) % block_q
    Sk = kf.shape[1]
    pad_k = (-Sk) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_kernel(qf, kf, vf, causal=causal, block_q=block_q,
                                 block_k=block_k, kv_len=Sk,
                                 interpret=interpret)
    out = out[:, :Sq]
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)


# ------------------------------------------------------------ masked mha
# Differentiable non-causal attention over per-row variable-length token
# sets — the hot path of the queue-as-tokens state encoder
# (repro.nn.queue_encoder).  Mirrors fused_mlp/ops.py: a custom_vjp whose
# forward and backward both run Pallas kernels, padding handled inside
# the vjp boundary, interpret-mode fallback off TPU.

def _pad_seq(x, mult: int):
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)


def default_interpret() -> bool:
    """Interpret-mode unless a real TPU is attached (fused_mlp semantics)."""
    return jax.default_backend() != "tpu"


def _mha_fwd_impl(q, k, v, lengths, block_q, block_k, interpret):
    with named_scope("mrsch.kernel.mha_fwd"):
        Sq = q.shape[1]
        o, lse = mha_fwd_kernel(
            _pad_seq(q, block_q), _pad_seq(k, block_k), _pad_seq(v, block_k),
            lengths, block_q=block_q, block_k=block_k, interpret=interpret)
        return o[:, :Sq], lse[:, :Sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _mha(q, k, v, lengths, block_q, block_k, interpret):
    return _mha_fwd_impl(q, k, v, lengths, block_q, block_k, interpret)[0]


def _mha_fwd(q, k, v, lengths, block_q, block_k, interpret):
    o, lse = _mha_fwd_impl(q, k, v, lengths, block_q, block_k, interpret)
    return o, (q, k, v, lengths, o, lse)


def _mha_bwd(block_q, block_k, interpret, res, do):
    with named_scope("mrsch.kernel.mha_bwd"):
        return _mha_bwd_impl(block_q, block_k, interpret, res, do)


def _mha_bwd_impl(block_q, block_k, interpret, res, do):
    q, k, v, lengths, o, lse = res
    Sq, Sk = q.shape[1], k.shape[1]
    # delta = rowsum(do * o): the softmax-jacobian correction, computed
    # once host-graph-side instead of inside both backward kernels.
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(axis=-1)
    dq, dk, dv = mha_bwd_kernels(
        _pad_seq(q, block_q), _pad_seq(k, block_k), _pad_seq(v, block_k),
        _pad_seq(do, block_q), _pad_seq(lse, block_q),
        _pad_seq(delta, block_q), lengths,
        block_q=block_q, block_k=block_k, interpret=interpret)
    # lengths ride through as a float array (custom_vjp nondiff_argnums
    # cannot carry traced arrays) — their cotangent is defined as zero.
    return (dq[:, :Sq], dk[:, :Sk], dv[:, :Sk], jnp.zeros_like(lengths))


_mha.defvjp(_mha_fwd, _mha_bwd)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def mha(q, k, v, lengths=None, *, block_q: int = 128, block_k: int = 128,
        interpret: bool | None = None):
    """Masked non-causal flash attention, differentiable in q/k/v.

    q (BH, Sq, dh), k/v (BH, Sk, dh); ``lengths`` (BH,) — valid KV tokens
    per batch-head row (keys at positions >= length are masked out; a
    fully-masked row outputs exactly 0, matching ``ref.attention_ref``
    with lengths).  ``None`` means every key is valid.  The backward pass
    runs the fused dq/dkv Pallas kernels via ``jax.custom_vjp``;
    ``interpret=None`` auto-selects interpret mode off TPU.
    """
    if interpret is None:
        interpret = default_interpret()
    BH, _, _ = q.shape
    Sk = k.shape[1]
    if lengths is None:
        lens = jnp.full((BH,), float(Sk), jnp.float32)
    else:
        lens = jnp.minimum(lengths.astype(jnp.float32), float(Sk))
    return _mha(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), lens, block_q, block_k,
                bool(interpret))
