"""jit'd public wrapper: GQA-aware flash attention.

Flattens (B, H) -> BH, repeats KV heads to query heads (simple v1 GQA;
a grouped-DOT kernel that avoids the repeat is a recorded §Perf follow-up),
pads sequence lengths to block multiples, and calls the Pallas kernel
(interpret mode on CPU, compiled on TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q (B, Sq, H, dh); k/v (B, Sk, KV, dh); returns (B, Sq, H, dh)."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, dh)

    pad_q = (-Sq) % block_q
    Sk = kf.shape[1]
    pad_k = (-Sk) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_kernel(qf, kf, vf, causal=causal, block_q=block_q,
                                 block_k=block_k, kv_len=Sk,
                                 interpret=interpret)
    out = out[:, :Sq]
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)
