"""Pure-jnp oracle: dense softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q (BH, Sq, dh), k/v (BH, Sk, dh)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
