"""Pure-jnp oracle: dense softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, lengths=None):
    """q (BH, Sq, dh), k/v (BH, Sk, dh).

    ``lengths`` (BH,), when given, masks keys at positions >= length per
    batch-head row; a row with length 0 outputs exactly 0 (the masked
    softmax weights are zeroed, not left uniform) — the contract the
    Pallas ``mha`` kernels are parity-tested against.
    """
    scale = q.shape[-1] ** -0.5
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kmask = None
    if lengths is not None:
        kmask = jnp.arange(Sk)[None, None, :] < lengths[:, None, None]
        s = jnp.where(kmask, s, -1e30)
    if causal:
        Sq = s.shape[-2]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    if kmask is not None:
        w = jnp.where(kmask, w, 0.0)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
