"""Pure-jnp oracle: the exact sequential SSM recurrence.

  h_t = exp(dA_t) * h_{t-1} + dt_t * B_t x_t^T
  y_t = C_t . h_t

This is the ground truth both the Pallas kernel and the vectorized
chunked implementation in ``repro.models.mamba2`` must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, dA, B, C):
    """x (BH,S,P); dt/dA (BH,S,1); B/C (BH,S,N) -> y (BH,S,P)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dAf = dA.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, dat, bt, ct = inp
        h = jnp.exp(dat)[:, None, None] * h \
            + (dtt[:, None] * bt)[..., None] * xt[:, None, :]
        y = jnp.einsum("bn,bnp->bp", ct, h)
        return h, y

    BH, S, P = x.shape
    N = B.shape[-1]
    h0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2)[..., 0],
          dAf.transpose(1, 0, 2)[..., 0], Bf.transpose(1, 0, 2),
          Cf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)
