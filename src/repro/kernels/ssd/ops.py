"""jit'd public wrapper for the SSD kernel.

Takes per-head tensors in model layout (B, S, H, ...), flattens to
(B*H, S, ...), computes the within-chunk cumulative decay, pads S to a
chunk multiple (decay of padded steps = 0 input), and calls the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, dA, B, C, *, chunk: int = 256, interpret: bool = True):
    """x (B,S,H,P); dt/dA (B,S,H); B/C (B,S,H,N) -> y (B,S,H,P).

    ``dA`` = dt * A (negative); the kernel consumes the in-chunk cumsum.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad

    def flat(t, d):
        return t.transpose(0, 2, 1, 3).reshape(b * H, Sp, d)

    xf = flat(x, P)
    Bf = flat(B, N)
    Cf = flat(C, N)
    dtf = dt.transpose(0, 2, 1).reshape(b * H, Sp, 1)
    dAf = dA.transpose(0, 2, 1).reshape(b * H, Sp, 1)
    # within-chunk cumulative decay
    l = dAf.reshape(b * H, Sp // chunk, chunk, 1)
    l = jnp.cumsum(l, axis=2).reshape(b * H, Sp, 1)
    y = ssd_kernel(xf, dtf, l, Bf, Cf, chunk=chunk, interpret=interpret)
    y = y.reshape(b, H, Sp, P).transpose(0, 2, 1, 3)
    return y[:, :S]
