"""Pallas TPU kernel: Mamba2 SSD (state-space duality), chunked.

One kernel computes the whole sequence mix per (batch*head): the grid is
(BH, n_chunks) with chunks innermost; the (P x N) SSM state persists in
VMEM scratch across chunk steps, so the inter-chunk recurrence costs no
HBM round-trips (this is the TPU-native replacement for the GPU
implementation's separate intra/inter passes):

  per chunk c:  y  = tril(C B^T * exp(l_i - l_j)) * dt  @ x     (intra, MXU)
                y += exp(l) * (C @ h^T)                          (inter)
                h  = exp(l_last) * h + (exp(l_last - l) dt B)^T @ x

Inputs are pre-projected (x, dt, B, C per token) — the projections stay in
XLA where they fuse with neighbours; the kernel owns the quadratic core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, l_ref, b_ref, c_ref, o_ref, h_ref, *,
                n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    l = l_ref[0].astype(jnp.float32)          # (Q, 1) cumsum(dA) in chunk
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    # --- intra-chunk quadratic term
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    decay = jnp.exp(l - l.T)                                       # l_i - l_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    w = jnp.where(ii >= jj, scores * decay * dt.T, 0.0)
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)          # (Q, P)

    # --- inter-chunk contribution from the carried state
    h = h_ref[...]                                                 # (N, P)
    y = y + jnp.exp(l) * jnp.dot(C, h, preferred_element_type=jnp.float32)

    # --- state update for the next chunk
    l_last = l[Q - 1]                                              # (1,)
    sdec = jnp.exp(l_last[None] - l)                               # (Q, 1)
    h_ref[...] = (jnp.exp(l_last)[:, None] * h
                  + jnp.dot((B * sdec * dt).T, x,
                            preferred_element_type=jnp.float32))
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_kernel(x, dt, l, B, C, *, chunk: int,
               interpret: bool = False) -> jnp.ndarray:
    """x (BH, S, P); dt/l (BH, S, 1); B/C (BH, S, N); S % chunk == 0.
    Returns y (BH, S, P)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks)
    spec = lambda d: pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0))
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[spec(P), spec(1), spec(1), spec(N), spec(N)],
        out_specs=spec(P),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, l, B, C)
