"""jit'd public wrapper for the fused-MLP kernel.

Pads (M, N, K) to block multiples, runs the Pallas kernel (interpret mode
on CPU, compiled on TPU), slices the result back, and exposes a
``dfp_state_module`` convenience that runs the whole DFP state MLP
through the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import fused_mlp_layer
from .ref import fused_mlp_layer_ref


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("activation", "slope",
                                             "block_m", "block_n", "block_k",
                                             "interpret"))
def fused_mlp(x, w, b, *, activation: str = "leaky_relu", slope: float = 0.2,
              block_m: int = 128, block_n: int = 256, block_k: int = 512,
              interpret: bool = True):
    """y = act(x @ w + b) with arbitrary (M, K, N)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    M, K = x.shape
    N = w.shape[1]
    block_m = min(block_m, max(8, 1 << (M - 1).bit_length()))
    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    bp = _pad_to(b, block_n, 0)
    y = fused_mlp_layer(xp, wp, bp, activation=activation, slope=slope,
                        block_m=block_m, block_n=block_n, block_k=block_k,
                        interpret=interpret)
    y = y[:M, :N]
    return y[0] if squeeze else y


def dfp_state_module(x, layers, *, interpret: bool = True):
    """Run the DFP state-module MLP (list of {'w','b'}) fused layer-by-layer
    (hidden layers use leaky_relu; final layer too, per MRSch §III-A)."""
    h = x
    for layer in layers:
        h = fused_mlp(h, layer["w"], layer["b"], activation="leaky_relu",
                      interpret=interpret)
    return h
