"""Differentiable public wrapper for the fused-MLP Pallas kernels.

``fused_mlp`` pads (M, N, K) to block multiples, runs the Pallas kernel
(interpret mode off-TPU, compiled on TPU), slices the result back, and
carries a ``jax.custom_vjp`` whose backward pass runs the fused
dgrad/wgrad kernels — so both DFP inference *and* the ``lax.scan``
training bursts stay inside the kernel layer.

Block sizes are autotuned per (M, K, N) problem shape (see
``autotune_blocks``), keyed on the *padded* batch the caller actually
produces — the batched rollout engine pads its decision batch to a
power of two (``MRSchAgent._greedy_rows``), so the jit/block cache sees
a small fixed set of shapes.  Explicit ``block_*`` arguments override
the autotuner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...obs.profiling import named_scope
from .kernel import (_activation_grad, fused_mlp_dgrad_layer, fused_mlp_layer,
                     fused_mlp_wgrad_layer)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pow2(v: int) -> int:
    return 1 << max(int(v) - 1, 0).bit_length()


def autotune_blocks(m: int, k: int, n: int) -> tuple:
    """Pick (block_m, block_n, block_k) for an (M, K) @ (K, N) layer.

    A shape-keyed heuristic (no measurement): M tiles shrink to the
    padded batch (a rollout round is often a handful of lanes x window,
    far below the 128-row MXU default); N/K tiles stay lane-aligned
    (>=128) and cap at the VMEM-friendly 256/512 the forward kernel was
    tuned with.  Upstream power-of-two batch padding keeps the set of
    distinct shapes — and thus jit specializations — small.
    """
    block_m = min(128, max(8, _pow2(m)))
    block_n = min(256, max(128, _pow2(n)))
    block_k = min(512, max(128, _pow2(k)))
    return block_m, block_n, block_k


def default_interpret() -> bool:
    """Compiled Pallas on TPU, interpreter everywhere else (CPU CI)."""
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------- custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fused_mlp_2d(x, w, b, activation, slope, block_m, block_n, block_k,
                  interpret):
    """y = act(x @ w + b) on 2-D x, differentiable w.r.t. (x, w, b)."""
    return _forward_2d(x, w, b, activation, slope, block_m, block_n,
                       block_k, interpret)


def _forward_2d(x, w, b, activation, slope, block_m, block_n, block_k,
                interpret):
    with named_scope("mrsch.kernel.fused_mlp"):
        M, K = x.shape
        N = w.shape[1]
        xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
        wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
        bp = _pad_to(b, block_n, 0)
        y = fused_mlp_layer(xp, wp, bp, activation=activation, slope=slope,
                            block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
        return y[:M, :N]


def _fused_mlp_fwd(x, w, b, activation, slope, block_m, block_n, block_k,
                   interpret):
    y = _forward_2d(x, w, b, activation, slope, block_m, block_n, block_k,
                    interpret)
    return y, (x, w, b, y)


def _fused_mlp_bwd(activation, slope, block_m, block_n, block_k, interpret,
                   res, g):
    with named_scope("mrsch.kernel.fused_mlp_bwd"):
        return _fused_mlp_bwd_impl(activation, slope, block_m, block_n,
                                   block_k, interpret, res, g)


def _fused_mlp_bwd_impl(activation, slope, block_m, block_n, block_k,
                        interpret, res, g):
    x, w, b, y = res
    M, K = x.shape
    N = w.shape[1]
    gp = _pad_to(_pad_to(g, block_m, 0), block_n, 1)
    yp = _pad_to(_pad_to(y, block_m, 0), block_n, 1)
    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    dx = fused_mlp_dgrad_layer(gp, yp, wp, activation=activation, slope=slope,
                               block_m=block_m, block_n=block_n,
                               block_k=block_k, interpret=interpret)[:M, :K]
    dw = fused_mlp_wgrad_layer(xp, gp, yp, activation=activation, slope=slope,
                               block_m=block_m, block_n=block_n,
                               block_k=block_k, interpret=interpret)[:K, :N]
    # Bias grad: XLA fuses the elementwise product into the reduction,
    # so this re-reads g/y but does not materialize an (M, N) buffer.
    gm = (g.astype(jnp.float32)
          * _activation_grad(y.astype(jnp.float32), activation, slope))
    db = gm.sum(axis=0).astype(b.dtype)
    return dx.astype(x.dtype), dw.astype(w.dtype), db


_fused_mlp_2d.defvjp(_fused_mlp_fwd, _fused_mlp_bwd)

_fused_mlp_jit = jax.jit(_fused_mlp_2d, static_argnums=(3, 4, 5, 6, 7, 8))


# ------------------------------------------------------------------- public
def fused_mlp(x, w, b, *, activation: str = "leaky_relu", slope: float = 0.2,
              block_m: int | None = None, block_n: int | None = None,
              block_k: int | None = None, interpret: bool | None = None):
    """y = act(x @ w + b) with arbitrary (M, K, N); differentiable.

    ``block_* = None`` autotunes on the problem shape; ``interpret =
    None`` compiles on TPU and interprets elsewhere.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = autotune_blocks(M, K, N)
    if block_m is not None:
        bm = min(block_m, max(8, _pow2(M)))
    if block_n is not None:
        bn = block_n
    if block_k is not None:
        bk = block_k
    if interpret is None:
        interpret = default_interpret()
    y = _fused_mlp_jit(x, w, b, activation, float(slope), bm, bn, bk,
                       bool(interpret))
    return y[0] if squeeze else y


def dfp_state_module(x, layers, *, interpret: bool | None = None):
    """Run the DFP state-module MLP (list of {'w','b'}) fused layer-by-layer
    (hidden layers use leaky_relu; final layer too, per MRSch §III-A)."""
    h = x
    for layer in layers:
        h = fused_mlp(h, layer["w"], layer["b"], activation="leaky_relu",
                      interpret=interpret)
    return h
