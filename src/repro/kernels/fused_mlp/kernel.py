"""Pallas TPU kernel: fused  y = act(x @ W + b).

The MRSch agent's hot spot is the DFP state-module MLP
(11410 -> 4000 -> 1000 -> 512, leaky rectifier).  This kernel fuses the
matmul, bias and activation so each layer is a single HBM round-trip:
x/W stream through VMEM in (bm x bk)/(bk x bn) tiles, a f32 accumulator
lives in VMEM scratch across the K-loop (innermost grid dim), and the
bias+activation epilogue runs on the last K step — MXU-aligned tiles
(multiples of 128 in M/N, K tiles of 512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_mlp_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                      activation: str, slope: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "leaky_relu":
            y = jnp.where(y >= 0, y, slope * y)
        elif activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "tanh":
            y = jnp.tanh(y)
        o_ref[...] = y.astype(o_ref.dtype)


def fused_mlp_layer(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                    activation: str = "leaky_relu", slope: float = 0.2,
                    block_m: int = 128, block_n: int = 256,
                    block_k: int = 512, interpret: bool = False
                    ) -> jnp.ndarray:
    """x (M,K) @ w (K,N) + b (N,), fused activation.  Shapes are padded to
    block multiples by the ``ops`` wrapper."""
    M, K = x.shape
    _, N = w.shape
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)
    kernel = functools.partial(_fused_mlp_kernel, n_k=n_k,
                               activation=activation, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b)
