"""Pallas TPU kernels: fused  y = act(x @ W + b)  and its backward pass.

The MRSch agent's hot spot is the DFP state-module MLP
(11410 -> 4000 -> 1000 -> 512, leaky rectifier).  The forward kernel
fuses the matmul, bias and activation so each layer is a single HBM
round-trip: x/W stream through VMEM in (bm x bk)/(bk x bn) tiles, a f32
accumulator lives in VMEM scratch across the K-loop (innermost grid
dim), and the bias+activation epilogue runs on the last K step —
MXU-aligned tiles (multiples of 128 in M/N, K tiles of 512).

The backward kernels reuse the same tiling.  Both fuse the activation
gradient into their contraction prologue, so neither ever writes the
(M, N) tensor ``g * act'(y)`` to HBM:

  * dgrad:  dx[m, k] = sum_n (g * act'(y))[m, n] * W[k, n]
  * wgrad:  dw[k, n] = sum_m x[m, k] * (g * act'(y))[m, n]

(The small bias gradient ``db = sum_m g * act'(y)`` is left to XLA as a
fused elementwise+reduce over the same product — see ``ops.py``.)

``act'`` is recovered from the *output* y (every supported activation
has a derivative expressible in its own output), so the forward only
needs to save (x, W, y) as residuals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS = ("leaky_relu", "relu", "tanh", "linear")


def _check_activation(activation: str) -> None:
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"expected one of {ACTIVATIONS}")


def _apply_activation(y, activation: str, slope: float):
    if activation == "leaky_relu":
        return jnp.where(y >= 0, y, slope * y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "tanh":
        return jnp.tanh(y)
    return y                                            # linear


def _activation_grad(y, activation: str, slope: float):
    """d act / d pre-activation, written in terms of the output ``y``.

    leaky_relu / relu are sign-recoverable (slope > 0), tanh' = 1 - y²;
    matches the convention JAX uses for the reference ops (derivative 1
    at exactly 0 for leaky_relu, 0 for relu).
    """
    one = jnp.ones_like(y)
    if activation == "leaky_relu":
        return jnp.where(y >= 0, one, slope * one)
    if activation == "relu":
        return jnp.where(y > 0, one, jnp.zeros_like(y))
    if activation == "tanh":
        return 1.0 - y * y
    return one                                          # linear


def _fused_mlp_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                      activation: str, slope: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_activation(y, activation, slope).astype(o_ref.dtype)


def fused_mlp_layer(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                    activation: str = "leaky_relu", slope: float = 0.2,
                    block_m: int = 128, block_n: int = 256,
                    block_k: int = 512, interpret: bool = False
                    ) -> jnp.ndarray:
    """x (M,K) @ w (K,N) + b (N,), fused activation.  Shapes are padded to
    block multiples by the ``ops`` wrapper."""
    _check_activation(activation)
    M, K = x.shape
    _, N = w.shape
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)
    kernel = functools.partial(_fused_mlp_kernel, n_k=n_k,
                               activation=activation, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b)


# ------------------------------------------------------------------ backward
def _fused_mlp_dgrad_kernel(g_ref, y_ref, w_ref, dx_ref, acc_ref, *,
                            n_n: int, activation: str, slope: float):
    """dx tile (bm, bk): contract g*act'(y) (bm, bn) with W (bk, bn) over N."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gm = (g_ref[...].astype(jnp.float32)
          * _activation_grad(y_ref[...].astype(jnp.float32), activation, slope))
    acc_ref[...] += jax.lax.dot_general(
        gm, w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_n - 1)
    def _epilogue():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def fused_mlp_dgrad_layer(g: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray, *,
                          activation: str = "leaky_relu", slope: float = 0.2,
                          block_m: int = 128, block_n: int = 256,
                          block_k: int = 512, interpret: bool = False
                          ) -> jnp.ndarray:
    """dx (M,K) from upstream g (M,N), saved output y (M,N), w (K,N)."""
    _check_activation(activation)
    M, N = g.shape
    K = w.shape[0]
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_n = N // block_n
    grid = (M // block_m, K // block_k, n_n)
    kernel = functools.partial(_fused_mlp_dgrad_kernel, n_n=n_n,
                               activation=activation, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, k, j: (i, j)),
            pl.BlockSpec((block_m, block_n), lambda i, k, j: (i, j)),
            pl.BlockSpec((block_k, block_n), lambda i, k, j: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_k), lambda i, k, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), g.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_k), jnp.float32)],
        interpret=interpret,
    )(g, y, w)


def _fused_mlp_wgrad_kernel(x_ref, g_ref, y_ref, dw_ref, acc_ref, *,
                            n_m: int, activation: str, slope: float):
    """dw tile (bk, bn): contract x (bm, bk) with g*act'(y) (bm, bn) over M."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gm = (g_ref[...].astype(jnp.float32)
          * _activation_grad(y_ref[...].astype(jnp.float32), activation, slope))
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), gm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_m - 1)
    def _epilogue():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def fused_mlp_wgrad_layer(x: jnp.ndarray, g: jnp.ndarray, y: jnp.ndarray, *,
                          activation: str = "leaky_relu", slope: float = 0.2,
                          block_m: int = 128, block_n: int = 256,
                          block_k: int = 512, interpret: bool = False
                          ) -> jnp.ndarray:
    """dw (K,N) from input x (M,K), upstream g (M,N), saved output y (M,N)."""
    _check_activation(activation)
    M, K = x.shape
    N = g.shape[1]
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_m = M // block_m
    grid = (K // block_k, N // block_n, n_m)
    kernel = functools.partial(_fused_mlp_wgrad_kernel, n_m=n_m,
                               activation=activation, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda k, j, m: (m, k)),
            pl.BlockSpec((block_m, block_n), lambda k, j, m: (m, j)),
            pl.BlockSpec((block_m, block_n), lambda k, j, m: (m, j)),
        ],
        out_specs=pl.BlockSpec((block_k, block_n), lambda k, j, m: (k, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_k, block_n), jnp.float32)],
        interpret=interpret,
    )(x, g, y)
