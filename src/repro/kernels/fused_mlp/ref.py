"""Pure-jnp oracle for the fused MLP kernel (forward and, via jax.grad,
backward — the custom-VJP parity tests differentiate through this)."""
from __future__ import annotations

import jax.numpy as jnp


def fused_mlp_layer_ref(x, w, b, activation: str = "leaky_relu",
                        slope: float = 0.2):
    y = (x.astype(jnp.float32) @ w.astype(jnp.float32)
         + b.astype(jnp.float32))
    if activation == "leaky_relu":
        y = jnp.where(y >= 0, y, slope * y)
    elif activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "linear":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)
