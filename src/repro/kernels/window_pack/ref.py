"""Pure-JAX reference for the window pack/select op.

Given per-environment waiting masks over a padded job axis, gather the
first ``W`` waiting jobs (queue order == ascending job index; the device
engine keeps traces sorted by submit time) into a dense window: their
feature rows, their job indices, and a validity mask.  This is the inner
candidate-enumeration step of every scheduling decision — the Pallas
kernel in ``kernel.py`` computes the same one-hot formulation with one
MXU matmul per environment row.
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_window_reference(waiting: jnp.ndarray, feats: jnp.ndarray, *,
                          window: int):
    """waiting (N, J) 0/1, feats (N, J, F) ->
    (win_feats (N, W, F), win_idx (N, W) int32, win_valid (N, W) bool).

    Slot ``w`` holds the (w+1)-th waiting job in index order; slots past
    the number of waiting jobs are invalid with zero features and index 0.
    """
    J = waiting.shape[1]
    is_wait = waiting > 0.5
    csum = jnp.cumsum(is_wait.astype(jnp.int32), axis=1)        # (N, J)
    slots = jnp.arange(window, dtype=jnp.int32)[None, :, None]  # (1, W, 1)
    sel = is_wait[:, None, :] & (csum[:, None, :] == slots + 1)  # (N, W, J)
    sel_f = sel.astype(feats.dtype)
    win_feats = jnp.einsum("nwj,njf->nwf", sel_f, feats)
    jidx = jnp.arange(J, dtype=jnp.int32)[None, None, :]
    win_idx = (sel * jidx).sum(axis=-1).astype(jnp.int32)
    win_valid = sel.any(axis=-1)
    return win_feats, win_idx, win_valid
