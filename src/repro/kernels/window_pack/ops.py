"""Public wrapper for the window pack/select op.

``pack_window`` pads (J, F, W) to tile multiples, dispatches to the
Pallas kernel on TPU (or when forced), and slices the results back.  Off
TPU it defaults to the vectorized XLA reference — the op sits inside the
device rollout engine's scan, and interpret-mode Pallas would execute
the kernel body in Python on every round; the kernel path is still
exercised off-TPU by the parity tests via ``use_pallas=True,
interpret=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...obs.profiling import named_scope
from .kernel import window_pack_kernel
from .ref import pack_window_reference


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pack_window(waiting: jnp.ndarray, feats: jnp.ndarray, *, window: int,
                use_pallas: bool | None = None,
                interpret: bool | None = None):
    """First ``window`` waiting jobs per environment, densely packed.

    waiting (N, J) 0/1 float, feats (N, J, F) float32 ->
    (win_feats (N, W, F) f32, win_idx (N, W) i32, win_valid (N, W) bool).
    Traceable (safe inside jit); padding/slicing is shape-static.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    with named_scope("mrsch.kernel.window_pack"):
        if not use_pallas:
            return pack_window_reference(waiting, feats, window=window)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        N, J = waiting.shape
        F = feats.shape[2]
        wp = _pad_axis(waiting.astype(jnp.float32), 128, 1)
        fp = _pad_axis(_pad_axis(feats.astype(jnp.float32), 128, 1), 128, 2)
        Wp = window + ((-window) % 8)
        wf, wi, wv = window_pack_kernel(wp, fp, window=Wp,
                                        interpret=bool(interpret))
        return (wf[:, :window, :F], wi[:, :window],
                wv[:, :window] > 0.5)
