"""Pallas TPU kernel: pack the first W waiting jobs into a dense window.

One grid step per environment row.  The selection is expressed as a
one-hot matrix ``sel[w, j] = waiting_j AND (cumsum(waiting)_j == w+1)``
so the gather becomes a single (W, J) @ (J, F) MXU matmul instead of a
serial scan over the job axis — the same trick lands the window indices
(contract against an iota) and the validity mask (row-sum of ``sel``).

Shapes are padded to tile multiples by the ``ops`` wrapper: J and F to
lane multiples (128), W to a sublane multiple (8).  All blocks live in
VMEM; no scratch is needed since each environment is one grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _window_pack_kernel(waiting_ref, feats_ref, wf_ref, wi_ref, wv_ref):
    waiting = waiting_ref[...]                     # (1, J)
    W = wf_ref.shape[1]
    J = waiting.shape[1]
    is_wait = waiting > 0.5
    csum = jnp.cumsum(waiting, axis=1)             # f32 exact for J < 2**24
    slot = jax.lax.broadcasted_iota(jnp.float32, (W, J), 0)
    sel = jnp.where(is_wait & (csum == slot + 1.0), 1.0, 0.0)   # (W, J)
    wf_ref[...] = jnp.dot(sel, feats_ref[0],
                          preferred_element_type=jnp.float32)[None]
    jidx = jax.lax.broadcasted_iota(jnp.float32, (W, J), 1)
    wi_ref[...] = (sel * jidx).sum(axis=1).astype(jnp.int32)[None]
    wv_ref[...] = sel.sum(axis=1)[None]


def window_pack_kernel(waiting: jnp.ndarray, feats: jnp.ndarray, *,
                       window: int, interpret: bool = False):
    """waiting (N, J) f32 0/1, feats (N, J, F) f32 ->
    (win_feats (N, W, F) f32, win_idx (N, W) i32, win_valid (N, W) f32).

    J, F and ``window`` must already be tile-aligned (``ops`` pads)."""
    N, J = waiting.shape
    F = feats.shape[2]
    W = window
    assert J % 128 == 0 and F % 128 == 0 and W % 8 == 0, (J, F, W)
    return pl.pallas_call(
        _window_pack_kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, J), lambda i: (i, 0)),
            pl.BlockSpec((1, J, F), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, W, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W, F), jnp.float32),
            jax.ShapeDtypeStruct((N, W), jnp.int32),
            jax.ShapeDtypeStruct((N, W), jnp.float32),
        ],
        interpret=interpret,
    )(waiting, feats)
