"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three files: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with CPU interpret fallback), and
ref.py (pure-jnp oracle used by the per-kernel allclose test sweeps).
"""
