"""Job and resource-request model for the multi-resource cluster simulator."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import numpy as np


@dataclass
class Job:
    """A rigid parallel HPC job.

    ``demands`` maps resource name -> requested units (integer), e.g.
    ``{"node": 512, "bb": 40, "power": 60}``.  Burst buffer is in units
    (default 1 TB/unit); power in kW of *incremental* draw above idle.

    Workflow/fault extensions (see ``repro.sim.lifecycle``):

    - ``deps``: jids of parent jobs; this job is HELD until every parent
      FINISHes (dangling jids — parents not present in the jobset — are
      treated as already satisfied, so sampled sub-traces stay runnable).
    - ``think_time``: seconds after the last parent finishes before this
      job becomes eligible (SWF field 18).
    - ``fail_times``: per-attempt failure points, in seconds after the
      attempt starts.  Attempt ``k`` dies at ``fail_times[k]`` if that is
      strictly less than ``runtime``; attempts beyond ``len(fail_times)``
      (and entries >= runtime) run to completion.
    """

    jid: int
    submit: float                       # submission time (seconds)
    runtime: float                      # actual runtime (seconds)
    walltime: float                     # user estimate (seconds), >= runtime
    demands: Dict[str, int] = field(default_factory=dict)

    # Mutable scheduling state (current attempt)
    start: float = -1.0
    end: float = -1.0

    # Workflow / fault spec (fixed per trace, survives ``copy()``)
    deps: Tuple[int, ...] = ()
    think_time: float = 0.0
    fail_times: Tuple[float, ...] = ()

    # Lifecycle state (reset by ``copy()``); ``state`` holds a
    # ``repro.sim.lifecycle`` state constant (HELD == 0).
    state: int = 0
    first_start: float = -1.0           # start of the FIRST attempt
    requeues: int = 0                   # completed failed attempts
    failed_work: float = 0.0            # node-seconds lost to killed attempts

    @property
    def started(self) -> bool:
        return self.first_start >= 0.0 or self.start >= 0.0

    @property
    def wait(self) -> float:
        """Queue wait measured from submission to the FIRST attempt.

        Requeued jobs keep the wait of their first start — a job that ran,
        failed, and ran again did not wait longer for service.
        """
        s = self.first_start if self.first_start >= 0.0 else self.start
        return s - self.submit

    @property
    def slowdown(self) -> float:
        return (self.wait + self.runtime) / max(self.runtime, 1.0)

    def bounded_slowdown(self, tau: float = 10.0) -> float:
        return max(1.0, (self.wait + self.runtime) / max(self.runtime, tau))

    def demand_fraction(self, capacities: Dict[str, int]) -> np.ndarray:
        """P_ij of Eq. (1): requested fraction of each resource's capacity."""
        return np.array(
            [self.demands.get(r, 0) / max(c, 1) for r, c in capacities.items()],
            dtype=np.float64,
        )

    def demand_row(self, names: tuple) -> tuple:
        """Demanded units ordered by ``names``, cached on the instance.

        Demands are fixed once a trace is built (simulators work on
        copies), and this row is consumed on every scheduling decision by
        the Eq. (1) goal computation — caching it removes a per-decision
        dict-lookup loop from the hot path.
        """
        cached = self.__dict__.get("_demand_row")
        if cached is not None and cached[0] == names:
            return cached[1]
        row = tuple(float(self.demands.get(n, 0)) for n in names)
        self.__dict__["_demand_row"] = (names, row)
        return row

    def copy(self) -> "Job":
        return Job(self.jid, self.submit, self.runtime, self.walltime,
                   dict(self.demands), deps=self.deps,
                   think_time=self.think_time, fail_times=self.fail_times)
