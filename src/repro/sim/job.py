"""Job and resource-request model for the multi-resource cluster simulator."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np


@dataclass
class Job:
    """A rigid parallel HPC job.

    ``demands`` maps resource name -> requested units (integer), e.g.
    ``{"node": 512, "bb": 40, "power": 60}``.  Burst buffer is in units
    (default 1 TB/unit); power in kW of *incremental* draw above idle.
    """

    jid: int
    submit: float                       # submission time (seconds)
    runtime: float                      # actual runtime (seconds)
    walltime: float                     # user estimate (seconds), >= runtime
    demands: Dict[str, int] = field(default_factory=dict)

    # Mutable scheduling state
    start: float = -1.0
    end: float = -1.0

    @property
    def started(self) -> bool:
        return self.start >= 0.0

    @property
    def wait(self) -> float:
        return self.start - self.submit

    @property
    def slowdown(self) -> float:
        return (self.wait + self.runtime) / max(self.runtime, 1.0)

    def bounded_slowdown(self, tau: float = 10.0) -> float:
        return max(1.0, (self.wait + self.runtime) / max(self.runtime, tau))

    def demand_fraction(self, capacities: Dict[str, int]) -> np.ndarray:
        """P_ij of Eq. (1): requested fraction of each resource's capacity."""
        return np.array(
            [self.demands.get(r, 0) / max(c, 1) for r, c in capacities.items()],
            dtype=np.float64,
        )

    def demand_row(self, names: tuple) -> tuple:
        """Demanded units ordered by ``names``, cached on the instance.

        Demands are fixed once a trace is built (simulators work on
        copies), and this row is consumed on every scheduling decision by
        the Eq. (1) goal computation — caching it removes a per-decision
        dict-lookup loop from the hot path.
        """
        cached = self.__dict__.get("_demand_row")
        if cached is not None and cached[0] == names:
            return cached[1]
        row = tuple(float(self.demands.get(n, 0)) for n in names)
        self.__dict__["_demand_row"] = (names, row)
        return row

    def copy(self) -> "Job":
        return Job(self.jid, self.submit, self.runtime, self.walltime,
                   dict(self.demands))
