"""Scheduling quality metrics (paper §IV-B).

1) node utilization      = used node-hours / elapsed node-hours
2) burst-buffer util     = used BB-hours / elapsed BB-hours
   (generalized: one utilization figure per schedulable resource)
3) average job wait time = mean(start - submit)
4) average job slowdown  = mean((wait + runtime) / runtime)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class ScheduleMetrics:
    utilization: Dict[str, float]
    avg_wait: float
    avg_slowdown: float
    avg_bounded_slowdown: float
    p95_wait: float
    max_wait: float
    n_jobs: int
    makespan: float
    truncated_jobs: int = 0   # waiting jobs beyond the observable window,
    #                           summed over decisions (set by the engines,
    #                           not by MetricsAccumulator.summarize)

    def as_row(self) -> Dict[str, float]:
        """Flat CSV/JSON row: every scalar field plus one util_<name>
        column per resource (tests pin that no field is dropped)."""
        row = {f"util_{k}": v for k, v in self.utilization.items()}
        row.update(
            avg_wait=self.avg_wait,
            avg_slowdown=self.avg_slowdown,
            avg_bounded_slowdown=self.avg_bounded_slowdown,
            p95_wait=self.p95_wait,
            max_wait=self.max_wait,
            n_jobs=self.n_jobs,
            makespan=self.makespan,
            truncated_jobs=self.truncated_jobs,
        )
        return row


class MetricsAccumulator:
    """Integrates per-resource busy-units over simulated time."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.last_time = 0.0
        self.busy_area: Dict[str, float] = {n: 0.0 for n in cluster.names}
        self.start_time: float | None = None

    def advance(self, new_time: float) -> None:
        dt = new_time - self.last_time
        if dt > 0:
            for n in self.cluster.names:
                busy = self.cluster.capacities[n] - self.cluster.free[n]
                self.busy_area[n] += busy * dt
        self.last_time = new_time

    def job_started(self, job) -> None:
        if self.start_time is None:
            self.start_time = job.start

    def summarize(self, jobs: List) -> ScheduleMetrics:
        elapsed = max(self.last_time - (self.start_time or 0.0), 1e-9)
        util = {
            n: self.busy_area[n] / (self.cluster.capacities[n] * elapsed)
            for n in self.cluster.names
        }
        waits = np.array([j.wait for j in jobs]) if jobs else np.zeros(1)
        slow = np.array([j.slowdown for j in jobs]) if jobs else np.ones(1)
        bslow = np.array([j.bounded_slowdown() for j in jobs]) if jobs else np.ones(1)
        return ScheduleMetrics(
            utilization=util,
            avg_wait=float(waits.mean()),
            avg_slowdown=float(slow.mean()),
            avg_bounded_slowdown=float(bslow.mean()),
            p95_wait=float(np.percentile(waits, 95)),
            max_wait=float(waits.max()),
            n_jobs=len(jobs),
            makespan=self.last_time,
        )
