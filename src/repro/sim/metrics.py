"""Scheduling quality metrics (paper §IV-B, plus lifecycle extensions).

1) node utilization      = used node-hours / elapsed node-hours
2) burst-buffer util     = used BB-hours / elapsed BB-hours
   (generalized: one utilization figure per schedulable resource)
3) average job wait time = mean(first start - submit)
4) average job slowdown  = mean((wait + runtime) / runtime)

Workflow/fault extensions (repro.sim.lifecycle, beyond the paper's
rigid-independent-job assumption):

5) requeues              = killed attempts that re-entered the queue
6) n_failed              = jobs terminally FAILED (requeue bound / cascade)
7) failed_node_hours     = node-hours of work lost to killed attempts
8) completed_work_frac   = completed / (completed + failed) node-hours
9) pipeline_makespan     = mean (last end - first submit) over fully
   finished workflow components
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from . import lifecycle


@dataclass
class ScheduleMetrics:
    utilization: Dict[str, float]
    avg_wait: float
    avg_slowdown: float
    avg_bounded_slowdown: float
    p95_wait: float
    max_wait: float
    n_jobs: int
    makespan: float
    truncated_jobs: int = 0   # waiting jobs beyond the observable window,
    #                           summed over decisions (set by the engines,
    #                           not by MetricsAccumulator.summarize)
    # Lifecycle metrics — appended last so committed baseline rows keep
    # prefix-comparing (tools/check_bench.py contract).
    requeues: int = 0
    n_failed: int = 0
    failed_node_hours: float = 0.0
    completed_work_frac: float = 1.0
    pipeline_makespan: float = 0.0

    def as_row(self) -> Dict[str, float]:
        """Flat CSV/JSON row: every scalar field plus one util_<name>
        column per resource (tests pin that no field is dropped)."""
        row = {f"util_{k}": v for k, v in self.utilization.items()}
        row.update(
            avg_wait=self.avg_wait,
            avg_slowdown=self.avg_slowdown,
            avg_bounded_slowdown=self.avg_bounded_slowdown,
            p95_wait=self.p95_wait,
            max_wait=self.max_wait,
            n_jobs=self.n_jobs,
            makespan=self.makespan,
            truncated_jobs=self.truncated_jobs,
            requeues=self.requeues,
            n_failed=self.n_failed,
            failed_node_hours=self.failed_node_hours,
            completed_work_frac=self.completed_work_frac,
            pipeline_makespan=self.pipeline_makespan,
        )
        return row


class MetricsAccumulator:
    """Integrates per-resource busy-units over simulated time."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.last_time = 0.0
        self.busy_area: Dict[str, float] = {n: 0.0 for n in cluster.names}
        self.start_time: float | None = None

    def advance(self, new_time: float) -> None:
        dt = new_time - self.last_time
        if dt > 0:
            for n in self.cluster.names:
                # Drained units are neither busy nor free: the outage is
                # charged to the fault metrics, not to utilization.
                self.busy_area[n] += self.cluster.busy_units(n) * dt
        self.last_time = new_time

    def job_started(self, job) -> None:
        if self.start_time is None:
            self.start_time = job.start

    def summarize(self, jobs: List,
                  all_jobs: Optional[List] = None) -> ScheduleMetrics:
        """``jobs``: started jobs (finite wait).  ``all_jobs``: the full
        trace with final lifecycle states, for the fault/workflow metrics;
        omitted by callers predating the lifecycle core."""
        elapsed = max(self.last_time - (self.start_time or 0.0), 1e-9)
        util = {
            n: self.busy_area[n] / (self.cluster.capacities[n] * elapsed)
            for n in self.cluster.names
        }
        waits = np.array([j.wait for j in jobs]) if jobs else np.zeros(1)
        slow = np.array([j.slowdown for j in jobs]) if jobs else np.ones(1)
        bslow = np.array([j.bounded_slowdown() for j in jobs]) if jobs else np.ones(1)
        m = ScheduleMetrics(
            utilization=util,
            avg_wait=float(waits.mean()),
            avg_slowdown=float(slow.mean()),
            avg_bounded_slowdown=float(bslow.mean()),
            p95_wait=float(np.percentile(waits, 95)),
            max_wait=float(waits.max()),
            n_jobs=len(jobs),
            makespan=self.last_time,
        )
        if all_jobs is not None:
            primary = ("node" if "node" in self.cluster.names
                       else self.cluster.names[0])
            lifecycle.cascade_failures(all_jobs)
            # A job's final kill may take it to FAILED instead of back to
            # the queue; only actual re-entries count as requeues.
            m.requeues = int(sum(
                j.requeues - (1 if j.state == lifecycle.FAILED
                              and j.requeues > 0 else 0)
                for j in all_jobs))
            m.n_failed = sum(1 for j in all_jobs
                             if j.state == lifecycle.FAILED)
            done, lost = lifecycle.work_summary(all_jobs, primary)
            m.failed_node_hours = lost / 3600.0
            m.completed_work_frac = (done / (done + lost)
                                     if done + lost > 0 else 1.0)
            m.pipeline_makespan = lifecycle.pipeline_makespan(all_jobs)
        return m
