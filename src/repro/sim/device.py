"""Device-resident rollout engine: the whole simulation loop in one jit.

``DeviceSimulator`` runs N independent trace simulations as ONE device
program: a ``lax.scan`` over scheduling rounds whose body advances job
arrival/completion events (one coalesced-timestamp pop per round, which
the ``3J + 2`` round budget covers), packs the
first-W waiting jobs per environment (``repro.kernels.window_pack``),
builds the packed decision rows in-graph, scores them with the policy's
pure ``score_window`` stage (``repro.core.policy_api``), and applies the
selected action — immediate start with first-free unit allocation, or a
reservation with EASY-backfill shadow accounting.  The host engines pay
a Python round trip per scheduling round; here the only host work is
packing the traces up front and summarizing metrics at the end.

State layout (leading axis = environment):

* job arrays ``(N, J)`` — submit/runtime/walltime (f32, padded jobs
  carry ``submit = +inf`` so they never arrive) and demands ``(N, J, R)``
  (f32 unit counts; exact below 2**24);
* ``n_arrived`` pointers — traces are sorted by (submit, jid), so the
  waiting queue in arrival order is exactly "arrived and not started in
  ascending job index", which is what the window-pack kernel assumes;
* per-unit cluster state ``(N, U)`` with ``U = sum(capacities)`` —
  ``release`` (estimated release time, 0 = free, mirroring
  ``Cluster.release``) and ``owner`` (job index, -1 free), in fixed
  per-resource segments;
* scalars per env — ``now``, ``in_pass``, ``done``, ``decisions``.

Semantics mirror ``Simulator`` event for event (coalesced timestamps,
scheduling-pass continuation, first-free unit allocation, reservation at
the earliest fit time, shadow-debit backfill in queue order), so an
N=1 rollout reproduces the sequential engine round for round; times are
float32 on device, so derived metrics agree to float32 precision
(pinned in ``tests/test_device.py``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.window_pack.ops import pack_window
from .cluster import Cluster, ResourceSpec
from .job import Job
from .metrics import MetricsAccumulator
from .simulator import SimConfig, SimResult

INF = jnp.float32(jnp.inf)


@dataclass(frozen=True)
class DeviceLayout:
    """Static shape/semantic configuration baked into the jitted rollout."""
    names: Tuple[str, ...]
    caps: Tuple[int, ...]            # actual cluster capacities
    enc_caps: Tuple[int, ...]        # encoding section sizes (reference caps)
    window: int
    n_envs: int
    n_jobs: int                      # J, padded job axis
    rounds: int                      # T, scan length
    backfill: bool
    requires_obs: bool
    time_scale: float
    state_module: str = "mlp"        # mirrors EncodingConfig.state_module
    queue_cap: int = 0               # Q, attention layout only

    @property
    def n_resources(self) -> int:
        return len(self.names)

    @property
    def segments(self) -> Tuple[Tuple[int, int], ...]:
        """(offset, capacity) per resource into the packed unit axis."""
        segs, off = [], 0
        for c in self.caps:
            segs.append((off, c))
            off += c
        return tuple(segs)

    @property
    def n_units(self) -> int:
        return int(sum(self.caps))

    @property
    def state_dim(self) -> int:
        if self.state_module == "attention":
            return (self.queue_cap * (self.n_resources + 2) + 1
                    + 2 * self.n_resources)
        return self.window * (self.n_resources + 2) + 2 * int(sum(self.enc_caps))


@dataclass
class DeviceStats:
    """Mirror of ``VectorStats`` for the device engine."""
    rounds: int = 0
    decisions: int = 0
    policy_calls: int = 0            # one in-graph score per active round
    max_batch: int = 0

    def as_dict(self) -> dict:
        return {"rounds": self.rounds, "decisions": self.decisions,
                "policy_calls": self.policy_calls,
                "max_batch": self.max_batch}


@dataclass
class DeviceRollout:
    """One device rollout: per-env results plus the decision trace.

    ``results`` materializes lazily on first access: rebuilding per-job
    Python objects for every environment is host-side work that
    collection-mode consumers (which ingest the packed decision trace,
    not ``SimResult``s) should not pay inside the rollout hot path.
    """
    actions: np.ndarray              # (T, N) int32, -1 where no decision
    decided: np.ndarray              # (T, N) bool
    stats: DeviceStats
    obs: Optional[np.ndarray] = None  # (T, N, row_dim) packed decision rows
    _build: Optional[Callable[[], List[SimResult]]] = field(
        default=None, repr=False)
    _cache: Optional[List[SimResult]] = field(default=None, repr=False)

    @property
    def results(self) -> List[SimResult]:
        """Per-env ``SimResult``s in jobset order (built on demand)."""
        if self._cache is None:
            self._cache = self._build()
        return self._cache

    def transitions(self):
        """Yield (round, env, obs_row, action) for every decision taken,
        in round order — the order the host trainer must ingest them to
        keep each environment's trajectory contiguous."""
        assert self.obs is not None, "rollout was not collected"
        for t in range(self.decided.shape[0]):
            for i in np.flatnonzero(self.decided[t]):
                yield t, int(i), self.obs[t, i], int(self.actions[t, i])


# ===================================================================== graph
def _segment_free(layout: DeviceLayout, release: jnp.ndarray) -> jnp.ndarray:
    """Free-unit counts per resource, (N, R) float32."""
    cols = [jnp.sum(release[:, off:off + cap] == 0.0, axis=1)
            for off, cap in layout.segments]
    return jnp.stack(cols, axis=1).astype(jnp.float32)


def _advance_events(layout: DeviceLayout, arrays, st):
    """Batched event step: pop+apply ONE coalesced timestamp per env not
    inside a scheduling pass.  Runs inline in the round body (no
    ``while_loop`` — its computation boundaries dominate the per-round
    cost on small problems); an env that pops a decision-free timestamp
    simply pops again next round, which the 3J+2 round budget covers
    (each job contributes at most one arrival pop, one completion pop,
    and one decision per pass it opens)."""
    jidx = jnp.arange(layout.n_jobs)
    s = st
    arrived = jidx[None, :] < s["n_arrived"][:, None]
    # A pass over an empty queue ends silently (Simulator.next_decision).
    in_pass = s["in_pass"] & (arrived & ~s["started"]).any(axis=1)
    adv = ~in_pass & ~s["done"]
    next_submit = jnp.take_along_axis(
        arrays["submit_ext"], s["n_arrived"][:, None], axis=1)[:, 0]
    running = s["started"] & ~s["finished"]
    next_end = jnp.min(jnp.where(running, s["end"], INF), axis=1)
    t = jnp.minimum(next_submit, next_end)
    no_ev = ~jnp.isfinite(t)
    done = s["done"] | (adv & no_ev)
    act = adv & ~no_ev
    now = jnp.where(act, t, s["now"])
    # Apply ALL events at the popped timestamp (coalescing): arrivals…
    is_sub = ((jidx[None, :] >= s["n_arrived"][:, None])
              & (arrays["submit"] == t[:, None]) & act[:, None])
    n_arrived = s["n_arrived"] + is_sub.sum(axis=1)
    # …and completions, whose units free up immediately.
    ends = running & (s["end"] == t[:, None]) & act[:, None]
    finished = s["finished"] | ends
    owner = s["owner"]
    owner_ended = (jnp.take_along_axis(
        ends, jnp.maximum(owner, 0), axis=1) & (owner >= 0))
    release = jnp.where(owner_ended, 0.0, s["release"])
    owner = jnp.where(owner_ended, -1, owner)
    return {**s, "in_pass": in_pass | act, "done": done, "now": now,
            "n_arrived": n_arrived, "finished": finished,
            "release": release, "owner": owner}


def _alloc_first_free(layout: DeviceLayout, release, owner, env_mask,
                      job_idx, demand, est):
    """Allocate ``demand`` (N, R) lowest-index free units for ``job_idx``
    in every env of ``env_mask`` (mirrors ``Cluster.allocate``)."""
    for r, (off, cap) in enumerate(layout.segments):
        seg = release[:, off:off + cap]
        freemask = seg == 0.0
        rank = jnp.cumsum(freemask.astype(jnp.float32), axis=1)
        take = (freemask & (rank <= demand[:, r:r + 1])
                & env_mask[:, None])
        release = release.at[:, off:off + cap].set(
            jnp.where(take, est[:, None], seg))
        owner = owner.at[:, off:off + cap].set(
            jnp.where(take, job_idx[:, None], owner[:, off:off + cap]))
    return release, owner


def _earliest_fit(layout: DeviceLayout, release, free, demand, now):
    """Per-env earliest time ``demand`` fits assuming estimated releases
    (mirrors ``Cluster.earliest_fit_time``): the need-th smallest release
    per resource (free units sort first as 0.0), max over resources."""
    t_res = now
    for r, (off, cap) in enumerate(layout.segments):
        seg_sorted = jnp.sort(release[:, off:off + cap], axis=1)
        need = demand[:, r]
        kth_idx = jnp.clip(need.astype(jnp.int32) - 1, 0, cap - 1)
        kth = jnp.take_along_axis(seg_sorted, kth_idx[:, None], axis=1)[:, 0]
        t_r = jnp.where(need <= free[:, r], now,
                        jnp.where(need <= float(cap), kth, INF))
        t_res = jnp.maximum(t_res, t_r)
    return t_res


def _easy_backfill(layout: DeviceLayout, arrays, st, free, need, waiting,
                   j_star, d_star):
    """EASY backfill for envs whose selection did not fit (vectorized
    mirror of ``Simulator._easy_backfill``): reservation at the earliest
    fit time, shadow accounting in queue order, then one batched
    first-fit unit assignment for every job that may jump ahead."""
    N, J, R = layout.n_envs, layout.n_jobs, layout.n_resources
    now = st["now"]
    t_res = _earliest_fit(layout, st["release"], free, d_star, now)
    do_bf = need & jnp.isfinite(t_res)
    # Shadow: free units at t_res (estimated releases) minus the
    # reservation's demand, per resource.
    shadow_cols = []
    for r, (off, cap) in enumerate(layout.segments):
        free_at = jnp.sum(st["release"][:, off:off + cap] <= t_res[:, None],
                          axis=1).astype(jnp.float32)
        shadow_cols.append(free_at - d_star[:, r])
    shadow = jnp.stack(shadow_cols, axis=1)

    ends_before_all = arrays["walltime"] + now[:, None] <= t_res[:, None]

    # The queue walk's carry only changes when a candidate actually
    # starts, and availability only ever decreases — so walking the
    # queue in order debiting as we go is equivalent to repeatedly
    # starting the FIRST still-fitting candidate.  That turns an O(J)
    # sequential scan into a while_loop with one iteration per started
    # job (almost always 0-2), each a vectorized pass over the queue.
    jidx = jnp.arange(J)
    cand = (do_bf[:, None] & (waiting > 0.5)
            & (jidx[None, :] != j_star[:, None]))          # (N, J)

    def fitting(free_c, shadow_c, go):
        # Per-resource (N, J) compares: XLA:CPU runs these an order of
        # magnitude faster than the equivalent (N, J, R) broadcast+all.
        fits_now = cand & ~go
        shadow_ok = None
        for r in range(R):
            d_r = arrays["demands"][:, :, r]
            fits_now = fits_now & (d_r <= free_c[:, r:r + 1])
            s_r = d_r <= shadow_c[:, r:r + 1]
            shadow_ok = s_r if shadow_ok is None else shadow_ok & s_r
        return fits_now & (ends_before_all | shadow_ok)

    # The loop carries the fit matrix so the condition is a 1-op any()
    # and each iteration evaluates ``fitting`` exactly once.  Each
    # iteration accepts a whole PREFIX of the fitting candidates: a
    # candidate is accepted when the cumulative demand of accepted
    # candidates up to and including it still fits (free and shadow) —
    # exactly the debits the sequential walk would have applied — and
    # the first cumulative failure blocks the rest of the queue until
    # the next iteration re-evaluates them against the debited carry.
    # One iteration per *blocking event* instead of one per start.
    def cond(c):
        return c[3].any()

    def body(c):
        free_c, shadow_c, go, ok = c
        ok_f = ok.astype(jnp.float32)
        debit_f = (ok & ~ends_before_all).astype(jnp.float32)
        free_ok = None
        shadow_fit = None
        d_acc_cols = []
        s_acc_cols = []
        for r in range(R):
            d_r = arrays["demands"][:, :, r]
            cum_r = jnp.cumsum(ok_f * d_r, axis=1)
            f_r = cum_r <= free_c[:, r:r + 1]
            free_ok = f_r if free_ok is None else free_ok & f_r
            cums_r = jnp.cumsum(debit_f * d_r, axis=1)
            s_r = cums_r <= shadow_c[:, r:r + 1]
            shadow_fit = s_r if shadow_fit is None else shadow_fit & s_r
            d_acc_cols.append(d_r)
        passes = free_ok & (ends_before_all | shadow_fit)
        fail = ok & ~passes
        accept = ok & passes & (jnp.cumsum(fail.astype(jnp.int32), axis=1)
                                == 0)
        acc_f = accept.astype(jnp.float32)
        acc_debit_f = (accept & ~ends_before_all).astype(jnp.float32)
        d_used = jnp.stack(
            [(acc_f * d_r).sum(axis=1) for d_r in d_acc_cols], axis=1)
        s_used = jnp.stack(
            [(acc_debit_f * d_r).sum(axis=1) for d_r in d_acc_cols], axis=1)
        free_c = free_c - d_used
        shadow_c = shadow_c - s_used
        go = go | accept
        return (free_c, shadow_c, go, fitting(free_c, shadow_c, go))

    go0 = jnp.zeros((N, J), bool)
    _, _, bf_start, _ = jax.lax.while_loop(
        cond, body, (free, shadow, go0, fitting(free, shadow, go0)))

    # Unit assignment, one batched pass per resource: job j takes the
    # free units whose free-rank falls in its cumulative-demand span —
    # identical to allocating each job first-fit in queue order.  Most
    # reservation rounds backfill nothing, so the whole phase is
    # conditioned on some env actually starting a job.
    def assign_units(st):
        est_all = now[:, None] + arrays["walltime"]            # (N, J)
        release, owner = st["release"], st["owner"]
        jidx_f = jnp.arange(J, dtype=jnp.float32)
        for r, (off, cap) in enumerate(layout.segments):
            seg = release[:, off:off + cap]
            freemask = seg == 0.0
            k = jnp.cumsum(freemask.astype(jnp.float32), axis=1)  # (N, cap)
            need_j = arrays["demands"][:, :, r] * bf_start         # (N, J)
            cum = jnp.cumsum(need_j, axis=1)
            assign = (freemask[:, :, None] & bf_start[:, None, :]
                      & (k[:, :, None] > (cum - need_j)[:, None, :])
                      & (k[:, :, None] <= cum[:, None, :]))        # (N, cap, J)
            assign_f = assign.astype(jnp.float32)
            any_assign = assign.any(axis=2)
            owner_val = jnp.einsum("nuj,j->nu", assign_f, jidx_f)
            rel_val = jnp.einsum("nuj,nj->nu", assign_f, est_all)
            release = release.at[:, off:off + cap].set(
                jnp.where(any_assign, rel_val, seg))
            owner = owner.at[:, off:off + cap].set(
                jnp.where(any_assign, owner_val.astype(jnp.int32),
                          owner[:, off:off + cap]))

        started = st["started"] | bf_start
        start = jnp.where(bf_start, now[:, None], st["start"])
        end = jnp.where(bf_start, now[:, None] + arrays["runtime"],
                        st["end"])
        est_end = jnp.where(bf_start, est_all, st["est_end"])
        any_bf = bf_start.any(axis=1)
        first = jnp.where(any_bf, jnp.minimum(st["first_start"], now),
                          st["first_start"])
        return {**st, "release": release, "owner": owner,
                "started": started, "start": start, "end": end,
                "est_end": est_end, "first_start": first}

    return jax.lax.cond(bf_start.any(), assign_units, lambda st: st, st)


def _meas_goal(layout: DeviceLayout, arrays, st, free, waiting):
    """Measurement (utilization) + Eq. (1) goal, (N, R) each — the shared
    tail of every packed decision row, module-independent."""
    R = layout.n_resources
    now = st["now"]
    caps_f = jnp.asarray([max(c, 1) for c in layout.caps], jnp.float32)
    meas = 1.0 - free / caps_f[None, :]
    # Eq. (1) goal over the full waiting queue + running remainders.
    running = st["started"] & ~st["finished"]
    tw = (arrays["walltime"] * waiting
          + jnp.maximum(st["est_end"] - now[:, None], 0.0) * running)
    acc = jnp.einsum("nj,njr->nr", tw, arrays["demands"])
    demand_time = acc / caps_f[None, :]
    total = demand_time.sum(axis=1, keepdims=True)
    goal = jnp.where(total > 0, demand_time / jnp.maximum(total, 1e-30),
                     1.0 / R)
    return meas, goal


def _job_tokens(layout: DeviceLayout, st, win_feats, win_valid):
    """Packed job slots -> [fracs(R), walltime_norm, queued_norm] tokens.

    [fracs(R), walltime_norm] are static per job; the queued-time column
    is derived from the packed raw submit times.  Invalid slots are
    all-zero (``pack_window`` zero-fills their features)."""
    R = layout.n_resources
    ts = jnp.float32(layout.time_scale)
    valid_f = win_valid.astype(jnp.float32)
    queued = (st["now"][:, None] - win_feats[..., R + 1]) / ts * valid_f
    return jnp.concatenate([win_feats[..., :R + 1], queued[..., None]],
                           axis=-1)


def _build_obs(layout: DeviceLayout, arrays, st, free, waiting, win_feats,
               win_valid):
    """Packed decision rows [state | meas | goal | valid] in-graph,
    mirroring ``encoding.encode_decision_row`` (float32 throughout)."""
    N, R, W = layout.n_envs, layout.n_resources, layout.window
    ts = jnp.float32(layout.time_scale)
    now = st["now"]
    valid_f = win_valid.astype(jnp.float32)
    win = _job_tokens(layout, st, win_feats, win_valid)
    parts = [win.reshape(N, W * (R + 2))]
    # Unit sections use the encoding's reference section sizes; a cluster
    # with fewer units fills the leading slots (encode_state semantics).
    # avail/ttf are computed once over the whole unit axis; the per-
    # segment views below are free slices.
    busy_all = st["release"] > 0.0
    avail_all = jnp.where(busy_all, 0.0, 1.0)
    ttf_all = jnp.where(busy_all,
                        jnp.maximum(st["release"] - now[:, None], 0.0),
                        0.0) / ts
    for r, (off, cap) in enumerate(layout.segments):
        k = min(cap, int(layout.enc_caps[r]))
        avail = avail_all[:, off:off + k]
        ttf = ttf_all[:, off:off + k]
        pad = int(layout.enc_caps[r]) - k
        if pad:
            zeros = jnp.zeros((N, pad), jnp.float32)
            avail = jnp.concatenate([avail, zeros], axis=1)
            ttf = jnp.concatenate([ttf, zeros], axis=1)
        parts.extend([avail, ttf])
    meas, goal = _meas_goal(layout, arrays, st, free, waiting)
    return jnp.concatenate(parts + [meas, goal, valid_f], axis=1)


def _build_obs_attention(layout: DeviceLayout, arrays, st, free, waiting,
                         q_feats, q_valid):
    """Attention-layout decision rows, mirroring ``encoding.encode_state``
    with ``state_module="attention"``:
    ``[Q*(R+2) tokens | queue_len | 2R context | meas | goal | valid(W)]``.
    ``q_feats``/``q_valid`` pack the first ``queue_cap`` waiting jobs; the
    leading W slots are exactly the action window."""
    N, R, W = layout.n_envs, layout.n_resources, layout.window
    Q = layout.queue_cap
    ts = jnp.float32(layout.time_scale)
    now = st["now"]
    tok = _job_tokens(layout, st, q_feats, q_valid)
    qlen = jnp.minimum(waiting.sum(axis=1), float(Q))
    ctx_cols = []
    for r, (off, cap) in enumerate(layout.segments):
        seg = st["release"][:, off:off + cap]
        busy = seg > 0.0
        nb = busy.sum(axis=1).astype(jnp.float32)
        ctx_cols.append(1.0 - nb / float(max(cap, 1)))       # free fraction
        ttf_sum = jnp.where(busy,
                            jnp.maximum(seg - now[:, None], 0.0),
                            0.0).sum(axis=1)
        ctx_cols.append(jnp.where(nb > 0, ttf_sum / jnp.maximum(nb, 1.0), 0.0)
                        / ts)                                # mean time-to-free
    meas, goal = _meas_goal(layout, arrays, st, free, waiting)
    return jnp.concatenate(
        [tok.reshape(N, Q * (R + 2)), qlen[:, None],
         jnp.stack(ctx_cols, axis=1), meas, goal,
         q_valid[:, :W].astype(jnp.float32)], axis=1)


def _device_rollout(layout: DeviceLayout, score_fn, explore: bool,
                    collect: bool, arrays, policy_state, eps, key):
    """The whole N-env x T-round rollout as one traced program."""
    N, J, R, W = (layout.n_envs, layout.n_jobs, layout.n_resources,
                  layout.window)
    jidx = jnp.arange(J)
    st = {
        "now": jnp.zeros(N, jnp.float32),
        "n_arrived": jnp.zeros(N, jnp.int32),
        "started": jnp.zeros((N, J), bool),
        "finished": jnp.zeros((N, J), bool),
        "start": jnp.full((N, J), -1.0, jnp.float32),
        "end": jnp.full((N, J), jnp.inf, jnp.float32),
        "est_end": jnp.zeros((N, J), jnp.float32),
        "release": jnp.zeros((N, layout.n_units), jnp.float32),
        "owner": jnp.full((N, layout.n_units), -1, jnp.int32),
        "in_pass": jnp.zeros(N, bool),
        "done": jnp.zeros(N, bool),
        "decisions": jnp.zeros(N, jnp.int32),
        "truncated": jnp.zeros(N, jnp.int32),
        "first_start": jnp.full(N, jnp.inf, jnp.float32),
        "key": key,
    }
    obs_dim = (layout.state_dim + 2 * R + W) if layout.requires_obs else W

    # Constant per rollout: keep the concat out of the per-round body.
    feats = jnp.concatenate(
        [arrays["static_feats"], arrays["submit_feat"][..., None]],
        axis=-1)

    def decide(s):
        now = s["now"]
        arrived = jidx[None, :] < s["n_arrived"][:, None]
        waiting = (arrived & ~s["started"]).astype(jnp.float32)
        n_waiting = waiting.sum(axis=1)
        need = s["in_pass"] & (n_waiting > 0) & ~s["done"]
        free = _segment_free(layout, s["release"])
        # The attention module observes the first queue_cap waiting jobs;
        # one pack covers both the Q-token state and (its leading W
        # slots) the action window.
        attention = layout.state_module == "attention"
        K = layout.queue_cap if attention else W
        pk_feats, pk_idx, pk_valid = pack_window(waiting, feats, window=K)
        win_idx, win_valid = pk_idx[:, :W], pk_valid[:, :W]
        if not layout.requires_obs:
            obs = win_valid.astype(jnp.float32)
        elif attention:
            obs = _build_obs_attention(layout, arrays, s, free, waiting,
                                       pk_feats, pk_valid)
        else:
            obs = _build_obs(layout, arrays, s, free, waiting, pk_feats,
                             pk_valid)
        # Jobs a host Simulator would drop from the observable window this
        # decision (ScheduleMetrics.truncated_jobs; the attention module
        # still reports window truncation so the A/B comparison reads the
        # same pressure signal for both modules).
        overflow = jnp.maximum(n_waiting - float(W), 0.0).astype(jnp.int32)
        s = {**s, "truncated": s["truncated"] + need * overflow}
        scores = score_fn(policy_state, obs)[:, :W]
        masked = jnp.where(win_valid, scores, -INF)
        a = jnp.argmax(masked, axis=1).astype(jnp.int32)
        if explore:
            k_next, k1, k2 = jax.random.split(s["key"], 3)
            n_valid = win_valid.sum(axis=1).astype(jnp.float32)
            a_rand = jnp.floor(jax.random.uniform(k2, (N,))
                               * jnp.maximum(n_valid, 1.0)).astype(jnp.int32)
            roll = jax.random.uniform(k1, (N,)) < eps
            a = jnp.where(roll, a_rand, a)
            s = {**s, "key": k_next}
        j_star = jnp.take_along_axis(win_idx, a[:, None], axis=1)[:, 0]
        d_star = jnp.take_along_axis(
            arrays["demands"], j_star[:, None, None], axis=1)[:, 0]   # (N, R)
        fits = jnp.all(d_star <= free, axis=1)
        start_env = need & fits
        reserve_env = need & ~fits
        # --- immediate start (scheduling pass continues)
        wall_star = jnp.take_along_axis(arrays["walltime"], j_star[:, None],
                                        axis=1)[:, 0]
        run_star = jnp.take_along_axis(arrays["runtime"], j_star[:, None],
                                       axis=1)[:, 0]
        est = now + wall_star
        release, owner = _alloc_first_free(
            layout, s["release"], s["owner"], start_env, j_star, d_star, est)
        sel = (jidx[None, :] == j_star[:, None]) & start_env[:, None]
        s = {**s, "release": release, "owner": owner,
             "started": s["started"] | sel,
             "start": jnp.where(sel, now[:, None], s["start"]),
             "end": jnp.where(sel, (now + run_star)[:, None], s["end"]),
             "est_end": jnp.where(sel, est[:, None], s["est_end"]),
             "decisions": s["decisions"] + need,
             "first_start": jnp.where(start_env,
                                      jnp.minimum(s["first_start"], now),
                                      s["first_start"])}
        # --- reservation + EASY backfill (scheduling pass ends).  The
        # call is cheap when no env reserved (no fitting candidates ->
        # zero queue-walk iterations, unit assignment conditioned out),
        # so it runs unconditionally rather than behind another cond.
        if layout.backfill:
            s = _easy_backfill(layout, arrays, s, free, reserve_env,
                               waiting, j_star, d_star)
        s = {**s, "in_pass": s["in_pass"] & ~reserve_env}
        a_out = jnp.where(need, a, -1)
        obs_out = obs if collect else jnp.zeros((N, 0), jnp.float32)
        return s, a_out, need, obs_out

    def round_body(s, _):
        s = _advance_events(layout, arrays, s)
        # Single-pop advancement can leave an env in_pass with an empty
        # queue (completion-only timestamp) — only envs with waiting
        # jobs actually need a decision this round.
        arrived = jidx[None, :] < s["n_arrived"][:, None]
        any_need = jnp.any(s["in_pass"] & ~s["done"]
                           & (arrived & ~s["started"]).any(axis=1))

        def live(s):
            return decide(s)

        def idle(s):
            return (s, jnp.full(N, -1, jnp.int32), jnp.zeros(N, bool),
                    jnp.zeros((N, obs_dim if collect else 0), jnp.float32))

        s, a_out, need, obs_out = jax.lax.cond(any_need, live, idle, s)
        return s, (a_out, need, obs_out)

    st, (actions, decided, obs_log) = jax.lax.scan(
        round_body, st, None, length=layout.rounds)
    out = {"started": st["started"], "start": st["start"], "end": st["end"],
           "now": st["now"], "decisions": st["decisions"],
           "truncated": st["truncated"],
           "first_start": st["first_start"], "done": st["done"],
           "actions": actions, "decided": decided}
    if collect:
        out["obs"] = obs_log
    return out


# ====================================================================== host
class DeviceSimulator:
    """N jobsets, one shared cluster spec, one jitted rollout program.

    ``policy`` must implement the device stages of the ``Policy``
    protocol (``init_state`` / ``score_window``); use
    ``repro.core.policy_api.supports_device`` to check.  Construction
    packs the traces into fixed-capacity arrays and compiles the rollout
    on first use; ``run()`` matches the ``Simulator``/``VectorSimulator``
    result contract, ``rollout()`` additionally returns the decision
    trace (and, with ``collect=True``, the packed decision rows for
    training ingestion).
    """

    def __init__(self, resources: Sequence[ResourceSpec],
                 jobsets: Sequence[Sequence[Job]], policy,
                 config: SimConfig | None = None):
        from ..core.policy_api import supports_device
        if not supports_device(policy):
            raise TypeError(
                f"{type(policy).__name__} has no device stages "
                "(init_state/score_window) — run it through Simulator or "
                "VectorSimulator instead")
        if not jobsets or any(len(js) == 0 for js in jobsets):
            raise ValueError("DeviceSimulator needs >= 1 non-empty jobset")
        self.resources = list(resources)
        self.policy = policy
        self.config = config or SimConfig.for_engine("device")
        names = tuple(r.name for r in self.resources)
        caps = tuple(int(r.capacity) for r in self.resources)
        requires_obs = bool(getattr(policy, "requires_obs", True))
        enc = getattr(policy, "enc", None)
        if requires_obs:
            assert enc is not None, \
                f"{type(policy).__name__} requires obs but has no enc"
            if tuple(enc.resource_names) != names:
                raise ValueError(
                    f"policy encodes resources {tuple(enc.resource_names)} "
                    f"but the cluster has {names}")
            if int(enc.window) != int(self.config.window):
                raise ValueError(
                    f"policy window {enc.window} != sim window "
                    f"{self.config.window} — the device engine scores "
                    "exactly the simulation window")
            enc_caps = tuple(int(c) for c in enc.capacities)
            time_scale = float(enc.time_scale)
            state_module = str(getattr(enc, "state_module", "mlp"))
            queue_cap = int(getattr(enc, "queue_cap", 0))
        else:
            enc_caps = caps
            time_scale = 86400.0
            state_module = "mlp"
            queue_cap = 0

        self.jobsets = [sorted((j.copy() for j in js),
                               key=lambda j: (j.submit, j.jid))
                        for js in jobsets]
        N = len(self.jobsets)
        J = max(len(js) for js in self.jobsets)
        rounds = 3 * J + 2
        if self.config.max_rounds is not None:
            rounds = min(rounds, int(self.config.max_rounds))
        self.layout = DeviceLayout(
            names=names, caps=caps, enc_caps=enc_caps,
            window=int(self.config.window), n_envs=N, n_jobs=J,
            rounds=rounds, backfill=bool(self.config.backfill),
            requires_obs=requires_obs, time_scale=time_scale,
            state_module=state_module, queue_cap=queue_cap)
        self.arrays = self._pack(self.jobsets)
        self.stats = DeviceStats()
        self._jitted: Dict[Tuple[bool, bool], object] = {}

    # ------------------------------------------------------------- packing
    def _pack(self, jobsets) -> Dict[str, jnp.ndarray]:
        lay = self.layout
        N, J, R = lay.n_envs, lay.n_jobs, lay.n_resources
        submit = np.full((N, J), np.inf, np.float64)
        runtime = np.zeros((N, J), np.float64)
        walltime = np.zeros((N, J), np.float64)
        demands = np.zeros((N, J, R), np.float32)
        static = np.zeros((N, J, R + 1), np.float32)
        caps_f = [float(max(c, 1)) for c in lay.caps]
        for i, js in enumerate(jobsets):
            for j, job in enumerate(js):
                submit[i, j] = job.submit
                runtime[i, j] = job.runtime
                walltime[i, j] = job.walltime
                for r, n in enumerate(lay.names):
                    d = job.demands.get(n, 0)
                    demands[i, j, r] = d
                    static[i, j, r] = d / caps_f[r]       # f64 div, f32 store
                static[i, j, R] = job.walltime / lay.time_scale
        submit_ext = np.concatenate(
            [submit, np.full((N, 1), np.inf)], axis=1)
        return {
            "submit": jnp.asarray(submit, jnp.float32),
            "submit_ext": jnp.asarray(submit_ext, jnp.float32),
            "submit_feat": jnp.asarray(
                np.where(np.isfinite(submit), submit, 0.0), jnp.float32),
            "runtime": jnp.asarray(runtime, jnp.float32),
            "walltime": jnp.asarray(walltime, jnp.float32),
            "demands": jnp.asarray(demands),
            "static_feats": jnp.asarray(static),
        }

    # ------------------------------------------------------------- rollout
    def _fn(self, explore: bool, collect: bool):
        key = (explore, collect)
        if key not in self._jitted:
            self._jitted[key] = jax.jit(functools.partial(
                _device_rollout, self.layout, self.policy.score_window,
                explore, collect))
        return self._jitted[key]

    def rollout(self, eps: Optional[float] = None, seed: int = 0,
                collect: bool = False) -> DeviceRollout:
        """Run every environment to completion in one device program.

        ``eps``: when set, actions are epsilon-greedy with in-graph
        (jax.random) draws — the device counterpart of the agent's
        training exploration (note: a *different* RNG stream than the
        host engines' numpy draws).  ``collect=True`` additionally
        returns the packed decision rows for trainer ingestion.
        """
        explore = eps is not None
        out = self._fn(explore, collect)(
            self.arrays, self.policy.init_state(),
            jnp.float32(0.0 if eps is None else eps),
            jax.random.PRNGKey(seed))
        out = {k: np.asarray(v) for k, v in out.items()}
        if not out["done"].all():
            raise RuntimeError(
                f"device rollout exhausted its round budget "
                f"({self.layout.rounds}); raise SimConfig.max_rounds")
        decided = out["decided"]
        self.stats = DeviceStats(
            rounds=int(decided.any(axis=1).sum()),
            decisions=int(decided.sum()),
            policy_calls=int(decided.any(axis=1).sum()),
            max_batch=int(decided.sum(axis=1).max(initial=0)))
        return DeviceRollout(
            actions=out["actions"], decided=decided,
            stats=self.stats, obs=out.get("obs"),
            _build=lambda: self._results(out))

    def run(self) -> List[SimResult]:
        """Greedy rollout; result contract matches the host engines."""
        return self.rollout().results

    # ------------------------------------------------------------- results
    def _results(self, out) -> List[SimResult]:
        results = []
        for i, js in enumerate(self.jobsets):
            started_m = out["started"][i]
            jobs = []
            for j, job in enumerate(js):
                job = job.copy()
                if started_m[j]:
                    job.start = float(out["start"][i, j])
                    job.end = float(out["end"][i, j])
                jobs.append(job)
            started = [jb for jb in jobs if jb.started]
            cluster = Cluster(self.resources)
            acc = MetricsAccumulator(cluster)
            acc.last_time = float(out["now"][i])
            acc.start_time = (float(out["first_start"][i]) if started
                              else None)
            for r, n in enumerate(self.layout.names):
                acc.busy_area[n] = float(sum(
                    jb.demands.get(n, 0) * (jb.end - jb.start)
                    for jb in started))
            metrics = acc.summarize(started)
            metrics.truncated_jobs = int(out["truncated"][i])
            results.append(SimResult(
                metrics=metrics,
                jobs=jobs,
                makespan=float(out["now"][i]),
                decisions=int(out["decisions"][i]),
                n_unstarted=len(jobs) - len(started),
                truncated_jobs=int(out["truncated"][i])))
        return results


def run_traces_device(resources: Sequence[ResourceSpec],
                      jobsets: Sequence[Sequence[Job]], policy,
                      config: SimConfig | None = None) -> List[SimResult]:
    """Convenience device counterpart of ``run_trace``/``run_traces``."""
    cfg = config or SimConfig.for_engine("device")
    return DeviceSimulator(resources, jobsets, policy, cfg).run()
