"""Device-resident rollout engine: the whole simulation loop in one jit.

``DeviceSimulator`` runs N independent trace simulations as ONE device
program: a ``lax.scan`` over scheduling rounds whose body advances
lifecycle events (one coalesced-timestamp pop per round, which the round
budget covers), packs the first-W waiting jobs per environment
(``repro.kernels.window_pack``), builds the packed decision rows
in-graph, scores them with the policy's pure ``score_window`` stage
(``repro.core.policy_api``), and applies the selected action — immediate
start with first-free unit allocation, or a reservation with
EASY-backfill shadow accounting.  The host engines pay a Python round
trip per scheduling round; here the only host work is packing the traces
up front and summarizing metrics at the end.

The job lifecycle (``repro.sim.lifecycle``) is folded into the pump via
the pure ``device_*`` transitions: per-job READY times replace the old
arrival pointer (``max(submit, max_parent(end) + think)``, ``+inf``
while a parent is unfinished), attempt ends are attempt-aware (a
failure-point attempt is killed and requeued instead of finishing),
and drain/restore events kill residents and phantom-reserve unit ranges
(owner ``PHANTOM_OWNER``) exactly like the host's ``JobLifecycle``.
Traces without dependencies, failure points, or drains stage the same
lean graph as before — the extra transitions are Python staging-time
branches on zero-size axes.

State layout (leading axis = environment):

* job arrays ``(N, J)`` — submit/runtime/walltime (f32, padded jobs
  carry ``submit = +inf`` so they never arrive) and demands ``(N, J, R)``
  (f32 unit counts; exact below 2**24); dependency indices ``(N, J, P)``
  (packed job index, -1 = none), think times ``(N, J)`` and failure
  points ``(N, J, A)`` (+inf padded);
* lifecycle state ``(N, J)`` — ``ready``/``started``/``finished``/
  ``failed`` masks, ``requeues``/``cur_fail`` attempt state,
  ``first_start_j``/``failed_work`` accounting; the waiting queue in
  (original submit, jid) order is exactly "ready and in no other live
  state, in ascending job index", which is what the window-pack kernel
  assumes (requeued jobs re-enter at their original position for free);
* per-unit cluster state ``(N, U)`` with ``U = sum(capacities)`` —
  ``release`` (estimated release time, 0 = free, mirroring
  ``Cluster.release``; drained units carry their restore time) and
  ``owner`` (job index, -1 free, -2 phantom/drained), in fixed
  per-resource segments;
* scalars per env — ``now``, ``in_pass``, ``done``, ``decisions``.

Semantics mirror ``Simulator`` event for event (coalesced timestamps
applied ends -> queue entries -> drains -> restores, scheduling-pass
continuation, first-free unit allocation, reservation at the earliest
fit time, shadow-debit backfill in queue order), so an N=1 rollout
reproduces the sequential engine round for round; times are float32 on
device, so derived metrics agree to float32 precision (pinned in
``tests/test_device.py``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.window_pack.ops import pack_window
from ..obs.profiling import annotate
from ..obs.trace import Tracer
from .cluster import TTF_HORIZON, Cluster, ResourceSpec
from .job import Job
from .lifecycle import (FAILED, FINISHED, FaultSchedule, device_apply_drains,
                        device_apply_ends, device_apply_restores,
                        device_attempt, device_next_event, device_queued,
                        device_ready, resolve_faults)
from .metrics import MetricsAccumulator
from .simulator import SimConfig, SimResult

INF = jnp.float32(jnp.inf)


class DeviceFaults(NamedTuple):
    """Packed fault schedules, one row per environment (D = max drains).

    Unused drain slots carry ``drain_t = +inf`` so they never fire;
    ``unit_seg``/``unit_local`` map every packed unit to its (resource
    segment, within-segment index) so a drain's "first k units of
    resource r" range is one vectorized compare."""
    drain_t: jnp.ndarray        # (N, D) f32, +inf = unused slot
    restore_t: jnp.ndarray      # (N, D) f32, +inf = permanent drain
    drain_res: jnp.ndarray      # (N, D) i32 resource segment index
    drain_units: jnp.ndarray    # (N, D) i32 leading units drained
    unit_seg: jnp.ndarray       # (U,)  i32 segment of each packed unit
    unit_local: jnp.ndarray     # (U,)  i32 index within the segment
    max_requeues: jnp.ndarray   # (N, 1) i32 requeue bound per env


@dataclass(frozen=True)
class DeviceLayout:
    """Static shape/semantic configuration baked into the jitted rollout."""
    names: Tuple[str, ...]
    caps: Tuple[int, ...]            # actual cluster capacities
    enc_caps: Tuple[int, ...]        # encoding section sizes (reference caps)
    window: int
    n_envs: int
    n_jobs: int                      # J, padded job axis
    rounds: int                      # T, scan length
    backfill: bool
    requires_obs: bool
    time_scale: float
    state_module: str = "mlp"        # mirrors EncodingConfig.state_module
    queue_cap: int = 0               # Q, attention layout only

    @property
    def n_resources(self) -> int:
        return len(self.names)

    @property
    def node_idx(self) -> int:
        """Resource anchoring the failed-work metric (JobLifecycle.primary)."""
        return self.names.index("node") if "node" in self.names else 0

    @property
    def segments(self) -> Tuple[Tuple[int, int], ...]:
        """(offset, capacity) per resource into the packed unit axis."""
        segs, off = [], 0
        for c in self.caps:
            segs.append((off, c))
            off += c
        return tuple(segs)

    @property
    def n_units(self) -> int:
        return int(sum(self.caps))

    @property
    def state_dim(self) -> int:
        if self.state_module == "attention":
            return (self.queue_cap * (self.n_resources + 2) + 1
                    + 2 * self.n_resources)
        return self.window * (self.n_resources + 2) + 2 * int(sum(self.enc_caps))


@dataclass
class DeviceStats:
    """Mirror of ``VectorStats`` for the device engine."""
    rounds: int = 0
    decisions: int = 0
    policy_calls: int = 0            # one in-graph score per active round
    max_batch: int = 0

    def as_dict(self) -> dict:
        return {"rounds": self.rounds, "decisions": self.decisions,
                "policy_calls": self.policy_calls,
                "max_batch": self.max_batch}


@dataclass
class DeviceRollout:
    """One device rollout: per-env results plus the decision trace.

    ``results`` materializes lazily on first access: rebuilding per-job
    Python objects for every environment is host-side work that
    collection-mode consumers (which ingest the packed decision trace,
    not ``SimResult``s) should not pay inside the rollout hot path.
    """
    actions: np.ndarray              # (T, N) int32, -1 where no decision
    decided: np.ndarray              # (T, N) bool
    stats: DeviceStats
    obs: Optional[np.ndarray] = None  # (T, N, row_dim) packed decision rows
    trace: Optional[Dict[str, np.ndarray]] = None  # rollout(trace=True):
    #   per-round state deltas + decision extras, decoded into mrsch.trace
    #   events by DeviceSimulator.emit_trace
    _build: Optional[Callable[[], List[SimResult]]] = field(
        default=None, repr=False)
    _cache: Optional[List[SimResult]] = field(default=None, repr=False)

    @property
    def results(self) -> List[SimResult]:
        """Per-env ``SimResult``s in jobset order (built on demand)."""
        if self._cache is None:
            self._cache = self._build()
        return self._cache

    def transitions(self):
        """Yield (round, env, obs_row, action) for every decision taken,
        in round order — the order the host trainer must ingest them to
        keep each environment's trajectory contiguous."""
        assert self.obs is not None, "rollout was not collected"
        for t in range(self.decided.shape[0]):
            for i in np.flatnonzero(self.decided[t]):
                yield t, int(i), self.obs[t, i], int(self.actions[t, i])


# ===================================================================== graph
def _segment_free(layout: DeviceLayout, release: jnp.ndarray) -> jnp.ndarray:
    """Free-unit counts per resource, (N, R) float32."""
    cols = [jnp.sum(release[:, off:off + cap] == 0.0, axis=1)
            for off, cap in layout.segments]
    return jnp.stack(cols, axis=1).astype(jnp.float32)


def _advance_events(layout: DeviceLayout, arrays, faults: DeviceFaults, st):
    """Batched event step: pop+apply ONE coalesced timestamp per env not
    inside a scheduling pass.  Runs inline in the round body (no
    ``while_loop`` — its computation boundaries dominate the per-round
    cost on small problems); an env that pops a decision-free timestamp
    simply pops again next round, which the round budget covers.

    Events at one timestamp apply in the host engines' kind order:
    attempt ends (clean finish or failure-point kill), then queue
    entries (implicit — the queued mask is derived from READY times),
    then drains, then restores."""
    P = arrays["deps_idx"].shape[2]
    A = arrays["fail_times"].shape[2]
    D = faults.drain_t.shape[1]
    s = dict(st)
    # A pass over an empty queue ends silently (Simulator.next_decision).
    queued_any = device_queued(s["ready"], s["now"], s["started"],
                               s["finished"], s["failed"]).any(axis=1)
    in_pass = s["in_pass"] & queued_any
    adv = ~in_pass & ~s["done"]
    t = device_next_event(s["now"], s["ready"], s["end"], s["started"],
                          s["finished"], s["failed"],
                          faults if D else None, s)
    no_ev = ~jnp.isfinite(t)
    s["done"] = s["done"] | (adv & no_ev)
    act = adv & ~no_ev
    s["now"] = jnp.where(act, t, s["now"])
    s = device_apply_ends(t, act, arrays["demands"], layout.node_idx,
                          faults.max_requeues, s, has_kills=(A > 0 or D > 0))
    if D:
        s = device_apply_drains(t, act, faults, arrays["demands"],
                                layout.node_idx, s)
        s = device_apply_restores(t, act, faults, s)
    if P:
        # Finishes may have released dependents: recompute READY times.
        s["ready"] = device_ready(arrays["submit"], arrays["deps_idx"],
                                  arrays["think"], s["end"], s["finished"])
    s["in_pass"] = in_pass | act
    return s


def _alloc_first_free(layout: DeviceLayout, release, owner, env_mask,
                      job_idx, demand, est):
    """Allocate ``demand`` (N, R) lowest-index free units for ``job_idx``
    in every env of ``env_mask`` (mirrors ``Cluster.allocate``)."""
    for r, (off, cap) in enumerate(layout.segments):
        seg = release[:, off:off + cap]
        freemask = seg == 0.0
        rank = jnp.cumsum(freemask.astype(jnp.float32), axis=1)
        take = (freemask & (rank <= demand[:, r:r + 1])
                & env_mask[:, None])
        release = release.at[:, off:off + cap].set(
            jnp.where(take, est[:, None], seg))
        owner = owner.at[:, off:off + cap].set(
            jnp.where(take, job_idx[:, None], owner[:, off:off + cap]))
    return release, owner


def _earliest_fit(layout: DeviceLayout, release, free, demand, now):
    """Per-env earliest time ``demand`` fits assuming estimated releases
    (mirrors ``Cluster.earliest_fit_time``): the need-th smallest release
    per resource (free units sort first as 0.0), max over resources.
    Permanently drained units carry ``release = +inf`` and therefore
    never count toward a future fit, exactly like the host."""
    t_res = now
    for r, (off, cap) in enumerate(layout.segments):
        seg_sorted = jnp.sort(release[:, off:off + cap], axis=1)
        need = demand[:, r]
        kth_idx = jnp.clip(need.astype(jnp.int32) - 1, 0, cap - 1)
        kth = jnp.take_along_axis(seg_sorted, kth_idx[:, None], axis=1)[:, 0]
        t_r = jnp.where(need <= free[:, r], now,
                        jnp.where(need <= float(cap), kth, INF))
        t_res = jnp.maximum(t_res, t_r)
    return t_res


def _easy_backfill(layout: DeviceLayout, arrays, st, free, need, waiting,
                   j_star, d_star, dur_all, will_fail_all):
    """EASY backfill for envs whose selection did not fit (vectorized
    mirror of ``Simulator._easy_backfill``): reservation at the earliest
    fit time, shadow accounting in queue order, then one batched
    first-fit unit assignment for every job that may jump ahead.
    ``dur_all``/``will_fail_all`` describe each job's NEXT attempt
    (``lifecycle.device_attempt``) so a backfilled doomed attempt ends at
    its failure point, exactly like an immediate start."""
    N, J, R = layout.n_envs, layout.n_jobs, layout.n_resources
    now = st["now"]
    t_res = _earliest_fit(layout, st["release"], free, d_star, now)
    do_bf = need & jnp.isfinite(t_res)
    # Shadow: free units at t_res (estimated releases) minus the
    # reservation's demand, per resource.
    shadow_cols = []
    for r, (off, cap) in enumerate(layout.segments):
        free_at = jnp.sum(st["release"][:, off:off + cap] <= t_res[:, None],
                          axis=1).astype(jnp.float32)
        shadow_cols.append(free_at - d_star[:, r])
    shadow = jnp.stack(shadow_cols, axis=1)

    ends_before_all = arrays["walltime"] + now[:, None] <= t_res[:, None]

    # The queue walk's carry only changes when a candidate actually
    # starts, and availability only ever decreases — so walking the
    # queue in order debiting as we go is equivalent to repeatedly
    # starting the FIRST still-fitting candidate.  That turns an O(J)
    # sequential scan into a while_loop with one iteration per started
    # job (almost always 0-2), each a vectorized pass over the queue.
    jidx = jnp.arange(J)
    cand = (do_bf[:, None] & (waiting > 0.5)
            & (jidx[None, :] != j_star[:, None]))          # (N, J)

    def fitting(free_c, shadow_c, go):
        # Per-resource (N, J) compares: XLA:CPU runs these an order of
        # magnitude faster than the equivalent (N, J, R) broadcast+all.
        fits_now = cand & ~go
        shadow_ok = None
        for r in range(R):
            d_r = arrays["demands"][:, :, r]
            fits_now = fits_now & (d_r <= free_c[:, r:r + 1])
            s_r = d_r <= shadow_c[:, r:r + 1]
            shadow_ok = s_r if shadow_ok is None else shadow_ok & s_r
        return fits_now & (ends_before_all | shadow_ok)

    # The loop carries the fit matrix so the condition is a 1-op any()
    # and each iteration evaluates ``fitting`` exactly once.  Each
    # iteration accepts a whole PREFIX of the fitting candidates: a
    # candidate is accepted when the cumulative demand of accepted
    # candidates up to and including it still fits (free and shadow) —
    # exactly the debits the sequential walk would have applied — and
    # the first cumulative failure blocks the rest of the queue until
    # the next iteration re-evaluates them against the debited carry.
    # One iteration per *blocking event* instead of one per start.
    def cond(c):
        return c[3].any()

    def body(c):
        free_c, shadow_c, go, ok = c
        ok_f = ok.astype(jnp.float32)
        debit_f = (ok & ~ends_before_all).astype(jnp.float32)
        free_ok = None
        shadow_fit = None
        d_acc_cols = []
        for r in range(R):
            d_r = arrays["demands"][:, :, r]
            cum_r = jnp.cumsum(ok_f * d_r, axis=1)
            f_r = cum_r <= free_c[:, r:r + 1]
            free_ok = f_r if free_ok is None else free_ok & f_r
            cums_r = jnp.cumsum(debit_f * d_r, axis=1)
            s_r = cums_r <= shadow_c[:, r:r + 1]
            shadow_fit = s_r if shadow_fit is None else shadow_fit & s_r
            d_acc_cols.append(d_r)
        passes = free_ok & (ends_before_all | shadow_fit)
        fail = ok & ~passes
        accept = ok & passes & (jnp.cumsum(fail.astype(jnp.int32), axis=1)
                                == 0)
        acc_f = accept.astype(jnp.float32)
        acc_debit_f = (accept & ~ends_before_all).astype(jnp.float32)
        d_used = jnp.stack(
            [(acc_f * d_r).sum(axis=1) for d_r in d_acc_cols], axis=1)
        s_used = jnp.stack(
            [(acc_debit_f * d_r).sum(axis=1) for d_r in d_acc_cols], axis=1)
        free_c = free_c - d_used
        shadow_c = shadow_c - s_used
        go = go | accept
        return (free_c, shadow_c, go, fitting(free_c, shadow_c, go))

    go0 = jnp.zeros((N, J), bool)
    _, _, bf_start, _ = jax.lax.while_loop(
        cond, body, (free, shadow, go0, fitting(free, shadow, go0)))

    # Unit assignment, one batched pass per resource: job j takes the
    # free units whose free-rank falls in its cumulative-demand span —
    # identical to allocating each job first-fit in queue order.  Most
    # reservation rounds backfill nothing, so the whole phase is
    # conditioned on some env actually starting a job.
    def assign_units(st):
        est_all = now[:, None] + arrays["walltime"]            # (N, J)
        release, owner = st["release"], st["owner"]
        jidx_f = jnp.arange(J, dtype=jnp.float32)
        for r, (off, cap) in enumerate(layout.segments):
            seg = release[:, off:off + cap]
            freemask = seg == 0.0
            k = jnp.cumsum(freemask.astype(jnp.float32), axis=1)  # (N, cap)
            need_j = arrays["demands"][:, :, r] * bf_start         # (N, J)
            cum = jnp.cumsum(need_j, axis=1)
            assign = (freemask[:, :, None] & bf_start[:, None, :]
                      & (k[:, :, None] > (cum - need_j)[:, None, :])
                      & (k[:, :, None] <= cum[:, None, :]))        # (N, cap, J)
            assign_f = assign.astype(jnp.float32)
            any_assign = assign.any(axis=2)
            owner_val = jnp.einsum("nuj,j->nu", assign_f, jidx_f)
            rel_val = jnp.einsum("nuj,nj->nu", assign_f, est_all)
            release = release.at[:, off:off + cap].set(
                jnp.where(any_assign, rel_val, seg))
            owner = owner.at[:, off:off + cap].set(
                jnp.where(any_assign, owner_val.astype(jnp.int32),
                          owner[:, off:off + cap]))

        started = st["started"] | bf_start
        start = jnp.where(bf_start, now[:, None], st["start"])
        end = jnp.where(bf_start, now[:, None] + dur_all, st["end"])
        est_end = jnp.where(bf_start, est_all, st["est_end"])
        fsj = jnp.where(bf_start & (st["first_start_j"] < 0),
                        now[:, None], st["first_start_j"])
        any_bf = bf_start.any(axis=1)
        first = jnp.where(any_bf, jnp.minimum(st["first_start"], now),
                          st["first_start"])
        out = {**st, "release": release, "owner": owner,
               "started": started, "start": start, "end": end,
               "est_end": est_end, "first_start": first,
               "first_start_j": fsj}
        if will_fail_all is not None:
            out["cur_fail"] = jnp.where(bf_start, will_fail_all,
                                        st["cur_fail"])
        return out

    return jax.lax.cond(bf_start.any(), assign_units, lambda st: st, st)


def _meas_goal(layout: DeviceLayout, arrays, st, free, waiting,
               has_drains: bool):
    """Measurement (utilization) + Eq. (1) goal, (N, R) each — the shared
    tail of every packed decision row, module-independent.  Drained
    (phantom-owned) units are neither busy nor free, matching
    ``Cluster.utilization``."""
    from .lifecycle import PHANTOM_OWNER
    R = layout.n_resources
    now = st["now"]
    caps_f = jnp.asarray([max(c, 1) for c in layout.caps], jnp.float32)
    if has_drains:
        ph_cols = [jnp.sum(st["owner"][:, off:off + cap] == PHANTOM_OWNER,
                           axis=1)
                   for off, cap in layout.segments]
        phantom = jnp.stack(ph_cols, axis=1).astype(jnp.float32)
        meas = 1.0 - (free + phantom) / caps_f[None, :]
    else:
        meas = 1.0 - free / caps_f[None, :]
    # Eq. (1) goal over the full waiting queue + running remainders.
    running = st["started"] & ~st["finished"]
    tw = (arrays["walltime"] * waiting
          + jnp.maximum(st["est_end"] - now[:, None], 0.0) * running)
    acc = jnp.einsum("nj,njr->nr", tw, arrays["demands"])
    demand_time = acc / caps_f[None, :]
    total = demand_time.sum(axis=1, keepdims=True)
    goal = jnp.where(total > 0, demand_time / jnp.maximum(total, 1e-30),
                     1.0 / R)
    return meas, goal


def _job_tokens(layout: DeviceLayout, st, win_feats, win_valid):
    """Packed job slots -> [fracs(R), walltime_norm, queued_norm] tokens.

    [fracs(R), walltime_norm] are static per job; the queued-time column
    is derived from the packed raw submit times.  Invalid slots are
    all-zero (``pack_window`` zero-fills their features)."""
    R = layout.n_resources
    ts = jnp.float32(layout.time_scale)
    valid_f = win_valid.astype(jnp.float32)
    queued = (st["now"][:, None] - win_feats[..., R + 1]) / ts * valid_f
    return jnp.concatenate([win_feats[..., :R + 1], queued[..., None]],
                           axis=-1)


def _build_obs(layout: DeviceLayout, arrays, st, win_feats, win_valid,
               meas, goal):
    """Packed decision rows [state | meas | goal | valid] in-graph,
    mirroring ``encoding.encode_decision_row`` (float32 throughout)."""
    N, R, W = layout.n_envs, layout.n_resources, layout.window
    ts = jnp.float32(layout.time_scale)
    now = st["now"]
    valid_f = win_valid.astype(jnp.float32)
    win = _job_tokens(layout, st, win_feats, win_valid)
    parts = [win.reshape(N, W * (R + 2))]
    # Unit sections use the encoding's reference section sizes; a cluster
    # with fewer units fills the leading slots (encode_state semantics).
    # avail/ttf are computed once over the whole unit axis; the per-
    # segment views below are free slices.  The TTF_HORIZON clip keeps
    # permanently drained units (release = +inf) out of the features,
    # matching encode_state.
    busy_all = st["release"] > 0.0
    avail_all = jnp.where(busy_all, 0.0, 1.0)
    ttf_all = jnp.where(
        busy_all,
        jnp.clip(st["release"] - now[:, None], 0.0, TTF_HORIZON),
        0.0) / ts
    for r, (off, cap) in enumerate(layout.segments):
        k = min(cap, int(layout.enc_caps[r]))
        avail = avail_all[:, off:off + k]
        ttf = ttf_all[:, off:off + k]
        pad = int(layout.enc_caps[r]) - k
        if pad:
            zeros = jnp.zeros((N, pad), jnp.float32)
            avail = jnp.concatenate([avail, zeros], axis=1)
            ttf = jnp.concatenate([ttf, zeros], axis=1)
        parts.extend([avail, ttf])
    return jnp.concatenate(parts + [meas, goal, valid_f], axis=1)


def _build_obs_attention(layout: DeviceLayout, arrays, st, waiting,
                         q_feats, q_valid, meas, goal):
    """Attention-layout decision rows, mirroring ``encoding.encode_state``
    with ``state_module="attention"``:
    ``[Q*(R+2) tokens | queue_len | 2R context | meas | goal | valid(W)]``.
    ``q_feats``/``q_valid`` pack the first ``queue_cap`` waiting jobs; the
    leading W slots are exactly the action window."""
    N, R, W = layout.n_envs, layout.n_resources, layout.window
    Q = layout.queue_cap
    ts = jnp.float32(layout.time_scale)
    now = st["now"]
    tok = _job_tokens(layout, st, q_feats, q_valid)
    qlen = jnp.minimum(waiting.sum(axis=1), float(Q))
    ctx_cols = []
    for r, (off, cap) in enumerate(layout.segments):
        seg = st["release"][:, off:off + cap]
        busy = seg > 0.0
        nb = busy.sum(axis=1).astype(jnp.float32)
        ctx_cols.append(1.0 - nb / float(max(cap, 1)))       # free fraction
        ttf_sum = jnp.where(
            busy, jnp.clip(seg - now[:, None], 0.0, TTF_HORIZON),
            0.0).sum(axis=1)
        ctx_cols.append(jnp.where(nb > 0, ttf_sum / jnp.maximum(nb, 1.0), 0.0)
                        / ts)                                # mean time-to-free
    return jnp.concatenate(
        [tok.reshape(N, Q * (R + 2)), qlen[:, None],
         jnp.stack(ctx_cols, axis=1), meas, goal,
         q_valid[:, :W].astype(jnp.float32)], axis=1)


def _device_rollout(layout: DeviceLayout, score_fn, explore: bool,
                    collect: bool, trace: bool, arrays,
                    faults: DeviceFaults, policy_state, eps, key):
    """The whole N-env x T-round rollout as one traced program.

    ``trace`` (static) additionally scans out per-round lifecycle deltas
    and decision extras — tiny boolean/int arrays carried through the
    scan so the hot loop stays device-resident — which
    ``DeviceSimulator.emit_trace`` decodes post-run into the same
    ``mrsch.trace/v1`` event stream the host engines emit inline."""
    N, J, R, W = (layout.n_envs, layout.n_jobs, layout.n_resources,
                  layout.window)
    P = arrays["deps_idx"].shape[2]
    A = arrays["fail_times"].shape[2]
    D = faults.drain_t.shape[1]
    has_drains = D > 0
    jidx = jnp.arange(J)
    end0 = jnp.full((N, J), jnp.inf, jnp.float32)
    finished0 = jnp.zeros((N, J), bool)
    falses0 = jnp.zeros((N, J), bool)
    now0 = jnp.zeros(N, jnp.float32)
    ready0 = device_ready(arrays["submit"], arrays["deps_idx"],
                          arrays["think"], end0, finished0)
    # Jobs ready at t=0 are queued before any event can fire (the pending-
    # ready event below is strictly future), so their scheduling pass is
    # seeded here — the host's t=0 submit pop.
    in_pass0 = device_queued(ready0, now0, falses0, finished0,
                             falses0).any(axis=1)
    st = {
        "now": now0,
        "ready": ready0,
        "started": jnp.zeros((N, J), bool),
        "finished": finished0,
        "failed": jnp.zeros((N, J), bool),
        "start": jnp.full((N, J), -1.0, jnp.float32),
        "end": end0,
        "est_end": jnp.zeros((N, J), jnp.float32),
        "first_start_j": jnp.full((N, J), -1.0, jnp.float32),
        "requeues": jnp.zeros((N, J), jnp.int32),
        "cur_fail": jnp.zeros((N, J), bool),
        "failed_work": jnp.zeros((N, J), jnp.float32),
        "failed_area": jnp.zeros((N, R), jnp.float32),
        "release": jnp.zeros((N, layout.n_units), jnp.float32),
        "owner": jnp.full((N, layout.n_units), -1, jnp.int32),
        "drain_done": jnp.zeros((N, D), bool),
        "restore_done": jnp.zeros((N, D), bool),
        "in_pass": in_pass0,
        "done": jnp.zeros(N, bool),
        "decisions": jnp.zeros(N, jnp.int32),
        "truncated": jnp.zeros(N, jnp.int32),
        "first_start": jnp.full(N, jnp.inf, jnp.float32),
        "key": key,
    }
    obs_dim = (layout.state_dim + 2 * R + W) if layout.requires_obs else W

    # Constant per rollout: keep the concat out of the per-round body.
    feats = jnp.concatenate(
        [arrays["static_feats"], arrays["submit_feat"][..., None]],
        axis=-1)

    def decide(s):
        now = s["now"]
        waiting = device_queued(s["ready"], now, s["started"], s["finished"],
                                s["failed"]).astype(jnp.float32)
        n_waiting = waiting.sum(axis=1)
        need = s["in_pass"] & (n_waiting > 0) & ~s["done"]
        free = _segment_free(layout, s["release"])
        # The attention module observes the first queue_cap waiting jobs;
        # one pack covers both the Q-token state and (its leading W
        # slots) the action window.
        attention = layout.state_module == "attention"
        K = layout.queue_cap if attention else W
        pk_feats, pk_idx, pk_valid = pack_window(waiting, feats, window=K)
        win_idx, win_valid = pk_idx[:, :W], pk_valid[:, :W]
        if not layout.requires_obs:
            obs = win_valid.astype(jnp.float32)
        else:
            meas, goal = _meas_goal(layout, arrays, s, free, waiting,
                                    has_drains)
            if attention:
                obs = _build_obs_attention(layout, arrays, s, waiting,
                                           pk_feats, pk_valid, meas, goal)
            else:
                obs = _build_obs(layout, arrays, s, pk_feats, pk_valid,
                                 meas, goal)
        # Jobs a host Simulator would drop from the observable window this
        # decision (ScheduleMetrics.truncated_jobs; the attention module
        # still reports window truncation so the A/B comparison reads the
        # same pressure signal for both modules).
        overflow = jnp.maximum(n_waiting - float(W), 0.0).astype(jnp.int32)
        s = {**s, "truncated": s["truncated"] + need * overflow}
        scores = score_fn(policy_state, obs)[:, :W]
        masked = jnp.where(win_valid, scores, -INF)
        a = jnp.argmax(masked, axis=1).astype(jnp.int32)
        if explore:
            k_next, k1, k2 = jax.random.split(s["key"], 3)
            n_valid = win_valid.sum(axis=1).astype(jnp.float32)
            a_rand = jnp.floor(jax.random.uniform(k2, (N,))
                               * jnp.maximum(n_valid, 1.0)).astype(jnp.int32)
            roll = jax.random.uniform(k1, (N,)) < eps
            a = jnp.where(roll, a_rand, a)
            s = {**s, "key": k_next}
        j_star = jnp.take_along_axis(win_idx, a[:, None], axis=1)[:, 0]
        d_star = jnp.take_along_axis(
            arrays["demands"], j_star[:, None, None], axis=1)[:, 0]   # (N, R)
        fits = jnp.all(d_star <= free, axis=1)
        start_env = need & fits
        reserve_env = need & ~fits
        # --- immediate start (scheduling pass continues).  The attempt's
        # actual duration is its failure point when the attempt is doomed
        # (lifecycle.device_attempt); the unit-release ESTIMATE still uses
        # the walltime, exactly like the host.
        if A:
            dur_all, will_fail_all = device_attempt(
                arrays["fail_times"], s["requeues"], arrays["runtime"])
        else:
            dur_all, will_fail_all = arrays["runtime"], None
        wall_star = jnp.take_along_axis(arrays["walltime"], j_star[:, None],
                                        axis=1)[:, 0]
        run_star = jnp.take_along_axis(dur_all, j_star[:, None],
                                       axis=1)[:, 0]
        est = now + wall_star
        release, owner = _alloc_first_free(
            layout, s["release"], s["owner"], start_env, j_star, d_star, est)
        sel = (jidx[None, :] == j_star[:, None]) & start_env[:, None]
        s = {**s, "release": release, "owner": owner,
             "started": s["started"] | sel,
             "start": jnp.where(sel, now[:, None], s["start"]),
             "end": jnp.where(sel, (now + run_star)[:, None], s["end"]),
             "est_end": jnp.where(sel, est[:, None], s["est_end"]),
             "first_start_j": jnp.where(sel & (s["first_start_j"] < 0),
                                        now[:, None], s["first_start_j"]),
             "decisions": s["decisions"] + need,
             "first_start": jnp.where(start_env,
                                      jnp.minimum(s["first_start"], now),
                                      s["first_start"])}
        if A:
            wf_star = jnp.take_along_axis(will_fail_all, j_star[:, None],
                                          axis=1)[:, 0]
            s = {**s, "cur_fail": jnp.where(sel, wf_star[:, None],
                                            s["cur_fail"])}
        # --- reservation + EASY backfill (scheduling pass ends).  The
        # call is cheap when no env reserved (no fitting candidates ->
        # zero queue-walk iterations, unit assignment conditioned out),
        # so it runs unconditionally rather than behind another cond.
        if layout.backfill:
            s = _easy_backfill(layout, arrays, s, free, reserve_env,
                               waiting, j_star, d_star, dur_all,
                               will_fail_all)
        s = {**s, "in_pass": s["in_pass"] & ~reserve_env}
        a_out = jnp.where(need, a, -1)
        obs_out = obs if collect else jnp.zeros((N, 0), jnp.float32)
        dec = ((j_star, fits, n_waiting.astype(jnp.int32)) if trace else ())
        return s, a_out, need, obs_out, dec

    def round_body(s, _):
        # Two-stage snapshots (pre-advance, post-advance): the deltas
        # distinguish advance-phase transitions (finish / fail / requeue
        # / drain / restore) from decide-phase starts, so a job killed
        # and restarted at the SAME timestamp decodes as both events.
        s_pre = s
        s = _advance_events(layout, arrays, faults, s)
        s_adv = s
        # Single-pop advancement can leave an env in_pass with an empty
        # queue (completion-only timestamp) — only envs with waiting
        # jobs actually need a decision this round.
        qa = device_queued(s["ready"], s["now"], s["started"], s["finished"],
                           s["failed"]).any(axis=1)
        any_need = jnp.any(s["in_pass"] & ~s["done"] & qa)

        def live(s):
            return decide(s)

        def idle(s):
            dec = ((jnp.zeros(N, jnp.int32), jnp.zeros(N, bool),
                    jnp.zeros(N, jnp.int32)) if trace else ())
            return (s, jnp.full(N, -1, jnp.int32), jnp.zeros(N, bool),
                    jnp.zeros((N, obs_dim if collect else 0), jnp.float32),
                    dec)

        s, a_out, need, obs_out, dec = jax.lax.cond(any_need, live, idle, s)
        ys = (a_out, need, obs_out)
        if trace:
            tr = {"now": s_adv["now"],
                  "finish_d": s_adv["finished"] & ~s_pre["finished"],
                  "fail_d": s_adv["failed"] & ~s_pre["failed"],
                  "requeue_d": s_adv["requeues"] > s_pre["requeues"],
                  "start_d": s["started"] & ~s_adv["started"],
                  "j_star": dec[0], "fit": dec[1], "qlen": dec[2]}
            if D:
                tr["drain_d"] = (s_adv["drain_done"]
                                 & ~s_pre["drain_done"])
                tr["restore_d"] = (s_adv["restore_done"]
                                   & ~s_pre["restore_done"])
            ys = ys + (tr,)
        return s, ys

    st, scan_out = jax.lax.scan(round_body, st, None, length=layout.rounds)
    if trace:
        actions, decided, obs_log, trace_out = scan_out
    else:
        actions, decided, obs_log = scan_out
    out = {"started": st["started"], "start": st["start"], "end": st["end"],
           "finished": st["finished"], "failed": st["failed"],
           "requeues": st["requeues"], "failed_work": st["failed_work"],
           "failed_area": st["failed_area"],
           "first_start_j": st["first_start_j"],
           "now": st["now"], "decisions": st["decisions"],
           "truncated": st["truncated"],
           "first_start": st["first_start"], "done": st["done"],
           "actions": actions, "decided": decided}
    if collect:
        out["obs"] = obs_log
    if trace:
        # Final READY times decode the first queue entry of every job
        # (host: queued exactly at max(submit, parent end + think)).
        out["trace"] = {**trace_out, "ready": st["ready"]}
    return out


# ====================================================================== host
class DeviceSimulator:
    """N jobsets, one shared cluster spec, one jitted rollout program.

    ``policy`` must implement the device stages of the ``Policy``
    protocol (``init_state`` / ``score_window``); use
    ``repro.core.policy_api.supports_device`` to check.  Construction
    packs the traces into fixed-capacity arrays and compiles the rollout
    on first use; ``run()`` matches the ``Simulator``/``VectorSimulator``
    result contract, ``rollout()`` additionally returns the decision
    trace (and, with ``collect=True``, the packed decision rows for
    training ingestion).

    ``faults`` mirrors the host engines: ``None``, one ``FaultSchedule``
    shared by every environment, or one (possibly ``None``) schedule per
    jobset.
    """

    def __init__(self, resources: Sequence[ResourceSpec],
                 jobsets: Sequence[Sequence[Job]], policy,
                 config: SimConfig | None = None, *, faults=None):
        from ..core.policy_api import supports_device
        if not supports_device(policy):
            raise TypeError(
                f"{type(policy).__name__} has no device stages "
                "(init_state/score_window) — run it through Simulator or "
                "VectorSimulator instead")
        if not jobsets or any(len(js) == 0 for js in jobsets):
            raise ValueError("DeviceSimulator needs >= 1 non-empty jobset")
        self.resources = list(resources)
        self.policy = policy
        self.config = config or SimConfig.for_engine("device")
        names = tuple(r.name for r in self.resources)
        caps = tuple(int(r.capacity) for r in self.resources)
        requires_obs = bool(getattr(policy, "requires_obs", True))
        enc = getattr(policy, "enc", None)
        if requires_obs:
            assert enc is not None, \
                f"{type(policy).__name__} requires obs but has no enc"
            if tuple(enc.resource_names) != names:
                raise ValueError(
                    f"policy encodes resources {tuple(enc.resource_names)} "
                    f"but the cluster has {names}")
            if int(enc.window) != int(self.config.window):
                raise ValueError(
                    f"policy window {enc.window} != sim window "
                    f"{self.config.window} — the device engine scores "
                    "exactly the simulation window")
            enc_caps = tuple(int(c) for c in enc.capacities)
            time_scale = float(enc.time_scale)
            state_module = str(getattr(enc, "state_module", "mlp"))
            queue_cap = int(getattr(enc, "queue_cap", 0))
        else:
            enc_caps = caps
            time_scale = 86400.0
            state_module = "mlp"
            queue_cap = 0

        self.jobsets = [sorted((j.copy() for j in js),
                               key=lambda j: (j.submit, j.jid))
                        for js in jobsets]
        N = len(self.jobsets)
        J = max(len(js) for js in self.jobsets)
        caps_map = dict(zip(names, caps))
        if faults is None or isinstance(faults, FaultSchedule):
            flist = [faults] * N
        else:
            flist = list(faults)
            if len(flist) != N:
                raise ValueError(
                    f"got {len(flist)} fault schedules for {N} jobsets")
        self._faults = [resolve_faults(f, js, caps_map)
                        for f, js in zip(flist, self.jobsets)]
        rounds = 3 * J + 2 + self._fault_rounds()
        if self.config.max_rounds is not None:
            rounds = min(rounds, int(self.config.max_rounds))
        self.layout = DeviceLayout(
            names=names, caps=caps, enc_caps=enc_caps,
            window=int(self.config.window), n_envs=N, n_jobs=J,
            rounds=rounds, backfill=bool(self.config.backfill),
            requires_obs=requires_obs, time_scale=time_scale,
            state_module=state_module, queue_cap=queue_cap)
        self.arrays = self._pack(self.jobsets)
        self.faults_arrays = self._pack_faults(self._faults)
        self.stats = DeviceStats()
        self._jitted: Dict[Tuple[bool, bool, bool], object] = {}

    def _fault_rounds(self) -> int:
        """Extra scan rounds for fault activity, max over environments:
        every kill adds one end pop and one restart decision; every drain
        adds its own pop, a restore pop, and a restart cycle per resident
        it can kill (bounded by the unit count)."""
        extra = 0
        for js, f in zip(self.jobsets, self._faults):
            kills = 0
            for job in js:
                k = 0
                for ft in job.fail_times:
                    if ft < job.runtime and k < f.max_requeues + 1:
                        k += 1
                    else:
                        break
                kills += k
            dcost = sum(2 + 2 * min(len(js), d.units) for d in f.drains)
            extra = max(extra, 2 * kills + dcost)
        return extra

    # ------------------------------------------------------------- packing
    def _pack(self, jobsets) -> Dict[str, jnp.ndarray]:
        lay = self.layout
        N, J, R = lay.n_envs, lay.n_jobs, lay.n_resources
        submit = np.full((N, J), np.inf, np.float64)
        runtime = np.zeros((N, J), np.float64)
        walltime = np.zeros((N, J), np.float64)
        demands = np.zeros((N, J, R), np.float32)
        static = np.zeros((N, J, R + 1), np.float32)
        caps_f = [float(max(c, 1)) for c in lay.caps]
        # Dependency edges resolve to packed job indices per environment;
        # dangling or self deps are dropped (JobLifecycle semantics).
        dep_lists = []
        for js in jobsets:
            id2idx = {job.jid: j for j, job in enumerate(js)}
            dep_lists.append([
                [id2idx[d] for d in job.deps
                 if d in id2idx and d != job.jid]
                for job in js])
        P = max((len(ds) for env in dep_lists for ds in env), default=0)
        A = max((len(job.fail_times) for js in jobsets for job in js),
                default=0)
        deps_idx = np.full((N, J, P), -1, np.int32)
        think = np.zeros((N, J), np.float32)
        fail_times = np.full((N, J, A), np.inf, np.float32)
        for i, js in enumerate(jobsets):
            for j, job in enumerate(js):
                submit[i, j] = job.submit
                runtime[i, j] = job.runtime
                walltime[i, j] = job.walltime
                for r, n in enumerate(lay.names):
                    d = job.demands.get(n, 0)
                    demands[i, j, r] = d
                    static[i, j, r] = d / caps_f[r]       # f64 div, f32 store
                static[i, j, R] = job.walltime / lay.time_scale
                ds = dep_lists[i][j]
                deps_idx[i, j, :len(ds)] = ds
                think[i, j] = job.think_time
                fail_times[i, j, :len(job.fail_times)] = job.fail_times
        return {
            "submit": jnp.asarray(submit, jnp.float32),
            "submit_feat": jnp.asarray(
                np.where(np.isfinite(submit), submit, 0.0), jnp.float32),
            "runtime": jnp.asarray(runtime, jnp.float32),
            "walltime": jnp.asarray(walltime, jnp.float32),
            "demands": jnp.asarray(demands),
            "static_feats": jnp.asarray(static),
            "deps_idx": jnp.asarray(deps_idx),
            "think": jnp.asarray(think),
            "fail_times": jnp.asarray(fail_times),
        }

    def _pack_faults(self, resolved: List[FaultSchedule]) -> DeviceFaults:
        lay = self.layout
        N = lay.n_envs
        D = max((len(f.drains) for f in resolved), default=0)
        drain_t = np.full((N, D), np.inf, np.float32)
        restore_t = np.full((N, D), np.inf, np.float32)
        drain_res = np.zeros((N, D), np.int32)
        drain_units = np.zeros((N, D), np.int32)
        mr = np.zeros((N, 1), np.int32)
        res_idx = {n: r for r, n in enumerate(lay.names)}
        for i, f in enumerate(resolved):
            mr[i, 0] = f.max_requeues
            for k, d in enumerate(f.drains):
                drain_t[i, k] = d.time
                restore_t[i, k] = d.time + d.duration
                drain_res[i, k] = res_idx[d.resource]
                drain_units[i, k] = d.units
        seg_cols = [np.full(cap, r, np.int32)
                    for r, (_, cap) in enumerate(lay.segments)]
        loc_cols = [np.arange(cap, dtype=np.int32)
                    for _, cap in lay.segments]
        unit_seg = (np.concatenate(seg_cols) if seg_cols
                    else np.zeros(0, np.int32))
        unit_local = (np.concatenate(loc_cols) if loc_cols
                      else np.zeros(0, np.int32))
        return DeviceFaults(
            drain_t=jnp.asarray(drain_t), restore_t=jnp.asarray(restore_t),
            drain_res=jnp.asarray(drain_res),
            drain_units=jnp.asarray(drain_units),
            unit_seg=jnp.asarray(unit_seg),
            unit_local=jnp.asarray(unit_local),
            max_requeues=jnp.asarray(mr))

    # ------------------------------------------------------------- rollout
    def _fn(self, explore: bool, collect: bool, trace: bool = False):
        key = (explore, collect, trace)
        if key not in self._jitted:
            self._jitted[key] = jax.jit(functools.partial(
                _device_rollout, self.layout, self.policy.score_window,
                explore, collect, trace))
        return self._jitted[key]

    def rollout(self, eps: Optional[float] = None, seed: int = 0,
                collect: bool = False, trace: bool = False) -> DeviceRollout:
        """Run every environment to completion in one device program.

        ``eps``: when set, actions are epsilon-greedy with in-graph
        (jax.random) draws — the device counterpart of the agent's
        training exploration (note: a *different* RNG stream than the
        host engines' numpy draws).  ``collect=True`` additionally
        returns the packed decision rows for trainer ingestion.
        ``trace=True`` (a separate jit specialization) scans out the
        per-round lifecycle deltas that ``emit_trace`` decodes into the
        ``mrsch.trace/v1`` event stream.
        """
        explore = eps is not None
        with annotate("mrsch.device.rollout"):
            raw = self._fn(explore, collect, trace)(
                self.arrays, self.faults_arrays, self.policy.init_state(),
                jnp.float32(0.0 if eps is None else eps),
                jax.random.PRNGKey(seed))
        tr = raw.pop("trace", None)
        out = {k: np.asarray(v) for k, v in raw.items()}
        if tr is not None:
            tr = {k: np.asarray(v) for k, v in tr.items()}
        if not out["done"].all():
            raise RuntimeError(
                f"device rollout exhausted its round budget "
                f"({self.layout.rounds}); raise SimConfig.max_rounds")
        decided = out["decided"]
        self.stats = DeviceStats(
            rounds=int(decided.any(axis=1).sum()),
            decisions=int(decided.sum()),
            policy_calls=int(decided.any(axis=1).sum()),
            max_batch=int(decided.sum(axis=1).max(initial=0)))
        return DeviceRollout(
            actions=out["actions"], decided=decided,
            stats=self.stats, obs=out.get("obs"), trace=tr,
            _build=lambda: self._results(out))

    def emit_trace(self, ro: DeviceRollout, tracer: Tracer,
                   env_ids: Optional[Sequence[int]] = None) -> None:
        """Decode a ``rollout(trace=True)`` into typed tracer events.

        Emits the exact event stream the sequential engine produces for
        the same jobsets/policy (canonical order restored by
        ``repro.obs.trace.canonical_events``; byte parity pinned in
        ``tests/test_obs.py`` on integer-time traces, where the f32
        device clock is exact).
        """
        tr = ro.trace
        if tr is None:
            raise ValueError("rollout was not traced; pass trace=True")
        lay = self.layout
        eids = (list(range(lay.n_envs)) if env_ids is None
                else [int(e) for e in env_ids])
        if len(eids) != lay.n_envs:
            raise ValueError(
                f"got {len(eids)} env ids for {lay.n_envs} environments")
        # First queue entry of every job: its final READY time (f32).
        for i, js in enumerate(self.jobsets):
            env, ready_i = eids[i], tr["ready"][i]
            for j, job in enumerate(js):
                if np.isfinite(ready_i[j]):
                    tracer.job_queued(env, float(ready_i[j]), job.jid)
        nreq = [[0] * len(js) for js in self.jobsets]
        T = ro.decided.shape[0]
        has_faults = "drain_d" in tr
        for t in range(T):
            for i, js in enumerate(self.jobsets):
                env = eids[i]
                now = float(tr["now"][t, i])
                fin_d, fail_d = tr["finish_d"][t, i], tr["fail_d"][t, i]
                req_d = tr["requeue_d"][t, i]
                for j in np.flatnonzero(fin_d | fail_d | req_d):
                    jid = js[j].jid
                    if fin_d[j]:
                        tracer.job_finish(env, now, jid)
                    elif fail_d[j]:
                        # The kill that crossed the requeue bound: the
                        # host emits job.fail only (no requeue event).
                        tracer.job_fail(env, now, jid)
                    else:
                        nreq[i][j] += 1
                        tracer.job_requeue(env, now, jid, nreq[i][j])
                        tracer.job_queued(env, now, jid)
                if has_faults:
                    for k in np.flatnonzero(tr["drain_d"][t, i]):
                        d = self._faults[i].drains[k]
                        tracer.drain(env, now, d.resource, d.units)
                    for k in np.flatnonzero(tr["restore_d"][t, i]):
                        d = self._faults[i].drains[k]
                        tracer.restore(env, now, d.resource, d.units)
                if not ro.decided[t, i]:
                    continue
                a = int(ro.actions[t, i])
                j_star = int(tr["j_star"][t, i])
                fit = bool(tr["fit"][t, i])
                jid = js[j_star].jid
                tracer.decision(env, now, a, jid, int(tr["qlen"][t, i]),
                                1 if fit else 0)
                if fit:
                    tracer.job_start(env, now, jid, 0)
                else:
                    tracer.reserve(env, now, jid)
                    if lay.backfill:
                        bf = np.flatnonzero(tr["start_d"][t, i])
                        for j in bf:   # ascending index == queue order
                            tracer.job_start(env, now, js[j].jid, 1)
                        tracer.backfill(env, now, len(bf))

    def run(self) -> List[SimResult]:
        """Greedy rollout; result contract matches the host engines."""
        return self.rollout().results

    # ------------------------------------------------------------- results
    def _results(self, out) -> List[SimResult]:
        results = []
        for i, js in enumerate(self.jobsets):
            jobs = []
            for j, job in enumerate(js):
                job = job.copy()
                job.requeues = int(out["requeues"][i, j])
                job.failed_work = float(out["failed_work"][i, j])
                fs = float(out["first_start_j"][i, j])
                if fs >= 0.0:
                    job.first_start = fs
                if out["finished"][i, j]:
                    job.state = FINISHED
                elif out["failed"][i, j]:
                    job.state = FAILED
                if out["started"][i, j]:
                    job.start = float(out["start"][i, j])
                    e = float(out["end"][i, j])
                    job.end = e if np.isfinite(e) else -1.0
                jobs.append(job)
            started = [jb for jb in jobs if jb.started]
            cluster = Cluster(self.resources)
            acc = MetricsAccumulator(cluster)
            acc.last_time = float(out["now"][i])
            acc.start_time = (float(out["first_start"][i]) if started
                              else None)
            # Busy area = completed attempts' occupancy + the work lost to
            # killed attempts (the host integral counted the latter while
            # the doomed attempts were running).  Drained units are
            # phantom-owned, so they contribute to neither term.
            for r, n in enumerate(self.layout.names):
                done_area = sum(
                    jb.demands.get(n, 0) * (jb.end - jb.start)
                    for jb in jobs if jb.state == FINISHED)
                acc.busy_area[n] = done_area + float(out["failed_area"][i, r])
            metrics = acc.summarize(started, all_jobs=jobs)
            metrics.truncated_jobs = int(out["truncated"][i])
            results.append(SimResult(
                metrics=metrics,
                jobs=jobs,
                makespan=float(out["now"][i]),
                decisions=int(out["decisions"][i]),
                n_unstarted=len(jobs) - len(started),
                truncated_jobs=int(out["truncated"][i]),
                requeues=metrics.requeues,
                n_failed=metrics.n_failed))
        return results


def run_traces_device(resources: Sequence[ResourceSpec],
                      jobsets: Sequence[Sequence[Job]], policy,
                      config: SimConfig | None = None,
                      faults=None) -> List[SimResult]:
    """Convenience device counterpart of ``run_trace``/``run_traces``."""
    cfg = config or SimConfig.for_engine("device")
    return DeviceSimulator(resources, jobsets, policy, cfg,
                           faults=faults).run()
