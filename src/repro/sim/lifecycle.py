"""Shared job-lifecycle core for all three scheduling engines.

One explicit state machine::

    HELD -> ELIGIBLE -> QUEUED -> RUNNING -> FINISHED
                           ^          |
                           +-- requeue+---------> FAILED

- HELD:     submitted (or not yet submitted) with unfinished parents.
- ELIGIBLE: all parents finished; waiting out ``think_time`` before the
  job may join the queue.
- QUEUED:   visible to the scheduler (window/backfill candidates).
- RUNNING:  holds cluster units until the attempt ends.
- FINISHED: terminal success; releases children.
- FAILED:   terminal failure — a killed attempt past the requeue bound,
  or (at result time) a cascade from a FAILED ancestor.

The *transition logic* lives here and only here:

- the sequential :class:`~repro.sim.simulator.Simulator` calls the host
  methods on :class:`JobLifecycle` per event (and the lockstep
  ``VectorSimulator`` therefore inherits them per environment);
- the device engine folds the ``device_*`` pure functions below into its
  jitted ``lax.scan`` event pump over masked fixed-capacity arrays.

Queue ordering is part of the contract: the waiting queue is kept sorted
by ``(original submit, jid)`` (:func:`queue_key`).  For dependency-free
traces this equals arrival order, so historic schedules are unchanged;
for requeued or dependency-released jobs it pins one deterministic order
that the packed device engine reproduces by construction (jobs are
packed sorted by the same key).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import Cluster
from .job import Job

# State constants.  HELD must stay 0: freshly built Jobs default to it.
HELD, ELIGIBLE, QUEUED, RUNNING, FINISHED, FAILED = range(6)
STATE_NAMES = ("HELD", "ELIGIBLE", "QUEUED", "RUNNING", "FINISHED", "FAILED")

#: Attempts a job may lose before it is FAILED permanently: a job is
#: requeued after kill k while ``k <= DEFAULT_MAX_REQUEUES``.
DEFAULT_MAX_REQUEUES = 3

#: Owner id of drained (phantom-reserved) units in the device engine's
#: packed owner array; real jobs are >= 0 and free units are -1.
PHANTOM_OWNER = -2

INF = float("inf")


# --------------------------------------------------------------------------
# Fault schedule
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class DrainEvent:
    """Drain the FIRST ``units`` units of ``resource`` at ``time`` for
    ``duration`` seconds (``inf`` = permanent failure).  Resident jobs are
    killed (whole-job: rigid jobs cannot shrink) and requeued.

    ``unit_frac`` may be given instead of ``units`` so one schedule works
    across cluster sizes; it resolves against capacity at simulation
    setup.  With ``FaultSchedule.relative``, ``time``/``duration`` are
    fractions of the trace's submit span instead of seconds.
    """

    time: float
    resource: str
    units: int = 0
    duration: float = INF
    unit_frac: float = 0.0


@dataclass(frozen=True)
class FaultSchedule:
    """Deterministic per-scenario fault plan (drains + requeue bound)."""

    drains: Tuple[DrainEvent, ...] = ()
    max_requeues: int = DEFAULT_MAX_REQUEUES
    relative: bool = False

    def resolve(self, jobs: Sequence[Job],
                capacities: Dict[str, int]) -> "FaultSchedule":
        """Return an absolute schedule: fractions -> units/seconds, drains
        sorted by time, per-resource overlap rejected (a unit can belong
        to at most one outage at a time)."""
        if self.max_requeues < 0:
            raise ValueError("max_requeues must be >= 0")
        submits = [j.submit for j in jobs]
        t0 = min(submits) if submits else 0.0
        span = max((max(submits) - t0), 1.0) if submits else 1.0
        out = []
        for d in self.drains:
            if d.resource not in capacities:
                raise ValueError(f"drain on unknown resource {d.resource!r}")
            units = d.units or int(round(d.unit_frac * capacities[d.resource]))
            units = max(0, min(units, capacities[d.resource]))
            t, dur = d.time, d.duration
            if self.relative:
                t = t0 + t * span
                dur = dur * span if np.isfinite(dur) else INF
            if dur <= 0:
                raise ValueError("drain duration must be > 0")
            if units > 0:
                out.append(DrainEvent(t, d.resource, units=units, duration=dur))
        out.sort(key=lambda d: (d.time, d.resource))
        last_end: Dict[str, float] = {}
        for d in out:
            if d.time < last_end.get(d.resource, -INF):
                raise ValueError(
                    f"overlapping drains on resource {d.resource!r}")
            last_end[d.resource] = d.time + d.duration
        return FaultSchedule(tuple(out), self.max_requeues, relative=False)


def resolve_faults(faults: Optional[FaultSchedule], jobs: Sequence[Job],
                   capacities: Dict[str, int]) -> FaultSchedule:
    return (faults or FaultSchedule()).resolve(jobs, capacities)


# --------------------------------------------------------------------------
# Queue ordering
# --------------------------------------------------------------------------
def queue_key(job: Job) -> Tuple[float, int]:
    """Deterministic waiting-queue order: original submit time, then jid."""
    return (job.submit, job.jid)


def insert_queued(queue: List[Job], job: Job) -> None:
    """Insert ``job`` into ``queue`` keeping it sorted by :func:`queue_key`.

    Requeued jobs re-enter at their ORIGINAL submit position, so they do
    not lose queue priority to jobs that arrived after them.
    """
    k = queue_key(job)
    lo, hi = 0, len(queue)
    while lo < hi:
        mid = (lo + hi) // 2
        if queue_key(queue[mid]) <= k:
            lo = mid + 1
        else:
            hi = mid
    queue.insert(lo, job)


# --------------------------------------------------------------------------
# Host transition core (sequential + vector engines)
# --------------------------------------------------------------------------
class JobLifecycle:
    """Per-event host transitions over one cluster + one job set.

    The :class:`~repro.sim.simulator.Simulator` owns the event heap and
    the waiting queue; every state change flows through this object so
    the three engines cannot drift apart.
    """

    def __init__(self, jobs: Sequence[Job], cluster: Cluster,
                 faults: Optional[FaultSchedule] = None):
        self.cluster = cluster
        self.jobs = list(jobs)
        self.by_id: Dict[int, Job] = {}
        for j in self.jobs:
            if j.jid in self.by_id:
                raise ValueError(f"duplicate jid {j.jid}")
            j.state = HELD
            self.by_id[j.jid] = j
        # Dangling deps (parent not in this jobset — e.g. sampled
        # sub-traces) are treated as already satisfied.
        self.children: Dict[int, List[Job]] = {}
        for j in self.jobs:
            for d in j.deps:
                if d in self.by_id and d != j.jid:
                    self.children.setdefault(d, []).append(j)
        self.faults = resolve_faults(faults, self.jobs, cluster.capacities)
        self.max_requeues = self.faults.max_requeues
        self.submitted: set = set()
        # "node" anchors the failed-work metric; first resource otherwise.
        self.primary = "node" if "node" in cluster.names else cluster.names[0]

    # ---------------------------------------------------------- eligibility
    def ready_time(self, job: Job) -> float:
        """Time the job may join the queue: ``max(submit, max_parent(end)
        + think_time)``; ``inf`` while any present parent is unfinished."""
        t = job.submit
        for d in job.deps:
            p = self.by_id.get(d)
            if p is None or p is job:
                continue
            if p.state != FINISHED:
                return INF
            t = max(t, p.end + job.think_time)
        return t

    def on_submit(self, job: Job, now: float) -> Tuple[str, float]:
        """Submit event.  Returns ``(outcome, ready)`` where outcome is
        ``"queued"`` (insert now), ``"eligible"`` (schedule a release
        event at ``ready``) or ``"held"`` (parents pending)."""
        self.submitted.add(job.jid)
        r = self.ready_time(job)
        if r <= now:
            job.state = QUEUED
            return "queued", now
        if np.isfinite(r):
            job.state = ELIGIBLE
            return "eligible", r
        return "held", INF

    def on_release(self, job: Job) -> bool:
        """ELIGIBLE -> QUEUED (think-time expiry).  False if stale."""
        if job.state != ELIGIBLE:
            return False
        job.state = QUEUED
        return True

    # ---------------------------------------------------------- run attempts
    def attempt(self, job: Job) -> Tuple[float, bool]:
        """Duration and failure flag of the job's NEXT attempt."""
        k = job.requeues
        if k < len(job.fail_times) and job.fail_times[k] < job.runtime:
            return float(job.fail_times[k]), True
        return job.runtime, False

    def start(self, job: Job, now: float) -> float:
        """QUEUED -> RUNNING.  Allocates units and returns the attempt's
        end time (the failure point for a doomed attempt)."""
        assert job.state == QUEUED, f"start from {STATE_NAMES[job.state]}"
        self.cluster.allocate(job, now)
        dur, _ = self.attempt(job)
        job.end = now + dur
        job.state = RUNNING
        return job.end

    def is_stale_end(self, job: Job, attempt_id: int) -> bool:
        """An end event is stale when its attempt was killed by a drain
        (the job was requeued or failed since the event was scheduled)."""
        return job.state != RUNNING or job.requeues != attempt_id

    def on_end(self, job: Job, now: float) -> Tuple[str, List[Tuple[Job, float]]]:
        """RUNNING attempt reached its scheduled end.

        Returns ``(outcome, released)``: outcome is ``"finished"``,
        ``"requeued"`` or ``"failed"``; ``released`` lists newly eligible
        children as ``(child, ready_time)`` pairs (ready <= now means the
        child joins the queue in this same coalesced timestamp).
        """
        _, fails = self.attempt(job)
        if fails:
            return self.kill(job, now), []
        self.cluster.release_job(job.jid)
        job.state = FINISHED
        return "finished", self._release_children(job, now)

    def _release_children(self, job: Job, now: float) -> List[Tuple[Job, float]]:
        out = []
        for c in self.children.get(job.jid, ()):  # deterministic jobset order
            if c.state != HELD or c.jid not in self.submitted:
                continue
            r = self.ready_time(c)
            if not np.isfinite(r):
                continue
            c.state = QUEUED if r <= now else ELIGIBLE
            out.append((c, max(r, now)))
        return out

    # ---------------------------------------------------------- faults
    def kill(self, job: Job, now: float) -> str:
        """Kill the RUNNING attempt (failure point or drain).  The lost
        work is charged to ``failed_work``; the job re-enters the queue at
        its original position unless the requeue bound is exhausted."""
        assert job.state == RUNNING
        job.failed_work += job.demands.get(self.primary, 0) * (now - job.start)
        self.cluster.release_job(job.jid)
        job.requeues += 1
        job.start = -1.0
        job.end = -1.0
        if job.requeues > self.max_requeues:
            job.state = FAILED
            return "failed"
        job.state = QUEUED
        return "requeued"

    def on_drain(self, d: DrainEvent, now: float) -> List[Tuple[Job, str]]:
        """Apply a drain: kill resident jobs (ascending jid), then mark
        the unit range as phantom-reserved until the restore time."""
        out = []
        for jid in self.cluster.residents(d.resource, d.units):
            job = self.cluster.running[jid].job
            out.append((job, self.kill(job, now)))
        restore_t = d.time + d.duration
        self.cluster.apply_drain(d.resource, d.units, restore_t)
        return out

    def on_restore(self, d: DrainEvent) -> None:
        self.cluster.apply_restore(d.resource, d.units)


# --------------------------------------------------------------------------
# Result-time helpers (shared by every engine's summarize path)
# --------------------------------------------------------------------------
def cascade_failures(jobs: Sequence[Job]) -> int:
    """Mark never-started descendants of FAILED ancestors as FAILED.

    Run at result time: during simulation a HELD child of a failed parent
    simply never becomes eligible, which is indistinguishable from
    starvation; the cascade makes the verdict explicit in the metrics.
    Returns the number of jobs newly marked.
    """
    by_id = {j.jid: j for j in jobs}
    n, changed = 0, True
    while changed:
        changed = False
        for j in jobs:
            if j.state in (FINISHED, FAILED) or j.started:
                continue
            if any(by_id[d].state == FAILED
                   for d in j.deps if d in by_id and d != j.jid):
                j.state = FAILED
                n += 1
                changed = True
    return n


def workflow_components(jobs: Sequence[Job]) -> List[List[Job]]:
    """Connected components of the dependency graph (size >= 2 only)."""
    idx = {j.jid: i for i, j in enumerate(jobs)}
    parent = list(range(len(jobs)))

    def find(a: int) -> int:
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for j in jobs:
        for d in j.deps:
            if d in idx and d != j.jid:
                ra, rb = find(idx[j.jid]), find(idx[d])
                if ra != rb:
                    parent[ra] = rb
    comps: Dict[int, List[Job]] = {}
    for i, j in enumerate(jobs):
        comps.setdefault(find(i), []).append(j)
    return [c for c in comps.values() if len(c) >= 2]


def pipeline_makespan(jobs: Sequence[Job]) -> float:
    """Mean makespan (last end - first submit) over workflow components
    whose every member FINISHED; 0.0 when no component completed."""
    spans = []
    for comp in workflow_components(jobs):
        if all(j.state == FINISHED for j in comp):
            spans.append(max(j.end for j in comp) - min(j.submit for j in comp))
    return float(np.mean(spans)) if spans else 0.0


def work_summary(jobs: Sequence[Job], primary: str) -> Tuple[float, float]:
    """(completed, failed) node-seconds on the ``primary`` resource."""
    completed = sum(j.demands.get(primary, 0) * j.runtime
                    for j in jobs if j.state == FINISHED)
    failed = sum(j.failed_work for j in jobs)
    return float(completed), float(failed)


# --------------------------------------------------------------------------
# Device-side pure transitions (folded into the lax.scan event pump)
# --------------------------------------------------------------------------
# Shapes: N envs, J jobs, P max parents, A max attempts, D drains, U total
# resource units (concatenated segments).  All functions are pure and
# jit-safe; the zero-size fast paths (P == 0, A == 0, D == 0) are Python
# staging-time branches, so dependency-free traces trace exactly the same
# graph they did before the lifecycle core existed.

def device_ready(submit, deps_idx, think, end_t, finished):
    """Earliest queue-entry time per job: ``max(submit, max_parent(end) +
    think)`` while all present parents are finished, else ``+inf``."""
    import jax.numpy as jnp

    n, j, p = deps_idx.shape
    if p == 0:
        return submit
    flat = jnp.clip(deps_idx, 0, j - 1).reshape(n, j * p)
    has = deps_idx >= 0
    pfin = jnp.take_along_axis(finished, flat, axis=1).reshape(n, j, p) & has
    pend = jnp.take_along_axis(end_t, flat, axis=1).reshape(n, j, p)
    all_done = jnp.where(has, pfin, True).all(axis=2)
    pmax = jnp.where(pfin, pend, -jnp.inf).max(axis=2)
    ready = jnp.maximum(submit, pmax + think)
    return jnp.where(all_done, ready, jnp.inf)


def device_queued(ready, now, started, finished, failed):
    """QUEUED mask: eligible by ``now`` and not in any other live state."""
    return (ready <= now[:, None]) & ~started & ~finished & ~failed


def device_attempt(fail_times, requeues, runtime):
    """(duration, will_fail) of each job's NEXT attempt."""
    import jax.numpy as jnp

    if fail_times.shape[2] == 0:
        return runtime, jnp.zeros(runtime.shape, bool)
    a = fail_times.shape[2]
    k = jnp.clip(requeues, 0, a - 1)[..., None]
    ft = jnp.take_along_axis(fail_times, k, axis=2)[..., 0]
    ft = jnp.where(requeues < a, ft, jnp.inf)
    will_fail = ft < runtime
    return jnp.where(will_fail, ft, runtime), will_fail


def device_free_units(mask_j, release, owner):
    """Free every unit owned by a job in ``mask_j`` (N, J)."""
    import jax.numpy as jnp

    hit = jnp.take_along_axis(mask_j, jnp.maximum(owner, 0), axis=1) \
        & (owner >= 0)
    return jnp.where(hit, 0.0, release), jnp.where(hit, -1, owner)


def device_kill(killed, now, demands, node_idx, max_requeues, st):
    """Kill RUNNING attempts in ``killed`` (N, J): free their units,
    charge the lost work, and either requeue (original queue position —
    ordering is by packed job index) or mark FAILED past the bound.
    Mutates-and-returns the relevant entries of the state dict ``st``."""
    import jax.numpy as jnp

    # where() not arithmetic masking: ``now`` is +inf for envs with no
    # event this round, and inf * 0.0 would poison the area with NaN.
    run_t = jnp.where(killed, jnp.maximum(now[:, None] - st["start"], 0.0),
                      0.0)
    work = demands * run_t[..., None]                      # (N, J, R)
    st["failed_area"] = st["failed_area"] + work.sum(axis=1)
    st["failed_work"] = st["failed_work"] + work[..., node_idx]
    st["release"], st["owner"] = device_free_units(
        killed, st["release"], st["owner"])
    st["requeues"] = st["requeues"] + killed
    st["failed"] = st["failed"] | (killed & (st["requeues"] > max_requeues))
    st["started"] = st["started"] & ~killed
    st["start"] = jnp.where(killed, -1.0, st["start"])
    st["end"] = jnp.where(killed, jnp.inf, st["end"])
    st["cur_fail"] = st["cur_fail"] & ~killed
    return st


def device_apply_ends(t, act, demands, node_idx, max_requeues, st,
                      has_kills=True):
    """Apply every attempt-end scheduled at ``t``: clean finishes release
    units and go FINISHED; failure points are killed/requeued.
    ``has_kills=False`` (a staging-time constant) skips the kill graph
    entirely for traces with no failure points and no drains."""
    running = st["started"] & ~st["finished"]
    due = running & (st["end"] == t[:, None]) & act[:, None]
    fin = due & ~st["cur_fail"] if has_kills else due
    st["finished"] = st["finished"] | fin
    st["release"], st["owner"] = device_free_units(
        fin, st["release"], st["owner"])
    if has_kills:
        st = device_kill(due & st["cur_fail"], t, demands, node_idx,
                         max_requeues, st)
    return st


def device_apply_drains(t, act, faults, demands, node_idx, st):
    """Fire drains scheduled at ``t``: kill residents of the unit range,
    then phantom-reserve it (owner = PHANTOM_OWNER) until restore."""
    import jax.numpy as jnp

    n, u = st["release"].shape
    jmax = st["started"].shape[1]
    env = jnp.arange(n)[:, None]
    for d in range(faults.drain_t.shape[1]):
        fire = act & (faults.drain_t[:, d] == t) & ~st["drain_done"][:, d]
        in_range = (faults.unit_seg[None, :] == faults.drain_res[:, d:d + 1]) \
            & (faults.unit_local[None, :] < faults.drain_units[:, d:d + 1])
        kill_u = fire[:, None] & in_range & (st["owner"] >= 0)
        killed = jnp.zeros((n, jmax), bool).at[
            env, jnp.maximum(st["owner"], 0)].max(kill_u)
        st = device_kill(killed, t, demands, node_idx,
                         faults.max_requeues, st)
        phantom = fire[:, None] & in_range
        st["release"] = jnp.where(
            phantom, faults.restore_t[:, d:d + 1], st["release"])
        st["owner"] = jnp.where(phantom, PHANTOM_OWNER, st["owner"])
        st["drain_done"] = st["drain_done"].at[:, d].max(fire)
    return st


def device_apply_restores(t, act, faults, st):
    """Return phantom units of elapsed drains to the free pool."""
    import jax.numpy as jnp

    for d in range(faults.drain_t.shape[1]):
        fire = act & (faults.restore_t[:, d] == t) \
            & st["drain_done"][:, d] & ~st["restore_done"][:, d]
        in_range = (faults.unit_seg[None, :] == faults.drain_res[:, d:d + 1]) \
            & (faults.unit_local[None, :] < faults.drain_units[:, d:d + 1])
        clear = fire[:, None] & in_range & (st["owner"] == PHANTOM_OWNER)
        st["release"] = jnp.where(clear, 0.0, st["release"])
        st["owner"] = jnp.where(clear, -1, st["owner"])
        st["restore_done"] = st["restore_done"].at[:, d].max(fire)
    return st


def device_next_event(now, ready, end_t, started, finished, failed, faults,
                      st):
    """Next event time per env: min over pending queue-entries, running
    ends, un-fired drains and un-fired restores (inf when drained)."""
    import jax.numpy as jnp

    pending = ~started & ~finished & ~failed & (ready > now[:, None])
    nxt = jnp.where(pending, ready, jnp.inf).min(axis=1)
    running = started & ~finished
    nxt = jnp.minimum(nxt, jnp.where(running, end_t, jnp.inf).min(axis=1))
    if faults is not None and faults.drain_t.shape[1]:
        nxt = jnp.minimum(nxt, jnp.where(
            ~st["drain_done"], faults.drain_t, jnp.inf).min(axis=1))
        nxt = jnp.minimum(nxt, jnp.where(
            st["drain_done"] & ~st["restore_done"], faults.restore_t,
            jnp.inf).min(axis=1))
    return nxt
