"""Multi-resource cluster state.

Tracks per-*unit* occupancy for every schedulable resource so the MRSch
vector state encoding (availability bit + estimated time-to-free per unit,
paper §III-A) can be produced exactly.  Unit granularity is configured per
resource (e.g. 1 node, 1 TB of burst buffer, 1 kW of power headroom).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .job import Job


@dataclass(frozen=True)
class ResourceSpec:
    name: str
    capacity: int               # number of schedulable units
    unit: str = ""              # human label, e.g. "node", "TB", "kW"


@dataclass
class RunningJob:
    job: Job
    units: Dict[str, np.ndarray]          # resource -> allocated unit indices
    est_end: float                        # start + walltime (user estimate)


class Cluster:
    """Allocation state over R resources, each an array of units.

    ``release[r][i]`` is the *estimated* release time of unit ``i`` of
    resource ``r`` (from the running job's user walltime estimate), or 0.0
    when the unit is free — exactly the quantity the paper's state encoding
    consumes.
    """

    def __init__(self, resources: List[ResourceSpec]):
        self.resources = list(resources)
        self.names = [r.name for r in self.resources]
        self.capacities: Dict[str, int] = {r.name: r.capacity for r in self.resources}
        self.release: Dict[str, np.ndarray] = {
            r.name: np.zeros(r.capacity, dtype=np.float64) for r in self.resources
        }
        self.free: Dict[str, int] = dict(self.capacities)
        self.running: Dict[int, RunningJob] = {}

    # ------------------------------------------------------------ queries
    def fits(self, job: Job) -> bool:
        return all(job.demands.get(n, 0) <= self.free[n] for n in self.names)

    def free_vector(self) -> Dict[str, int]:
        return dict(self.free)

    def utilization(self) -> np.ndarray:
        """Instantaneous busy fraction per resource (paper's measurement)."""
        return np.array(
            [1.0 - self.free[n] / max(self.capacities[n], 1) for n in self.names],
            dtype=np.float64,
        )

    def earliest_fit_time(self, job: Job, now: float) -> float:
        """Earliest time the job fits, assuming running jobs release at their
        estimated end times.  Used to place the head-of-queue reservation."""
        t = now
        for n in self.names:
            need = job.demands.get(n, 0)
            if need <= self.free[n]:
                continue
            rel = self.release[n]
            busy = np.sort(rel[rel > 0.0])
            extra = need - self.free[n]
            if extra > len(busy):          # can never fit (over capacity)
                return float("inf")
            t = max(t, busy[extra - 1])
        return t

    # ------------------------------------------------------------ mutation
    def allocate(self, job: Job, now: float) -> None:
        assert self.fits(job), f"job {job.jid} does not fit"
        units: Dict[str, np.ndarray] = {}
        est_end = now + job.walltime
        for n in self.names:
            need = job.demands.get(n, 0)
            if need == 0:
                units[n] = np.empty(0, dtype=np.int64)
                continue
            idx = np.flatnonzero(self.release[n] == 0.0)[:need]
            self.release[n][idx] = est_end
            self.free[n] -= need
            units[n] = idx
        job.start = now
        job.end = now + job.runtime
        self.running[job.jid] = RunningJob(job=job, units=units, est_end=est_end)

    def release_job(self, jid: int) -> Job:
        rj = self.running.pop(jid)
        for n, idx in rj.units.items():
            if idx.size:
                self.release[n][idx] = 0.0
                self.free[n] += int(idx.size)
        return rj.job

    # ------------------------------------------------------------ encoding
    def unit_encoding(self, now: float) -> Dict[str, np.ndarray]:
        """Per-unit (availability, time-to-free) pairs, paper §III-A."""
        out = {}
        for n in self.names:
            rel = self.release[n]
            avail = (rel == 0.0).astype(np.float64)
            ttf = np.where(rel > 0.0, np.maximum(rel - now, 0.0), 0.0)
            out[n] = np.stack([avail, ttf], axis=1)
        return out

    def running_jobs(self) -> List[RunningJob]:
        return list(self.running.values())
