"""Multi-resource cluster state.

Tracks per-*unit* occupancy for every schedulable resource so the MRSch
vector state encoding (availability bit + estimated time-to-free per unit,
paper §III-A) can be produced exactly.  Unit granularity is configured per
resource (e.g. 1 node, 1 TB of burst buffer, 1 kW of power headroom).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .job import Job

# Time-to-free values are clamped to this horizon (30 days) in the state
# encoding: a permanently drained unit carries an infinite release time,
# which would otherwise leak inf into the NN features.  Ordinary jobs
# (walltime <= 1 day in every trace family) never reach the clamp.
TTF_HORIZON = 30.0 * 86400.0


@dataclass(frozen=True)
class ResourceSpec:
    name: str
    capacity: int               # number of schedulable units
    unit: str = ""              # human label, e.g. "node", "TB", "kW"


@dataclass
class RunningJob:
    job: Job
    units: Dict[str, np.ndarray]          # resource -> allocated unit indices
    est_end: float                        # start + walltime (user estimate)


class Cluster:
    """Allocation state over R resources, each an array of units.

    ``release[r][i]`` is the *estimated* release time of unit ``i`` of
    resource ``r`` (from the running job's user walltime estimate), or 0.0
    when the unit is free — exactly the quantity the paper's state encoding
    consumes.

    Drained units (fault injection) are modeled as *phantom reservations*:
    the unit's release time is set to the restore time (``inf`` for a
    permanent failure) without any owning job, so every fit / reservation /
    backfill / encoding path treats the outage like one more running job.
    ``drained[r]`` marks which units are phantom so restores never free a
    real job's units and utilization can exclude the lost capacity.
    """

    def __init__(self, resources: List[ResourceSpec]):
        self.resources = list(resources)
        self.names = [r.name for r in self.resources]
        self.capacities: Dict[str, int] = {r.name: r.capacity for r in self.resources}
        self.release: Dict[str, np.ndarray] = {
            r.name: np.zeros(r.capacity, dtype=np.float64) for r in self.resources
        }
        self.free: Dict[str, int] = dict(self.capacities)
        self.running: Dict[int, RunningJob] = {}
        self.drained: Dict[str, np.ndarray] = {
            r.name: np.zeros(r.capacity, dtype=bool) for r in self.resources
        }

    # ------------------------------------------------------------ queries
    def fits(self, job: Job) -> bool:
        return all(job.demands.get(n, 0) <= self.free[n] for n in self.names)

    def free_vector(self) -> Dict[str, int]:
        return dict(self.free)

    def drained_count(self, name: str) -> int:
        return int(self.drained[name].sum())

    def busy_units(self, name: str) -> int:
        """Units running real work: capacity minus free minus drained."""
        return self.capacities[name] - self.free[name] - self.drained_count(name)

    def utilization(self) -> np.ndarray:
        """Instantaneous busy fraction per resource (paper's measurement).

        Drained units count as neither busy nor free — lost capacity is
        reported through the fault metrics, not as utilization.
        """
        return np.array(
            [self.busy_units(n) / max(self.capacities[n], 1) for n in self.names],
            dtype=np.float64,
        )

    def earliest_fit_time(self, job: Job, now: float) -> float:
        """Earliest time the job fits, assuming running jobs release at their
        estimated end times.  Used to place the head-of-queue reservation.
        Phantom (drained) reservations participate like any other: a
        permanently drained unit releases at ``inf``."""
        t = now
        for n in self.names:
            need = job.demands.get(n, 0)
            if need <= self.free[n]:
                continue
            rel = self.release[n]
            busy = np.sort(rel[rel > 0.0])
            extra = need - self.free[n]
            if extra > len(busy):          # can never fit (over capacity)
                return float("inf")
            t = max(t, busy[extra - 1])
        return t

    # ------------------------------------------------------------ mutation
    def allocate(self, job: Job, now: float) -> None:
        assert self.fits(job), f"job {job.jid} does not fit"
        units: Dict[str, np.ndarray] = {}
        est_end = now + job.walltime
        for n in self.names:
            need = job.demands.get(n, 0)
            if need == 0:
                units[n] = np.empty(0, dtype=np.int64)
                continue
            idx = np.flatnonzero(self.release[n] == 0.0)[:need]
            self.release[n][idx] = est_end
            self.free[n] -= need
            units[n] = idx
        job.start = now
        job.end = now + job.runtime
        if job.first_start < 0.0:
            job.first_start = now
        self.running[job.jid] = RunningJob(job=job, units=units, est_end=est_end)

    def release_job(self, jid: int) -> Job:
        rj = self.running.pop(jid)
        for n, idx in rj.units.items():
            if idx.size:
                self.release[n][idx] = 0.0
                self.free[n] += int(idx.size)
        return rj.job

    # ------------------------------------------------------------ faults
    def residents(self, name: str, count: int) -> List[int]:
        """jids of running jobs owning any unit of ``name`` in [0, count)."""
        out = []
        for jid, rj in self.running.items():
            idx = rj.units.get(name)
            if idx is not None and idx.size and int(idx.min()) < count:
                out.append(jid)
        return sorted(out)

    def apply_drain(self, name: str, count: int, restore_t: float) -> None:
        """Mark units [0, count) of ``name`` as phantom-reserved until
        ``restore_t``.  Resident jobs must have been killed already."""
        rel = self.release[name]
        assert not rel[:count].any(), "drain applied over occupied units"
        assert not self.drained[name][:count].any(), "overlapping drains"
        rel[:count] = restore_t
        self.drained[name][:count] = True
        self.free[name] -= count

    def apply_restore(self, name: str, count: int) -> None:
        """Return the phantom units of a finished drain to the free pool."""
        mask = self.drained[name].copy()
        mask[count:] = False
        n = int(mask.sum())
        self.release[name][mask] = 0.0
        self.drained[name][mask] = False
        self.free[name] += n

    # ------------------------------------------------------------ encoding
    def unit_encoding(self, now: float) -> Dict[str, np.ndarray]:
        """Per-unit (availability, time-to-free) pairs, paper §III-A."""
        out = {}
        for n in self.names:
            rel = self.release[n]
            avail = (rel == 0.0).astype(np.float64)
            ttf = np.where(rel > 0.0, np.maximum(rel - now, 0.0), 0.0)
            out[n] = np.stack([avail, np.minimum(ttf, TTF_HORIZON)], axis=1)
        return out

    def running_jobs(self) -> List[RunningJob]:
        return list(self.running.values())
