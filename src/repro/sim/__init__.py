from .cluster import Cluster, ResourceSpec
from .job import Job
from .metrics import MetricsAccumulator, ScheduleMetrics
from .simulator import (SchedContext, SimConfig, SimResult, Simulator,
                        run_trace, sim_config)
from .vector import (BatchSchedulingPolicy, VectorSimulator, VectorStats,
                     run_traces)

__all__ = [
    "Cluster", "ResourceSpec", "Job", "MetricsAccumulator", "ScheduleMetrics",
    "SchedContext", "SimConfig", "SimResult", "Simulator", "run_trace",
    "sim_config",
    "BatchSchedulingPolicy", "VectorSimulator", "VectorStats", "run_traces",
]
