from .cluster import Cluster, ResourceSpec
from .device import (DeviceRollout, DeviceSimulator, DeviceStats,
                     run_traces_device)
from .job import Job
from .metrics import MetricsAccumulator, ScheduleMetrics
from .simulator import (ENGINES, SchedContext, SimConfig, SimResult,
                        Simulator, run_trace, sim_config)
from .vector import (BatchSchedulingPolicy, VectorSimulator, VectorStats,
                     run_traces)

__all__ = [
    "Cluster", "ResourceSpec", "Job", "MetricsAccumulator", "ScheduleMetrics",
    "ENGINES", "SchedContext", "SimConfig", "SimResult", "Simulator",
    "run_trace", "sim_config",
    "BatchSchedulingPolicy", "VectorSimulator", "VectorStats", "run_traces",
    "DeviceRollout", "DeviceSimulator", "DeviceStats", "run_traces_device",
]
