from .cluster import Cluster, ResourceSpec
from .device import (DeviceRollout, DeviceSimulator, DeviceStats,
                     run_traces_device)
from .job import Job
from .lifecycle import (DEFAULT_MAX_REQUEUES, ELIGIBLE, FAILED, FINISHED,
                        HELD, QUEUED, RUNNING, STATE_NAMES, DrainEvent,
                        FaultSchedule, JobLifecycle, cascade_failures,
                        pipeline_makespan, workflow_components, work_summary)
from .metrics import MetricsAccumulator, ScheduleMetrics
from .simulator import (ENGINES, SchedContext, SimConfig, SimResult,
                        Simulator, run_trace, sim_config)
from .vector import (BatchSchedulingPolicy, VectorSimulator, VectorStats,
                     run_traces)

__all__ = [
    "Cluster", "ResourceSpec", "Job", "MetricsAccumulator", "ScheduleMetrics",
    "ENGINES", "SchedContext", "SimConfig", "SimResult", "Simulator",
    "run_trace", "sim_config",
    "BatchSchedulingPolicy", "VectorSimulator", "VectorStats", "run_traces",
    "DeviceRollout", "DeviceSimulator", "DeviceStats", "run_traces_device",
    "HELD", "ELIGIBLE", "QUEUED", "RUNNING", "FINISHED", "FAILED",
    "STATE_NAMES", "DEFAULT_MAX_REQUEUES", "DrainEvent", "FaultSchedule",
    "JobLifecycle", "cascade_failures", "pipeline_makespan",
    "workflow_components", "work_summary",
]
