"""Event-driven multi-resource scheduling simulator (CQSim-equivalent).

Semantics follow the paper (§IV): jobs are imported from a trace; the
simulation clock advances on job arrival / job completion events; each
event triggers a scheduling pass in which the policy (MRSch agent or a
baseline) repeatedly selects jobs from a window at the head of the queue.
A selected job that fits starts immediately; the first selected job that
does not fit receives a reservation at its earliest fit time and EASY
backfilling then fills the remaining gap (§III-C).

The decision step is *re-entrant*: ``next_decision()`` advances the event
loop until a policy decision is required and returns the pending
``SchedContext``; ``post_action(a)`` applies the selection and resumes.
``run()`` is the synchronous adapter that drives a ``SchedulingPolicy``
inline, and ``repro.sim.vector.VectorSimulator`` interleaves many
simulators through the same API so policy inference can be batched.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Sequence

import numpy as np

from .cluster import Cluster, ResourceSpec
from .job import Job
from .metrics import MetricsAccumulator, ScheduleMetrics


@dataclass
class SchedContext:
    """Everything a policy may observe at one selection step."""
    now: float
    cluster: Cluster
    window: List[Job]            # first W waiting jobs, arrival order
    queue_len: int
    running: List[Job]
    queue: Optional[List[Job]] = None   # full waiting queue (arrival order)


class SchedulingPolicy(Protocol):
    """Deprecation alias: the ``select`` stage of the unified ``Policy``
    protocol (``repro.core.policy_api.Policy``).  Kept so external
    callers typed against the old single-stage surface keep working."""

    def select(self, ctx: SchedContext) -> int:
        """Return an index into ``ctx.window``."""
        ...

    def notify_started(self, job: Job, ctx: SchedContext) -> None: ...
    def notify_reserved(self, job: Job, ctx: SchedContext) -> None: ...


ENGINES = ("sequential", "vector", "device")


@dataclass
class SimConfig:
    window: int = 10             # W, paper §III-C / §IV-C
    backfill: bool = True        # EASY backfilling
    max_events: int = 50_000_000
    engine: str = "sequential"   # "sequential" | "vector" | "device"
    max_rounds: Optional[int] = None   # device engine round-budget override

    @classmethod
    def for_engine(cls, engine: str = "sequential", *, window: int = 10,
                   backfill: bool = True, max_events: Optional[int] = None,
                   max_rounds: Optional[int] = None) -> "SimConfig":
        """The single validated constructor path for all three engines.

        Every harness that fans traces over an engine (sweep, drift
        phases, the evaluation matrix, service-routed replay, the device
        rollout) builds its ``SimConfig`` here, so validation — and any
        future knob — lands everywhere at once.  ``max_rounds`` bounds
        the device engine's scan length (it raises if the budget proves
        too small rather than silently truncating); the host engines
        ignore it.
        """
        if engine not in ENGINES:
            raise ValueError(
                f"unknown engine {engine!r}; expected one of {ENGINES}")
        window = int(window)
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        cfg = SimConfig(window=window, backfill=bool(backfill), engine=engine)
        if max_events is not None:
            if int(max_events) < 1:
                raise ValueError(f"max_events must be >= 1, got {max_events}")
            cfg.max_events = int(max_events)
        if max_rounds is not None:
            if int(max_rounds) < 1:
                raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
            cfg.max_rounds = int(max_rounds)
        return cfg


def sim_config(window: int = 10, backfill: bool = True,
               max_events: Optional[int] = None,
               engine: str = "sequential",
               max_rounds: Optional[int] = None) -> SimConfig:
    """Functional alias of ``SimConfig.for_engine`` (kept for callers of
    the original ``(window, backfill)`` signature)."""
    return SimConfig.for_engine(engine, window=window, backfill=backfill,
                                max_events=max_events, max_rounds=max_rounds)


@dataclass
class SimResult:
    metrics: ScheduleMetrics
    jobs: List[Job]              # ALL trace jobs, including never-started
    makespan: float
    decisions: int
    n_unstarted: int = 0         # jobs still waiting when events drained
    truncated_jobs: int = 0      # waiting jobs beyond the observable window,
    #                              summed over decisions (queue pressure the
    #                              classic W-window encoding cannot see)

    @property
    def started_jobs(self) -> List[Job]:
        return [j for j in self.jobs if j.started]


class Simulator:
    def __init__(self, resources: Sequence[ResourceSpec], jobs: Sequence[Job],
                 policy, config: SimConfig | None = None):
        self.cluster = Cluster(list(resources))
        self.jobs = sorted((j.copy() for j in jobs), key=lambda j: (j.submit, j.jid))
        self.policy = policy
        self.config = config or SimConfig()
        self.queue: List[Job] = []
        self._events: List = []
        self._eseq = itertools.count()
        self.now = 0.0
        self.decisions = 0
        self.truncated = 0
        self.acc = MetricsAccumulator(self.cluster)
        self._started = False
        self._in_pass = False     # inside a scheduling pass awaiting decisions
        self._pending_ctx: Optional[SchedContext] = None

    # ------------------------------------------------------------ event api
    def _push(self, time: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (time, next(self._eseq), kind, payload))

    def _apply(self, kind: str, payload) -> None:
        if kind == "submit":
            self.queue.append(payload)
        else:  # "end"
            self.cluster.release_job(payload)

    # ------------------------------------------------------------ re-entrant
    def start(self) -> None:
        """Seed the event queue.  Idempotent; called lazily by the steppers."""
        if self._started:
            return
        self._started = True
        self._n_events = 0
        for job in self.jobs:
            self._push(job.submit, "submit", job)

    def next_decision(self) -> Optional[SchedContext]:
        """Advance the event loop until the policy must pick a window slot.

        Returns the pending ``SchedContext``, or ``None`` once every event
        has been processed (the simulation is over).  Each returned context
        must be answered with exactly one ``post_action`` call before the
        next ``next_decision``.
        """
        self.start()
        while True:
            if self._in_pass:
                if self.queue:
                    self._pending_ctx = self._ctx()
                    return self._pending_ctx
                self._in_pass = False
            if not self._events:
                return None
            self._n_events += 1
            if self._n_events > self.config.max_events:
                raise RuntimeError("simulator exceeded max_events")
            time, _, kind, payload = heapq.heappop(self._events)
            self.acc.advance(time)
            self.now = time
            self._apply(kind, payload)
            # Coalesce events at identical timestamps before scheduling.
            while self._events and self._events[0][0] == time:
                _, _, k2, p2 = heapq.heappop(self._events)
                self._apply(k2, p2)
            self._in_pass = True

    def post_action(self, action: int) -> None:
        """Apply the policy's selection for the context from ``next_decision``.

        A fitting job starts and the scheduling pass continues (the next
        ``next_decision`` returns a fresh context at the same timestamp);
        the first non-fitting selection takes a reservation, triggers EASY
        backfilling, and ends the pass.
        """
        assert self._in_pass and self.queue, "no pending decision"
        # Reuse the context handed out by next_decision (nothing mutates
        # between the two calls); rebuild only for direct post_action use.
        ctx = self._pending_ctx if self._pending_ctx is not None else self._ctx()
        self._pending_ctx = None
        self.decisions += 1
        self.truncated += max(ctx.queue_len - len(ctx.window), 0)
        a = max(0, min(int(action), len(ctx.window) - 1))
        job = ctx.window[a]
        if self.cluster.fits(job):
            if hasattr(self.policy, "notify_started"):
                self.policy.notify_started(job, ctx)
            self._start(job)
            return
        # First non-fitting selection: reserve it, then backfill.
        if hasattr(self.policy, "notify_reserved"):
            self.policy.notify_reserved(job, ctx)
        if self.config.backfill:
            self._easy_backfill(job)
        self._in_pass = False

    def result(self) -> SimResult:
        """Summarize after the event loop drains.

        ``jobs`` contains the FULL trace, including jobs that never started
        (e.g. demands exceeding capacity, so no event could free enough
        units).  Wait/slowdown metrics aggregate started jobs only — an
        unstarted job has no finite wait — but ``n_unstarted`` is reported
        so starvation cannot pass silently.
        """
        started = [j for j in self.jobs if j.started]
        metrics = self.acc.summarize(started)
        metrics.truncated_jobs = self.truncated
        return SimResult(
            metrics=metrics,
            jobs=list(self.jobs),
            makespan=self.now,
            decisions=self.decisions,
            n_unstarted=len(self.jobs) - len(started),
            truncated_jobs=self.truncated,
        )

    # ------------------------------------------------------------ main loop
    def run(self) -> SimResult:
        """Synchronous adapter: drive ``self.policy.select`` inline."""
        while (ctx := self.next_decision()) is not None:
            self.post_action(int(self.policy.select(ctx)))
        return self.result()

    # ------------------------------------------------------------ scheduling
    def _ctx(self) -> SchedContext:
        return SchedContext(
            now=self.now,
            cluster=self.cluster,
            window=self.queue[: self.config.window],
            queue_len=len(self.queue),
            running=[rj.job for rj in self.cluster.running.values()],
            queue=self.queue,
        )

    def _start(self, job: Job) -> None:
        self.cluster.allocate(job, self.now)
        self.queue.remove(job)
        self._push(job.end, "end", job.jid)
        self.acc.job_started(job)

    def _easy_backfill(self, reserved: Job) -> None:
        """EASY backfilling against a reservation for ``reserved``.

        A waiting job may jump ahead iff it fits now AND either (a) it is
        estimated to finish before the reservation start, or (b) at the
        reservation start the reserved job still fits with the backfilled
        job occupying its units ("shadow" resources).
        """
        t_res = self.cluster.earliest_fit_time(reserved, self.now)
        if not np.isfinite(t_res):
            return
        names = self.cluster.names
        # Free units at t_res assuming estimated releases and no backfill.
        free_at_res = {}
        for n in names:
            rel = self.cluster.release[n]
            free_at_res[n] = int((rel <= t_res).sum())  # free now or released by t_res
        shadow = {n: free_at_res[n] - reserved.demands.get(n, 0) for n in names}

        for job in list(self.queue):
            if job is reserved:
                continue
            if not self.cluster.fits(job):
                continue
            ends_before = self.now + job.walltime <= t_res
            fits_shadow = all(job.demands.get(n, 0) <= shadow[n] for n in names)
            if ends_before or fits_shadow:
                if not ends_before:
                    for n in names:
                        shadow[n] -= job.demands.get(n, 0)
                self._start(job)


def run_trace(resources, jobs, policy, window: int = 10,
              backfill: bool = True) -> SimResult:
    """Convenience one-shot simulation."""
    return Simulator(resources, jobs, policy,
                     sim_config(window=window, backfill=backfill)).run()
