"""Event-driven multi-resource scheduling simulator (CQSim-equivalent).

Semantics follow the paper (§IV): jobs are imported from a trace; the
simulation clock advances on job arrival / job completion events; each
event triggers a scheduling pass in which the policy (MRSch agent or a
baseline) repeatedly selects jobs from a window at the head of the queue.
A selected job that fits starts immediately; the first selected job that
does not fit receives a reservation at its earliest fit time and EASY
backfilling then fills the remaining gap (§III-C).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Sequence

import numpy as np

from .cluster import Cluster, ResourceSpec
from .job import Job
from .metrics import MetricsAccumulator, ScheduleMetrics


@dataclass
class SchedContext:
    """Everything a policy may observe at one selection step."""
    now: float
    cluster: Cluster
    window: List[Job]            # first W waiting jobs, arrival order
    queue_len: int
    running: List[Job]
    queue: Optional[List[Job]] = None   # full waiting queue (arrival order)


class SchedulingPolicy(Protocol):
    def select(self, ctx: SchedContext) -> int:
        """Return an index into ``ctx.window``."""
        ...

    def notify_started(self, job: Job, ctx: SchedContext) -> None: ...
    def notify_reserved(self, job: Job, ctx: SchedContext) -> None: ...


@dataclass
class SimConfig:
    window: int = 10             # W, paper §III-C / §IV-C
    backfill: bool = True        # EASY backfilling
    max_events: int = 50_000_000


@dataclass
class SimResult:
    metrics: ScheduleMetrics
    jobs: List[Job]
    makespan: float
    decisions: int


class Simulator:
    def __init__(self, resources: Sequence[ResourceSpec], jobs: Sequence[Job],
                 policy, config: SimConfig | None = None):
        self.cluster = Cluster(list(resources))
        self.jobs = sorted((j.copy() for j in jobs), key=lambda j: (j.submit, j.jid))
        self.policy = policy
        self.config = config or SimConfig()
        self.queue: List[Job] = []
        self._events: List = []
        self._eseq = itertools.count()
        self.now = 0.0
        self.decisions = 0
        self.acc = MetricsAccumulator(self.cluster)

    # ------------------------------------------------------------ event api
    def _push(self, time: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (time, next(self._eseq), kind, payload))

    # ------------------------------------------------------------ main loop
    def run(self) -> SimResult:
        for job in self.jobs:
            self._push(job.submit, "submit", job)
        n_events = 0
        while self._events:
            n_events += 1
            if n_events > self.config.max_events:
                raise RuntimeError("simulator exceeded max_events")
            time, _, kind, payload = heapq.heappop(self._events)
            self.acc.advance(time)
            self.now = time
            if kind == "submit":
                self.queue.append(payload)
            elif kind == "end":
                self.cluster.release_job(payload)
            # Coalesce events at identical timestamps before scheduling.
            while self._events and self._events[0][0] == time:
                t2, _, k2, p2 = heapq.heappop(self._events)
                if k2 == "submit":
                    self.queue.append(p2)
                else:
                    self.cluster.release_job(p2)
            self._schedule()
        finished = [j for j in self.jobs if j.started]
        return SimResult(
            metrics=self.acc.summarize(finished),
            jobs=finished,
            makespan=self.now,
            decisions=self.decisions,
        )

    # ------------------------------------------------------------ scheduling
    def _ctx(self) -> SchedContext:
        return SchedContext(
            now=self.now,
            cluster=self.cluster,
            window=self.queue[: self.config.window],
            queue_len=len(self.queue),
            running=[rj.job for rj in self.cluster.running_jobs()],
            queue=self.queue,
        )

    def _start(self, job: Job) -> None:
        self.cluster.allocate(job, self.now)
        self.queue.remove(job)
        self._push(job.end, "end", job.jid)
        self.acc.job_started(job)

    def _schedule(self) -> None:
        """One scheduling pass: window selection loop + reservation + EASY."""
        while self.queue:
            ctx = self._ctx()
            if not ctx.window:
                break
            self.decisions += 1
            a = int(self.policy.select(ctx))
            a = max(0, min(a, len(ctx.window) - 1))
            job = ctx.window[a]
            if self.cluster.fits(job):
                if hasattr(self.policy, "notify_started"):
                    self.policy.notify_started(job, ctx)
                self._start(job)
                continue
            # First non-fitting selection: reserve it, then backfill.
            if hasattr(self.policy, "notify_reserved"):
                self.policy.notify_reserved(job, ctx)
            if self.config.backfill:
                self._easy_backfill(job)
            break

    def _easy_backfill(self, reserved: Job) -> None:
        """EASY backfilling against a reservation for ``reserved``.

        A waiting job may jump ahead iff it fits now AND either (a) it is
        estimated to finish before the reservation start, or (b) at the
        reservation start the reserved job still fits with the backfilled
        job occupying its units ("shadow" resources).
        """
        t_res = self.cluster.earliest_fit_time(reserved, self.now)
        if not np.isfinite(t_res):
            return
        names = self.cluster.names
        # Free units at t_res assuming estimated releases and no backfill.
        free_at_res = {}
        for n in names:
            rel = self.cluster.release[n]
            free_at_res[n] = int((rel <= t_res).sum())  # free now or released by t_res
        shadow = {n: free_at_res[n] - reserved.demands.get(n, 0) for n in names}

        for job in list(self.queue):
            if job is reserved:
                continue
            if not self.cluster.fits(job):
                continue
            ends_before = self.now + job.walltime <= t_res
            fits_shadow = all(job.demands.get(n, 0) <= shadow[n] for n in names)
            if ends_before or fits_shadow:
                if not ends_before:
                    for n in names:
                        shadow[n] -= job.demands.get(n, 0)
                self._start(job)


def run_trace(resources, jobs, policy, window: int = 10,
              backfill: bool = True) -> SimResult:
    """Convenience one-shot simulation."""
    return Simulator(resources, jobs, policy,
                     SimConfig(window=window, backfill=backfill)).run()
