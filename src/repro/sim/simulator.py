"""Event-driven multi-resource scheduling simulator (CQSim-equivalent).

Semantics follow the paper (§IV): jobs are imported from a trace; the
simulation clock advances on job arrival / eligibility-release / attempt
end / drain / restore events; each event triggers a scheduling pass in
which the policy (MRSch agent or a baseline) repeatedly selects jobs from
a window at the head of the queue.  A selected job that fits starts
immediately; the first selected job that does not fit receives a
reservation at its earliest fit time and EASY backfilling then fills the
remaining gap (§III-C).

All job state transitions flow through ``repro.sim.lifecycle`` — this
module owns only the event heap, the waiting queue, and the scheduling
pass.  Events coalesced at one timestamp apply in a fixed kind order
(attempt ends, then submissions/releases, then drains, then restores) so
the host engines and the device engine's ``lax.scan`` pump see identical
intermediate states.  End events carry their attempt id: an attempt
killed by a drain leaves a stale end event behind, which is dropped
WITHOUT advancing the clock or opening a pass (the device pump never saw
it either).

The decision step is *re-entrant*: ``next_decision()`` advances the event
loop until a policy decision is required and returns the pending
``SchedContext``; ``post_action(a)`` applies the selection and resumes.
``run()`` is the synchronous adapter that drives a ``SchedulingPolicy``
inline, and ``repro.sim.vector.VectorSimulator`` interleaves many
simulators through the same API so policy inference can be batched.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from ..obs.trace import NULL, Tracer
from .cluster import Cluster, ResourceSpec
from .job import Job
from .lifecycle import (ELIGIBLE, FaultSchedule, JobLifecycle, insert_queued)
from .metrics import MetricsAccumulator, ScheduleMetrics


@dataclass
class SchedContext:
    """Everything a policy may observe at one selection step."""
    now: float
    cluster: Cluster
    window: List[Job]            # first W waiting jobs, queue order
    queue_len: int
    running: List[Job]
    queue: Optional[List[Job]] = None   # full waiting queue (sorted by
    #                                     original submit time, then jid)


class SchedulingPolicy(Protocol):
    """Deprecation alias: the ``select`` stage of the unified ``Policy``
    protocol (``repro.core.policy_api.Policy``).  Kept so external
    callers typed against the old single-stage surface keep working."""

    def select(self, ctx: SchedContext) -> int:
        """Return an index into ``ctx.window``."""
        ...

    def notify_started(self, job: Job, ctx: SchedContext) -> None: ...
    def notify_reserved(self, job: Job, ctx: SchedContext) -> None: ...


ENGINES = ("sequential", "vector", "device")

# Application order for events coalesced at one timestamp.  Ends first
# (a job finishing at t is NOT killed by a drain at t), then queue
# entries, then drains, then restores — mirrored by the device pump.
_KIND_ORDER = {"end": 0, "submit": 1, "release": 1, "drain": 2, "restore": 3}


@dataclass
class SimConfig:
    window: int = 10             # W, paper §III-C / §IV-C
    backfill: bool = True        # EASY backfilling
    max_events: int = 50_000_000
    engine: str = "sequential"   # "sequential" | "vector" | "device"
    max_rounds: Optional[int] = None   # device engine round-budget override

    @classmethod
    def for_engine(cls, engine: str = "sequential", *, window: int = 10,
                   backfill: bool = True, max_events: Optional[int] = None,
                   max_rounds: Optional[int] = None) -> "SimConfig":
        """The single validated constructor path for all three engines.

        Every harness that fans traces over an engine (sweep, drift
        phases, the evaluation matrix, service-routed replay, the device
        rollout) builds its ``SimConfig`` here, so validation — and any
        future knob — lands everywhere at once.  ``max_rounds`` bounds
        the device engine's scan length (it raises if the budget proves
        too small rather than silently truncating); the host engines
        ignore it.
        """
        if engine not in ENGINES:
            raise ValueError(
                f"unknown engine {engine!r}; expected one of {ENGINES}")
        window = int(window)
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        cfg = SimConfig(window=window, backfill=bool(backfill), engine=engine)
        if max_events is not None:
            if int(max_events) < 1:
                raise ValueError(f"max_events must be >= 1, got {max_events}")
            cfg.max_events = int(max_events)
        if max_rounds is not None:
            if int(max_rounds) < 1:
                raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
            cfg.max_rounds = int(max_rounds)
        return cfg


def sim_config(window: int = 10, backfill: bool = True,
               max_events: Optional[int] = None,
               engine: str = "sequential",
               max_rounds: Optional[int] = None) -> SimConfig:
    """Functional alias of ``SimConfig.for_engine`` (kept for callers of
    the original ``(window, backfill)`` signature)."""
    return SimConfig.for_engine(engine, window=window, backfill=backfill,
                                max_events=max_events, max_rounds=max_rounds)


@dataclass
class SimResult:
    metrics: ScheduleMetrics
    jobs: List[Job]              # ALL trace jobs, including never-started
    makespan: float
    decisions: int
    n_unstarted: int = 0         # jobs still waiting when events drained
    truncated_jobs: int = 0      # waiting jobs beyond the observable window,
    #                              summed over decisions (queue pressure the
    #                              classic W-window encoding cannot see)
    requeues: int = 0            # killed attempts that re-entered the queue
    n_failed: int = 0            # terminally FAILED jobs (incl. cascades)

    @property
    def started_jobs(self) -> List[Job]:
        return [j for j in self.jobs if j.started]


class Simulator:
    def __init__(self, resources: Sequence[ResourceSpec], jobs: Sequence[Job],
                 policy, config: SimConfig | None = None, *,
                 faults: Optional[FaultSchedule] = None,
                 tracer: Tracer = NULL, env: int = 0):
        self.cluster = Cluster(list(resources))
        self.jobs = sorted((j.copy() for j in jobs), key=lambda j: (j.submit, j.jid))
        self.policy = policy
        self.config = config or SimConfig()
        self.lifecycle = JobLifecycle(self.jobs, self.cluster, faults=faults)
        self.queue: List[Job] = []
        self._events: List = []
        self._eseq = itertools.count()
        self.now = 0.0
        self.decisions = 0
        self.truncated = 0
        self.acc = MetricsAccumulator(self.cluster)
        self._started = False
        self._in_pass = False     # inside a scheduling pass awaiting decisions
        self._pending_ctx: Optional[SchedContext] = None
        # mrsch.trace/v1 emission (docs/observability.md).  The default
        # NULL tracer keeps these paths allocation-free; ``env`` tags
        # events when many simulators share one tracer (vector engine).
        self.tracer = tracer
        self.env = int(env)

    # ------------------------------------------------------------ event api
    def _push(self, time: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (time, next(self._eseq), kind, payload))

    def _is_stale(self, kind: str, payload) -> bool:
        if kind == "end":
            jid, attempt = payload
            return self.lifecycle.is_stale_end(self.lifecycle.by_id[jid],
                                               attempt)
        if kind == "release":
            return payload.state != ELIGIBLE
        return False

    def _apply(self, kind: str, payload) -> None:
        lc = self.lifecycle
        tr, env = self.tracer, self.env
        if kind == "submit":
            out, ready = lc.on_submit(payload, self.now)
            if out == "queued":
                insert_queued(self.queue, payload)
                tr.job_queued(env, self.now, payload.jid)
            elif out == "eligible":
                self._push(ready, "release", payload)
        elif kind == "release":
            if lc.on_release(payload):
                insert_queued(self.queue, payload)
                tr.job_queued(env, self.now, payload.jid)
        elif kind == "end":
            jid, _attempt = payload
            job = lc.by_id[jid]
            out, released = lc.on_end(job, self.now)
            if out == "requeued":
                insert_queued(self.queue, job)
                tr.job_requeue(env, self.now, job.jid, job.requeues)
                tr.job_queued(env, self.now, job.jid)
            else:
                if out == "failed":
                    tr.job_fail(env, self.now, job.jid)
                else:
                    tr.job_finish(env, self.now, job.jid)
                for child, ready in released:
                    if ready <= self.now:
                        insert_queued(self.queue, child)
                        tr.job_queued(env, self.now, child.jid)
                    else:
                        self._push(ready, "release", child)
        elif kind == "drain":
            tr.drain(env, self.now, payload.resource, payload.units)
            for job, out in lc.on_drain(payload, self.now):
                if out == "requeued":
                    insert_queued(self.queue, job)
                    tr.job_requeue(env, self.now, job.jid, job.requeues)
                    tr.job_queued(env, self.now, job.jid)
                else:
                    tr.job_fail(env, self.now, job.jid)
        else:  # "restore"
            lc.on_restore(payload)
            tr.restore(env, self.now, payload.resource, payload.units)

    # ------------------------------------------------------------ re-entrant
    def start(self) -> None:
        """Seed the event queue.  Idempotent; called lazily by the steppers."""
        if self._started:
            return
        self._started = True
        self._n_events = 0
        for job in self.jobs:
            self._push(job.submit, "submit", job)
        for d in self.lifecycle.faults.drains:
            self._push(d.time, "drain", d)
            if np.isfinite(d.duration):
                self._push(d.time + d.duration, "restore", d)

    def next_decision(self) -> Optional[SchedContext]:
        """Advance the event loop until the policy must pick a window slot.

        Returns the pending ``SchedContext``, or ``None`` once every event
        has been processed (the simulation is over).  Each returned context
        must be answered with exactly one ``post_action`` call before the
        next ``next_decision``.
        """
        self.start()
        while True:
            if self._in_pass:
                if self.queue:
                    self._pending_ctx = self._ctx()
                    return self._pending_ctx
                self._in_pass = False
            if not self._events:
                return None
            # Pop the full coalesced batch at the next timestamp, dropping
            # stale events.  An all-stale batch neither advances the clock
            # nor opens a pass — the device pump has no such event at all.
            # Likewise a submission that cannot join the queue yet (parents
            # unfinished, or think-time pending) is applied WITHOUT
            # advancing the clock: its queue entry is a later release/end
            # event, which is the only event the device pump sees.
            time = self._events[0][0]
            batch = []
            while self._events and self._events[0][0] == time:
                _, seq, kind, payload = heapq.heappop(self._events)
                if self._is_stale(kind, payload):
                    continue
                if (kind == "submit"
                        and self.lifecycle.ready_time(payload) > time):
                    self._apply(kind, payload)
                    continue
                batch.append((_KIND_ORDER[kind], seq, kind, payload))
            if not batch:
                continue
            self._n_events += 1
            if self._n_events > self.config.max_events:
                raise RuntimeError("simulator exceeded max_events")
            self.acc.advance(time)
            self.now = time
            for _, _, kind, payload in sorted(batch):
                self._apply(kind, payload)
            self._in_pass = True

    def post_action(self, action: int) -> None:
        """Apply the policy's selection for the context from ``next_decision``.

        A fitting job starts and the scheduling pass continues (the next
        ``next_decision`` returns a fresh context at the same timestamp);
        the first non-fitting selection takes a reservation, triggers EASY
        backfilling, and ends the pass.
        """
        assert self._in_pass and self.queue, "no pending decision"
        # Reuse the context handed out by next_decision (nothing mutates
        # between the two calls); rebuild only for direct post_action use.
        ctx = self._pending_ctx if self._pending_ctx is not None else self._ctx()
        self._pending_ctx = None
        self.decisions += 1
        self.truncated += max(ctx.queue_len - len(ctx.window), 0)
        a = max(0, min(int(action), len(ctx.window) - 1))
        job = ctx.window[a]
        if self.cluster.fits(job):
            self.tracer.decision(self.env, self.now, a, job.jid,
                                 ctx.queue_len, 1)
            if hasattr(self.policy, "notify_started"):
                self.policy.notify_started(job, ctx)
            self._start(job)
            return
        # First non-fitting selection: reserve it, then backfill.
        self.tracer.decision(self.env, self.now, a, job.jid,
                             ctx.queue_len, 0)
        self.tracer.reserve(self.env, self.now, job.jid)
        if hasattr(self.policy, "notify_reserved"):
            self.policy.notify_reserved(job, ctx)
        if self.config.backfill:
            n_bf = self._easy_backfill(job)
            self.tracer.backfill(self.env, self.now, n_bf)
        self._in_pass = False

    def result(self) -> SimResult:
        """Summarize after the event loop drains.

        ``jobs`` contains the FULL trace, including jobs that never started
        (e.g. demands exceeding capacity, so no event could free enough
        units).  Wait/slowdown metrics aggregate started jobs only — an
        unstarted job has no finite wait — but ``n_unstarted`` is reported
        so starvation cannot pass silently.  Failure cascades (children of
        FAILED ancestors) are resolved here, inside ``summarize``.
        """
        started = [j for j in self.jobs if j.started]
        metrics = self.acc.summarize(started, all_jobs=self.jobs)
        metrics.truncated_jobs = self.truncated
        return SimResult(
            metrics=metrics,
            jobs=list(self.jobs),
            makespan=self.now,
            decisions=self.decisions,
            n_unstarted=len(self.jobs) - len(started),
            truncated_jobs=self.truncated,
            requeues=metrics.requeues,
            n_failed=metrics.n_failed,
        )

    # ------------------------------------------------------------ main loop
    def run(self) -> SimResult:
        """Synchronous adapter: drive ``self.policy.select`` inline."""
        while (ctx := self.next_decision()) is not None:
            self.post_action(int(self.policy.select(ctx)))
        return self.result()

    # ------------------------------------------------------------ scheduling
    def _ctx(self) -> SchedContext:
        return SchedContext(
            now=self.now,
            cluster=self.cluster,
            window=self.queue[: self.config.window],
            queue_len=len(self.queue),
            running=[rj.job for rj in self.cluster.running.values()],
            queue=self.queue,
        )

    def _start(self, job: Job, bf: int = 0) -> None:
        end = self.lifecycle.start(job, self.now)
        self.queue.remove(job)
        self._push(end, "end", (job.jid, job.requeues))
        self.acc.job_started(job)
        self.tracer.job_start(self.env, self.now, job.jid, bf)

    def _easy_backfill(self, reserved: Job) -> int:
        """EASY backfilling against a reservation for ``reserved``.

        A waiting job may jump ahead iff it fits now AND either (a) it is
        estimated to finish before the reservation start, or (b) at the
        reservation start the reserved job still fits with the backfilled
        job occupying its units ("shadow" resources).  Drained units are
        phantom reservations, so they participate automatically.
        """
        t_res = self.cluster.earliest_fit_time(reserved, self.now)
        if not np.isfinite(t_res):
            return 0
        names = self.cluster.names
        # Free units at t_res assuming estimated releases and no backfill.
        free_at_res = {}
        for n in names:
            rel = self.cluster.release[n]
            free_at_res[n] = int((rel <= t_res).sum())  # free now or released by t_res
        shadow = {n: free_at_res[n] - reserved.demands.get(n, 0) for n in names}

        n_started = 0
        for job in list(self.queue):
            if job is reserved:
                continue
            if not self.cluster.fits(job):
                continue
            ends_before = self.now + job.walltime <= t_res
            fits_shadow = all(job.demands.get(n, 0) <= shadow[n] for n in names)
            if ends_before or fits_shadow:
                if not ends_before:
                    for n in names:
                        shadow[n] -= job.demands.get(n, 0)
                self._start(job, bf=1)
                n_started += 1
        return n_started


def run_trace(resources, jobs, policy, window: int = 10,
              backfill: bool = True,
              faults: Optional[FaultSchedule] = None) -> SimResult:
    """Convenience one-shot simulation."""
    return Simulator(resources, jobs, policy,
                     sim_config(window=window, backfill=backfill),
                     faults=faults).run()
