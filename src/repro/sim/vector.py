"""Batched multi-environment rollout engine (ROADMAP scale+speed path).

``VectorSimulator`` advances N independent trace simulations in lockstep
*rounds*: each round gathers the pending ``SchedContext`` from every
environment that needs a decision, hands the whole batch to the policy in
ONE call (``select_batch`` — a single jitted DFP forward for the MRSch
agent), scatters the selected actions back, and lets each environment's
event loop run to its next decision point.  Environments that drain their
event queues drop out of subsequent rounds — or, when a ``refill``
callback is supplied (the vectorized trainer in ``repro.core.train``),
are immediately re-seeded with their next trace so the decision batch
stays wide across a whole curriculum.  This mirrors the parallel episode
collection that makes HPC-scheduling RL tractable in DRAS (Fan & Lan,
arXiv:2102.06243) and related co-scheduler work (arXiv:2401.09706).

Per-environment trajectories are identical to running each ``Simulator``
alone: the engine only interleaves *when* decisions are computed, never
what each environment observes — each context is built from that
environment's own cluster/queue state at its own simulation clock.

Batching requires a policy whose decision is a function of the context
(the MRSch agent, FCFS, ...).  Policies whose ``select_batch`` accepts a
``slots`` keyword (the MRSch agent in training mode) additionally receive
the environment index of every context, so per-environment state such as
episode accumulators stays separated.  Policies that keep cross-call
state keyed to one trace (e.g. ``GAOptimizer``'s cached plan) should run
through the sequential per-environment fallback, which this engine uses
automatically whenever the policy lacks ``select_batch``.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from typing import Callable, List, Optional, Protocol, Sequence

import numpy as np

from ..obs.profiling import annotate
from ..obs.trace import NULL, Tracer
from .cluster import ResourceSpec
from .job import Job
from .lifecycle import FaultSchedule
from .simulator import SchedContext, SimConfig, SimResult, Simulator


class BatchSchedulingPolicy(Protocol):
    """Deprecation alias: the batched host stage of the unified
    ``Policy`` protocol.  ``repro.core.policy_api.WindowPolicy`` derives
    this stage from ``score_window`` for protocol policies."""

    def select_batch(self, ctxs: Sequence[SchedContext]) -> np.ndarray:
        """Return one window index per context."""
        ...


@dataclass
class VectorStats:
    """Instrumentation for the lockstep engine (fed into bench JSON)."""
    rounds: int = 0              # lockstep rounds executed
    decisions: int = 0           # total decisions across environments
    policy_calls: int = 0        # batched policy invocations
    max_batch: int = 0           # widest decision batch seen
    episodes: int = 0            # environment episodes completed

    def as_dict(self) -> dict:
        return {"rounds": self.rounds, "decisions": self.decisions,
                "policy_calls": self.policy_calls,
                "max_batch": self.max_batch, "episodes": self.episodes}


class VectorSimulator:
    """Run N simulators in lockstep with batched policy inference.

    Parameters
    ----------
    sims:   the environments; each may carry its own trace and config.
    policy: shared decision policy.  If omitted, every simulator's own
            ``policy`` answers its contexts one at a time (lockstep order
            is preserved but nothing batches).
    """

    def __init__(self, sims: Sequence[Simulator], policy=None):
        self.sims = list(sims)
        self.policy = policy
        self.stats = VectorStats()
        select_batch = getattr(policy, "select_batch", None)
        self._batched = select_batch is not None
        self._slot_aware = False
        if self._batched:
            try:
                params = inspect.signature(select_batch).parameters
                self._slot_aware = "slots" in params
            except (TypeError, ValueError):
                pass

    @staticmethod
    def _fault_list(faults, n: int):
        """Normalize the ``faults`` argument: None, one schedule shared by
        every environment, or one (possibly None) schedule per jobset."""
        if faults is None or isinstance(faults, FaultSchedule):
            return [faults] * n
        faults = list(faults)
        if len(faults) != n:
            raise ValueError(
                f"got {len(faults)} fault schedules for {n} jobsets")
        return faults

    @staticmethod
    def _env_ids(env_ids, n: int):
        if env_ids is None:
            return list(range(n))
        env_ids = [int(e) for e in env_ids]
        if len(env_ids) != n:
            raise ValueError(f"got {len(env_ids)} env ids for {n} jobsets")
        return env_ids

    @classmethod
    def from_jobsets(cls, resources: Sequence[ResourceSpec],
                     jobsets: Sequence[Sequence[Job]], policy,
                     config: SimConfig | None = None, *,
                     faults=None, tracer: Tracer = NULL,
                     env_ids=None) -> "VectorSimulator":
        """One environment per jobset, all sharing cluster spec and policy.

        ``tracer`` is shared by every environment; ``env_ids`` (default
        ``0..N-1``) tags each environment's events so one trace file can
        hold a whole matrix run.
        """
        flist = cls._fault_list(faults, len(jobsets))
        eids = cls._env_ids(env_ids, len(jobsets))
        sims = [Simulator(resources, jobs, policy, config, faults=f,
                          tracer=tracer, env=e)
                for jobs, f, e in zip(jobsets, flist, eids)]
        return cls(sims, policy=policy)

    @classmethod
    def from_factory(cls, resources: Sequence[ResourceSpec],
                     jobsets: Sequence[Sequence[Job]],
                     policy_factory: Callable[[], object],
                     config: SimConfig | None = None, *,
                     faults=None, tracer: Tracer = NULL,
                     env_ids=None) -> "VectorSimulator":
        """One FRESH policy instance per environment, lockstep preserved.

        For stateful sequential policies (``GAOptimizer``'s cached plan,
        learning baselines) that must not share state across lanes: each
        environment answers its own contexts through its own instance via
        the engine's sequential fallback.  Nothing batches, but the
        round interleaving — and therefore any refill/on_round driving —
        matches the batched policies, so matrix cells stay comparable.
        """
        flist = cls._fault_list(faults, len(jobsets))
        eids = cls._env_ids(env_ids, len(jobsets))
        sims = [Simulator(resources, jobs, policy_factory(), config, faults=f,
                          tracer=tracer, env=e)
                for jobs, f, e in zip(jobsets, flist, eids)]
        return cls(sims, policy=None)

    # ---------------------------------------------------------------- run
    def _advance(self, i: int,
                 refill: Optional[Callable[[int, SimResult],
                                           Optional[Simulator]]],
                 results: List[SimResult]) -> Optional[SchedContext]:
        """Step env ``i`` to its next decision, refilling drained traces."""
        while True:
            ctx = self.sims[i].next_decision()
            if ctx is not None:
                return ctx
            if refill is None:
                return None
            self.stats.episodes += 1
            result = self.sims[i].result()
            results.append(result)
            prev_policy = self.sims[i].policy
            nxt = refill(i, result)
            if nxt is None:
                return None
            if nxt.policy is None:
                # Carry the slot's policy instance across the refill: a
                # factory-built engine owns per-environment policy state
                # (GA plan caches, learning baselines) that must survive
                # the trace swap — re-instantiating here would silently
                # reset stateful policies mid-curriculum.
                nxt.policy = prev_policy
            self.sims[i] = nxt

    def run(self, refill=None, on_round=None) -> List[SimResult]:
        """Drive all environments to completion; return their results.

        refill(i, result) — called the moment environment ``i`` drains;
            may return a fresh ``Simulator`` to continue collecting in
            that slot (or None to retire it).  With a refill callback the
            returned list holds every completed episode in completion
            order; without one it holds exactly one result per slot, in
            slot order.
        on_round(round_idx, n_live) — called after each lockstep round's
            actions are applied; the vectorized trainer hooks interleaved
            gradient steps here.
        """
        results: List[SimResult] = []
        pending: List[Optional[SchedContext]] = [
            self._advance(i, refill, results)
            for i in range(len(self.sims))]
        while True:
            live = [i for i, c in enumerate(pending) if c is not None]
            if not live:
                break
            ctxs = [pending[i] for i in live]
            with annotate("mrsch.vector.policy_select"):
                if self._slot_aware:
                    actions = np.asarray(self.policy.select_batch(
                        ctxs, slots=live))
                elif self._batched:
                    actions = np.asarray(self.policy.select_batch(ctxs))
                else:
                    actions = [self.sims[i].policy.select(c)
                               for i, c in zip(live, ctxs)]
            self.stats.policy_calls += 1 if self._batched else len(live)
            self.stats.decisions += len(live)
            self.stats.max_batch = max(self.stats.max_batch, len(live))
            for i, a in zip(live, actions):
                self.sims[i].post_action(int(a))
                pending[i] = self._advance(i, refill, results)
            if on_round is not None:
                on_round(self.stats.rounds, len(live))
            self.stats.rounds += 1
        if refill is None:
            return [s.result() for s in self.sims]
        return results


def run_traces(resources: Sequence[ResourceSpec],
               jobsets: Sequence[Sequence[Job]], policy, window: int = 10,
               backfill: bool = True, faults=None) -> List[SimResult]:
    """Convenience batched counterpart of ``run_trace``."""
    vec = VectorSimulator.from_jobsets(
        resources, jobsets, policy,
        SimConfig.for_engine("vector", window=window, backfill=backfill),
        faults=faults)
    return vec.run()
