"""Batched multi-environment rollout engine.

``VectorSimulator`` advances N independent trace simulations in lockstep
*rounds*: each round gathers the pending ``SchedContext`` from every
environment that needs a decision, hands the whole batch to the policy in
ONE call (``select_batch`` — a single jitted DFP forward for the MRSch
agent), scatters the selected actions back, and lets each environment's
event loop run to its next decision point.  Environments that drain their
event queues simply drop out of subsequent rounds.

Per-environment trajectories are identical to running each ``Simulator``
alone: the engine only interleaves *when* decisions are computed, never
what each environment observes — each context is built from that
environment's own cluster/queue state at its own simulation clock.

Batching requires a policy whose decision is a pure function of the
context (the evaluation-mode MRSch agent, FCFS, ...).  Policies that keep
cross-call state keyed to one trace (e.g. ``GAOptimizer``'s cached plan)
should run through the sequential per-environment fallback, which this
engine uses automatically whenever the policy lacks ``select_batch``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, Sequence

import numpy as np

from .cluster import ResourceSpec
from .job import Job
from .simulator import SchedContext, SimConfig, SimResult, Simulator


class BatchSchedulingPolicy(Protocol):
    def select_batch(self, ctxs: Sequence[SchedContext]) -> np.ndarray:
        """Return one window index per context."""
        ...


@dataclass
class VectorStats:
    """Instrumentation for the lockstep engine (fed into bench JSON)."""
    rounds: int = 0              # lockstep rounds executed
    decisions: int = 0           # total decisions across environments
    policy_calls: int = 0        # batched policy invocations
    max_batch: int = 0           # widest decision batch seen

    def as_dict(self) -> dict:
        return {"rounds": self.rounds, "decisions": self.decisions,
                "policy_calls": self.policy_calls, "max_batch": self.max_batch}


class VectorSimulator:
    """Run N simulators in lockstep with batched policy inference.

    Parameters
    ----------
    sims:   the environments; each may carry its own trace and config.
    policy: shared decision policy.  If omitted, every simulator's own
            ``policy`` answers its contexts one at a time (lockstep order
            is preserved but nothing batches).
    """

    def __init__(self, sims: Sequence[Simulator], policy=None):
        self.sims = list(sims)
        self.policy = policy
        self.stats = VectorStats()

    @classmethod
    def from_jobsets(cls, resources: Sequence[ResourceSpec],
                     jobsets: Sequence[Sequence[Job]], policy,
                     config: SimConfig | None = None) -> "VectorSimulator":
        """One environment per jobset, all sharing cluster spec and policy."""
        sims = [Simulator(resources, jobs, policy, config) for jobs in jobsets]
        return cls(sims, policy=policy)

    # ---------------------------------------------------------------- run
    def run(self) -> List[SimResult]:
        batched = self.policy is not None and hasattr(self.policy,
                                                      "select_batch")
        pending: List[Optional[SchedContext]] = [s.next_decision()
                                                 for s in self.sims]
        while True:
            live = [i for i, c in enumerate(pending) if c is not None]
            if not live:
                break
            ctxs = [pending[i] for i in live]
            if batched:
                actions = np.asarray(self.policy.select_batch(ctxs))
            else:
                actions = [self.sims[i].policy.select(c)
                           for i, c in zip(live, ctxs)]
            self.stats.rounds += 1
            self.stats.policy_calls += 1 if batched else len(live)
            self.stats.decisions += len(live)
            self.stats.max_batch = max(self.stats.max_batch, len(live))
            for i, a in zip(live, actions):
                self.sims[i].post_action(int(a))
                pending[i] = self.sims[i].next_decision()
        return [s.result() for s in self.sims]


def run_traces(resources: Sequence[ResourceSpec],
               jobsets: Sequence[Sequence[Job]], policy, window: int = 10,
               backfill: bool = True) -> List[SimResult]:
    """Convenience batched counterpart of ``run_trace``."""
    vec = VectorSimulator.from_jobsets(
        resources, jobsets, policy, SimConfig(window=window, backfill=backfill))
    return vec.run()
