"""Multi-head Latent Attention (DeepSeek V2/V3).

Prefill/train use the decompressed form (per-head K/V up-projections);
decode uses the *absorbed* form against the compressed cache
(c_kv: kv_lora_rank + rope dims per token), which is MLA's serving win —
the KV cache is rank-512+64 regardless of head count.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig
from ..distributed.sharding import shard, tp_row_matmul
from .attention import dense_attention, flash_attention_scan
from .layers import _init_dense, apply_rope, rmsnorm, rmsnorm_init


def mla_init(key, d_model: int, n_heads: int, mla: MLAConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    qk_head = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    p = {}
    if mla.q_lora_rank:
        p["w_dq"] = _init_dense(ks[0], d_model, mla.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(mla.q_lora_rank, dtype)
        p["w_uq"] = _init_dense(ks[1], mla.q_lora_rank, n_heads * qk_head, dtype)
    else:
        p["w_uq"] = _init_dense(ks[1], d_model, n_heads * qk_head, dtype)
    p["w_dkv"] = _init_dense(ks[2], d_model,
                             mla.kv_lora_rank + mla.qk_rope_head_dim, dtype)
    p["kv_norm"] = rmsnorm_init(mla.kv_lora_rank, dtype)
    p["w_uk"] = _init_dense(ks[3], mla.kv_lora_rank,
                            n_heads * mla.qk_nope_head_dim, dtype)
    p["w_uv"] = _init_dense(ks[4], mla.kv_lora_rank,
                            n_heads * mla.v_head_dim, dtype)
    p["wo"] = _init_dense(ks[5], n_heads * mla.v_head_dim, d_model, dtype)
    return p


def _queries(params, x, n_heads: int, mla: MLAConfig, positions):
    B, S, _ = x.shape
    qk_head = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    w_uq = shard(params["w_uq"], None, "heads")
    if mla.q_lora_rank:
        cq = rmsnorm(params["q_norm"], x @ shard(params["w_dq"], None, None))
        q = (cq @ w_uq).reshape(B, S, n_heads, qk_head)
    else:
        q = (x @ w_uq).reshape(B, S, n_heads, qk_head)
    q = shard(q, "batch", None, "heads", None)
    q_nope = q[..., : mla.qk_nope_head_dim]
    q_rope = apply_rope(q[..., mla.qk_nope_head_dim:], positions, 10_000.0)
    return q_nope, q_rope


def _compressed_kv(params, x, mla: MLAConfig, positions):
    ckv = x @ shard(params["w_dkv"], None, None)
    c = rmsnorm(params["kv_norm"], ckv[..., : mla.kv_lora_rank])
    k_rope = ckv[..., mla.kv_lora_rank:][:, :, None, :]        # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, 10_000.0)[:, :, 0]
    return c, k_rope


def mla_apply(params, x, positions, *, n_heads: int, mla: MLAConfig,
              dense_threshold: int = 2048) -> jnp.ndarray:
    """Decompressed-form MLA for train/prefill.  x (B,S,D)."""
    B, S, D = x.shape
    q_nope, q_rope = _queries(params, x, n_heads, mla, positions)
    c, k_rope = _compressed_kv(params, x, mla, positions)
    k_nope = (c @ shard(params["w_uk"], None, "heads")
              ).reshape(B, S, n_heads, mla.qk_nope_head_dim)
    v = (c @ shard(params["w_uv"], None, "heads")
         ).reshape(B, S, n_heads, mla.v_head_dim)
    k_nope = shard(k_nope, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, n_heads, mla.qk_rope_head_dim))],
        axis=-1)
    # Grouped layout with KV == heads (MLA decompresses to per-head K/V).
    qg = q[:, :, :, None, :]
    if S <= dense_threshold:
        out = dense_attention(qg, k, v, causal=True)
    else:
        out = flash_attention_scan(qg, k, v, causal=True)
    out = out.reshape(B, S, n_heads * mla.v_head_dim)
    return shard(tp_row_matmul(out, shard(params["wo"], "heads", None)),
                 "batch", "act_seq", None)


def mla_decode_apply(params, x, cache_c, cache_rope, pos, *, n_heads: int,
                     mla: MLAConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Absorbed-form decode.  cache_c (B,Smax,kv_lora), cache_rope
    (B,Smax,rope).  Scores: q_nope W_uk^T c  +  q_rope k_rope."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _queries(params, x, n_heads, mla, positions)
    c, k_rope = _compressed_kv(params, x, mla, positions)
    cache_c = jax.lax.dynamic_update_slice(cache_c, c.astype(cache_c.dtype),
                                           (0, pos, 0))
    cache_rope = jax.lax.dynamic_update_slice(
        cache_rope, k_rope.astype(cache_rope.dtype), (0, pos, 0))
    # Absorb W_uk into the query:  (B,1,H,nope) @ (lora, H*nope) -> (B,H,lora)
    w_uk = params["w_uk"].reshape(mla.kv_lora_rank, n_heads,
                                  mla.qk_nope_head_dim)
    q_abs = jnp.einsum("bshn,lhn->bhl", q_nope, w_uk)
    scale = (mla.qk_nope_head_dim + mla.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhl,btl->bht", q_abs, cache_c.astype(q_abs.dtype))
         + jnp.einsum("bshr,btr->bht", q_rope,
                      cache_rope.astype(q_rope.dtype)))
    s = s.astype(jnp.float32) * scale
    tpos = jnp.arange(cache_c.shape[1])[None, None, :]
    s = jnp.where(tpos <= pos, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bht,btl->bhl", w, cache_c.astype(x.dtype))
    w_uv = params["w_uv"].reshape(mla.kv_lora_rank, n_heads, mla.v_head_dim)
    out = jnp.einsum("bhl,lhv->bhv", ctx, w_uv).reshape(B, 1, -1)
    return shard(out @ params["wo"], "batch", None, None), cache_c, cache_rope
