"""Shared building blocks for the decoder zoo.

Everything is functional: ``*_init(key, cfg) -> params`` (pure jax, safe
under ``jax.eval_shape`` so the dry-run never allocates) and
``*_apply(params, x, ...)``.  Compute dtype is bf16 with fp32 norms/softmax;
parameter dtype is configurable.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard as _shard
from ..distributed.sharding import tp_row_matmul as _tp_row


def _init_dense(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * std).astype(dtype)


# ----------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., S, H, dh); positions: (..., S).  Rotates the leading
    ``fraction`` of the head dim (partial rotary for stablelm/chatglm)."""
    dh = x.shape[-1]
    inv, rot = rope_frequencies(dh, theta, fraction)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv        # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(*x1.shape[:-1], rot)
    return jnp.concatenate([yr.astype(x.dtype), x[..., rot:]], axis=-1)


# ----------------------------------------------------------------- embed
def embedding_init(key, vocab: int, d: int, dtype) -> dict:
    tbl = jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype)
    return {"table": tbl}


def embedding_lookup(params, tokens):
    table = _shard(params["table"], "vocab", None)     # gather fsdp dim
    out = jnp.take(table, tokens, axis=0)
    return _shard(out, "batch", None, None)


def unembed(params, x, softcap: float = 0.0):
    table = _shard(params["table"], "vocab", None)
    logits = jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return _shard(logits, "batch", "act_seq", "vocab")


def lm_head_init(key, d: int, vocab: int, dtype) -> dict:
    return {"w": _init_dense(key, d, vocab, dtype)}


def lm_head_apply(params, x, softcap: float = 0.0):
    logits = (x @ _shard(params["w"], None, "vocab")).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return _shard(logits, "batch", "act_seq", "vocab")


# ----------------------------------------------------------------- ffn
def ffn_init(key, d: int, f: int, glu: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": _init_dense(ks[0], d, f, dtype),
         "w_down": _init_dense(ks[1], f, d, dtype)}
    if glu:
        p["w_gate"] = _init_dense(ks[2], d, f, dtype)
    return p


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def ffn_apply(params, x, act: str, glu: bool):
    # ZeRO-3 "gather-on-use": weights are *stored* fsdp-sharded over data;
    # constraining the use-site to (None, mlp) makes GSPMD emit a small
    # weight all-gather instead of partial-sum all-reducing the (B,S,F)
    # activation (measured 40x wire difference on nemotron-340b).
    w_up = _shard(params["w_up"], None, "mlp")
    up = x @ w_up
    up = _shard(up, "batch", None, "mlp")
    if glu:
        gate = _shard(x @ _shard(params["w_gate"], None, "mlp"),
                      "batch", None, "mlp")
        h = _act(act)(gate) * up
    else:
        h = _act(act)(up)
    out = _tp_row(h, _shard(params["w_down"], "mlp", None))
    return _shard(out, "batch", "act_seq", None)


# ----------------------------------------------------------------- losses
def softmax_cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) fp32, labels (B,S) int32 -> scalar mean nll."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
