"""Config-driven decoder stack covering all 10 assigned architectures.

One generic implementation; blocks compose by ``ModelConfig``:
  dense GQA/MQA  -> attention + (GLU or squared-ReLU) FFN
  moe (deepseek) -> MLA attention + (dense-FFN prefix, MoE main stack)
  ssm (mamba2)   -> SSD blocks, attention-free
  hybrid (zamba2)-> SSD backbone + shared attention/MLP blocks cycled in
  vlm / audio    -> same stacks with an embeddings input stub
                    (musicgen adds parallel codebook heads)

Params for homogeneous layer runs are *stacked* (leading L dim) so the
full-depth program lowers through one ``lax.scan`` body (fast compile);
``unroll=True`` traces a python loop instead (exact HLO cost accounting —
used by the dry-run's L=1/L=2 extrapolation lowers and the smoke tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from . import attention as attn
from . import mamba2 as ssd
from . import mla as mla_mod
from . import moe as moe_mod
from .layers import (embedding_init, embedding_lookup, ffn_apply, ffn_init,
                     lm_head_apply, lm_head_init, rmsnorm, rmsnorm_init,
                     softmax_cross_entropy, unembed)


# ------------------------------------------------------------------ blocks
def _block_init(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: Dict[str, Any] = {"norm1": rmsnorm_init(D, dtype)}
    if kind == "ssm":
        p["ssm"] = ssd.mamba2_init(ks[0], D, cfg.ssm, dtype)
        return p
    if cfg.mla is not None:
        p["mla"] = mla_mod.mla_init(ks[0], D, cfg.n_heads, cfg.mla, dtype)
    else:
        p["attn"] = attn.attention_init(ks[0], D, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.resolved_head_dim, dtype)
    p["norm2"] = rmsnorm_init(D, dtype)
    if kind == "attn_moe":
        p["moe"] = moe_mod.moe_init(ks[1], D, cfg.moe, cfg.glu, dtype)
    else:
        p["mlp"] = ffn_init(ks[1], D, cfg.d_ff, cfg.glu, dtype)
    return p


def _shared_block_init(key, cfg: ModelConfig, dtype) -> dict:
    """Zamba2 shared attention+MLP block."""
    h = cfg.hybrid
    ks = jax.random.split(key, 3)
    dh = cfg.d_model // h.shared_n_heads
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attention_init(ks[0], cfg.d_model, h.shared_n_heads,
                                    h.shared_n_kv_heads, dh, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "shared": ffn_init(ks[1], cfg.d_model, h.shared_d_ff, cfg.glu, dtype),
    }


def _block_apply(params, cfg: ModelConfig, kind: str, x, positions):
    # Sequence-parallel residual stream under "opt" rules (S over model);
    # no-op under baseline rules or when S doesn't divide.
    x = shard(x, "batch", "act_seq", None)
    if kind == "ssm":
        x = x + ssd.mamba2_apply(params["ssm"], rmsnorm(params["norm1"], x,
                                                        cfg.norm_eps),
                                 cfg.ssm)
        return shard(x, "batch", "act_seq", None)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a = mla_mod.mla_apply(params["mla"], h, positions,
                              n_heads=cfg.n_heads, mla=cfg.mla)
    else:
        a = attn.attention_apply(params["attn"], h, positions,
                                 n_heads=cfg.n_heads,
                                 n_kv_heads=cfg.n_kv_heads,
                                 head_dim=cfg.resolved_head_dim,
                                 rope_theta=cfg.rope_theta,
                                 rope_fraction=cfg.rope_fraction)
    x = shard(x + a, "batch", "act_seq", None)
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        f = moe_mod.moe_apply(params["moe"], h, cfg.moe, cfg.act, cfg.glu)
    else:
        f = ffn_apply(params["mlp"], h, cfg.act, cfg.glu)
    return shard(x + f, "batch", "act_seq", None)


def _shared_block_apply(params, cfg: ModelConfig, x, positions):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    hcfg = cfg.hybrid
    a = attn.attention_apply(params["attn"], h, positions,
                             n_heads=hcfg.shared_n_heads,
                             n_kv_heads=hcfg.shared_n_kv_heads,
                             head_dim=cfg.d_model // hcfg.shared_n_heads,
                             rope_theta=cfg.rope_theta)
    x = shard(x + a, "batch", "act_seq", None)
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    return shard(x + ffn_apply(params["shared"], h, cfg.act, cfg.glu),
                 "batch", "act_seq", None)


# ------------------------------------------------------------------ stacks
def _stack_init(key, cfg: ModelConfig, kind: str, n: int, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, cfg, kind, dtype))(keys)


def _layer_plan(cfg: ModelConfig) -> Tuple[int, str, int, str]:
    """(prefix_n, prefix_kind, main_n, main_kind)."""
    if cfg.family in ("ssm", "hybrid"):
        return 0, "", cfg.n_layers, "ssm"
    if cfg.moe is not None:
        p = cfg.moe.first_dense_layers
        return p, "attn", cfg.n_layers - p, "attn_moe"
    return 0, "", cfg.n_layers, "attn"


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    prefix_n, prefix_kind, main_n, main_kind = _layer_plan(cfg)
    params: Dict[str, Any] = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if prefix_n:
        params["prefix"] = _stack_init(ks[1], cfg, prefix_kind, prefix_n, dtype)
    params["stack"] = _stack_init(ks[2], cfg, main_kind, main_n, dtype)
    if cfg.hybrid is not None:
        skeys = jax.random.split(ks[3], cfg.hybrid.n_shared_blocks)
        params["shared_blocks"] = [
            _shared_block_init(k, cfg, dtype) for k in skeys]
    if not cfg.tie_embeddings:
        out_dim = cfg.vocab_size * cfg.n_codebooks
        params["lm_head"] = lm_head_init(ks[4], cfg.d_model, out_dim, dtype)
    return params


def _tree_index(tree, i):
    return jax.tree.map(lambda w: w[i], tree)


def _run_stack(stack_params, cfg: ModelConfig, kind: str, x, positions,
               n: int, unroll: bool, remat: bool):
    body = _block_apply
    if remat:
        body = jax.checkpoint(
            functools.partial(_block_apply, cfg=cfg, kind=kind),
            static_argnums=())
        def call(p, xx):
            return body(p, x=xx, positions=positions)
    else:
        def call(p, xx):
            return _block_apply(p, cfg, kind, xx, positions)
    if unroll:
        for i in range(n):
            x = call(_tree_index(stack_params, i), x)
        return x

    def scan_body(xx, p):
        return call(p, xx), ()

    x, _ = jax.lax.scan(scan_body, x, stack_params)
    return x


def _hybrid_run(params, cfg: ModelConfig, x, positions, unroll: bool,
                remat: bool):
    """SSD backbone with shared attn blocks every ``attn_period`` layers."""
    h = cfg.hybrid
    L = cfg.n_layers
    period = h.attn_period
    stack = params["stack"]
    i = 0
    seg = 0
    while i < L:
        n = min(period, L - i)
        seg_params = jax.tree.map(lambda w: w[i:i + n], stack)
        x = _run_stack(seg_params, cfg, "ssm", x, positions, n, unroll, remat)
        i += n
        if i < L or n == period:
            blk = params["shared_blocks"][seg % h.n_shared_blocks]
            x = _shared_block_apply(blk, cfg, x, positions)
            seg += 1
    return x


def _inputs_to_h(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    if cfg.input_mode == "embeddings":
        x = batch["embeddings"].astype(params["embed"]["table"].dtype)
        return shard(x, "batch", None, None)
    tokens = batch["tokens"]
    if cfg.n_codebooks > 1 and tokens.ndim == 3:
        x = sum(embedding_lookup(params["embed"], tokens[..., c])
                for c in range(cfg.n_codebooks))
        return x
    return embedding_lookup(params["embed"], tokens)


def _logits(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return unembed(params["embed"], x, cfg.logit_softcap)
    logits = lm_head_apply(params["lm_head"], x, cfg.logit_softcap)
    if cfg.n_codebooks > 1:
        B, S, _ = logits.shape
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab_size)
    return logits


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            unroll: bool = False, remat: bool = False) -> jnp.ndarray:
    """Full-sequence forward -> logits (B,S,V[,K])."""
    x = _inputs_to_h(params, cfg, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    prefix_n, prefix_kind, main_n, main_kind = _layer_plan(cfg)
    if prefix_n:
        x = _run_stack(params["prefix"], cfg, prefix_kind, x, positions,
                       prefix_n, True, remat)
    if cfg.family == "hybrid":
        x = _hybrid_run(params, cfg, x, positions, unroll, remat)
    else:
        x = _run_stack(params["stack"], cfg, main_kind, x, positions,
                       main_n, unroll, remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x)


def loss(params, cfg: ModelConfig, batch, unroll: bool = False,
         remat: bool = True) -> jnp.ndarray:
    logits = forward(params, cfg, batch, unroll=unroll, remat=remat)
    labels = batch["labels"]
    if cfg.n_codebooks > 1:
        total = 0.0
        for c in range(cfg.n_codebooks):
            total = total + softmax_cross_entropy(logits[..., c, :],
                                                  labels[..., c])
        return total / cfg.n_codebooks
    return softmax_cross_entropy(logits, labels)


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree (pure shapes; safe under eval_shape)."""
    prefix_n, prefix_kind, main_n, main_kind = _layer_plan(cfg)
    D = cfg.d_model

    def attn_cache(n_layers, kv_heads, head_dim):
        shape = (n_layers, batch, max_len, kv_heads, head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    cache: Dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        one = ssd.mamba2_decode_init_cache(batch, D, cfg.ssm, dtype)
        cache["stack"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (main_n, *t.shape)).copy(), one)
        if cfg.hybrid is not None:
            h = cfg.hybrid
            n_inv = -(-cfg.n_layers // h.attn_period)
            dh = D // h.shared_n_heads
            cache["shared"] = attn_cache(n_inv, h.shared_n_kv_heads, dh)
        return cache
    if cfg.mla is not None:
        m = cfg.mla
        cache["stack"] = {
            "c": jnp.zeros((main_n, batch, max_len, m.kv_lora_rank), dtype),
            "rope": jnp.zeros((main_n, batch, max_len, m.qk_rope_head_dim),
                              dtype),
        }
        if prefix_n:
            cache["prefix"] = {
                "c": jnp.zeros((prefix_n, batch, max_len, m.kv_lora_rank),
                               dtype),
                "rope": jnp.zeros((prefix_n, batch, max_len,
                                   m.qk_rope_head_dim), dtype),
            }
        return cache
    cache["stack"] = attn_cache(main_n, cfg.n_kv_heads, cfg.resolved_head_dim)
    return cache


def _decode_block(params, cfg: ModelConfig, kind: str, x, layer_cache, pos):
    if kind == "ssm":
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        out, new_cache = ssd.mamba2_decode_apply(params["ssm"], h, layer_cache,
                                                 cfg.ssm)
        return x + out, new_cache
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, c, r = mla_mod.mla_decode_apply(params["mla"], h, layer_cache["c"],
                                           layer_cache["rope"], pos,
                                           n_heads=cfg.n_heads, mla=cfg.mla)
        new_cache = {"c": c, "rope": r}
    else:
        a, k, v = attn.decode_attention_apply(
            params["attn"], h, layer_cache["k"], layer_cache["v"], pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            rope_fraction=cfg.rope_fraction)
        new_cache = {"k": k, "v": v}
    x = x + a
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        f = moe_mod.moe_apply(params["moe"], h, cfg.moe, cfg.act, cfg.glu,
                              n_groups=1)
    else:
        f = ffn_apply(params["mlp"], h, cfg.act, cfg.glu)
    return x + f, new_cache


def _decode_shared_block(params, cfg: ModelConfig, x, kcache, vcache, pos):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    hcfg = cfg.hybrid
    a, k, v = attn.decode_attention_apply(
        params["attn"], h, kcache, vcache, pos,
        n_heads=hcfg.shared_n_heads, n_kv_heads=hcfg.shared_n_kv_heads,
        head_dim=cfg.d_model // hcfg.shared_n_heads,
        rope_theta=cfg.rope_theta)
    x = shard(x + a, "batch", "act_seq", None)
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    return shard(x + ffn_apply(params["shared"], h, cfg.act, cfg.glu),
                 "batch", "act_seq", None), k, v


def decode_step(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                cache: dict, pos, unroll: bool = False
                ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode.  batch: tokens (B,1)[,K] or embeddings (B,1,D).
    ``pos`` = current cache length (index of the new token)."""
    x = _inputs_to_h(params, cfg, batch)
    prefix_n, prefix_kind, main_n, main_kind = _layer_plan(cfg)
    new_cache: Dict[str, Any] = {}

    if prefix_n:
        pcaches = []
        for i in range(prefix_n):
            x, nc = _decode_block(_tree_index(params["prefix"], i), cfg,
                                  prefix_kind, x,
                                  _tree_index(cache["prefix"], i), pos)
            pcaches.append(nc)
        new_cache["prefix"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *pcaches)

    if cfg.family == "hybrid":
        h = cfg.hybrid
        L, period = cfg.n_layers, h.attn_period
        scaches, kso, vso = [], [], []
        i = seg = 0
        while i < L:
            n = min(period, L - i)
            for j in range(i, i + n):
                x, nc = _decode_block(_tree_index(params["stack"], j), cfg,
                                      "ssm", x,
                                      _tree_index(cache["stack"], j), pos)
                scaches.append(nc)
            i += n
            if i < L or n == period:
                blk = params["shared_blocks"][seg % h.n_shared_blocks]
                x, k, v = _decode_shared_block(
                    blk, cfg, x, cache["shared"]["k"][seg],
                    cache["shared"]["v"][seg], pos)
                kso.append(k)
                vso.append(v)
                seg += 1
        new_cache["stack"] = jax.tree.map(lambda *xs: jnp.stack(xs), *scaches)
        new_cache["shared"] = {"k": jnp.stack(kso), "v": jnp.stack(vso)}
    elif unroll:
        caches = []
        for i in range(main_n):
            x, nc = _decode_block(_tree_index(params["stack"], i), cfg,
                                  main_kind, x,
                                  _tree_index(cache["stack"], i), pos)
            caches.append(nc)
        new_cache["stack"] = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        def body(xx, inputs):
            p, c = inputs
            xx, nc = _decode_block(p, cfg, main_kind, xx, c, pos)
            return xx, nc

        x, stack_cache = jax.lax.scan(body, x,
                                      (params["stack"], cache["stack"]))
        new_cache["stack"] = stack_cache

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), new_cache
