"""GQA/MQA attention: dense, blockwise-flash, and single-token decode paths.

Path selection:
  * ``dense``  — full-matrix attention; exact HLO flops; used for short
    sequences (train_4k) and smoke tests.
  * ``flash``  — online-softmax over KV blocks via ``lax.scan``; used to
    lower long-context prefill with flash-like memory behaviour.  NOTE:
    XLA:CPU ``cost_analysis`` counts scan bodies once, so cells lowering
    this path get their flops corrected analytically (see
    ``distributed.costs``); the Pallas kernel in ``kernels/flash_attention``
    is the TPU execution path and is validated against ``ref.py``.
  * ``decode`` — one new token against a KV cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard, tp_row_matmul
from .layers import _init_dense, apply_rope

NEG_INF = -1e30


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": _init_dense(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": _init_dense(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": _init_dense(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": _init_dense(ks[3], n_heads * head_dim, d_model, dtype),
    }


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim, positions,
                 rope_theta, rope_fraction):
    B, S, _ = x.shape
    wq = shard(params["wq"], None, "heads")       # gather fsdp dim on use
    wk = shard(params["wk"], None, "kv_heads")
    wv = shard(params["wv"], None, "kv_heads")
    q = (x @ wq).reshape(B, S, n_heads, head_dim)
    k = (x @ wk).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ wv).reshape(B, S, n_kv_heads, head_dim)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta, rope_fraction)
        k = apply_rope(k, positions, rope_theta, rope_fraction)
    return q, k, v


def _group_heads(q, n_kv_heads):
    """(B,S,H,dh) -> (B,S,KV,G,dh) splitting query heads into KV groups."""
    B, S, H, dh = q.shape
    return q.reshape(B, S, n_kv_heads, H // n_kv_heads, dh)


def dense_attention(q, k, v, causal: bool = True,
                    q_offset: int = 0) -> jnp.ndarray:
    """Full-matrix grouped attention.  q (B,S,KV,G,dh), k/v (B,T,KV,dh)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    if causal:
        S, T = scores.shape[-2], scores.shape[-1]
        qpos = jnp.arange(S)[:, None] + q_offset
        mask = qpos >= jnp.arange(T)[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", w, v)


def flash_attention_scan(q, k, v, block_k: int = 1024,
                         causal: bool = True) -> jnp.ndarray:
    """Online-softmax over KV blocks (lax.scan).  q (B,S,KV,G,dh)."""
    B, S, KV, G, dh = q.shape
    dv = v.shape[-1]                      # may differ from dh (MLA)
    T = k.shape[1]
    scale = dh ** -0.5
    nblk = -(-T // block_k)
    pad = nblk * block_k - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_k, KV, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_k, KV, dv).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S)[:, None]

    def body(carry, inputs):
        m, l, acc = carry
        kblk, vblk, start = inputs
        s = jnp.einsum("bskgd,btkd->bkgst", q, kblk).astype(jnp.float32) * scale
        kpos = start + jnp.arange(block_k)[None, :]
        valid = kpos < T
        if causal:
            valid = valid & (qpos >= kpos)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(q.dtype), vblk)
        acc_new = acc * alpha[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    acc0 = jnp.zeros((B, KV, G, S, dv), jnp.float32)
    starts = jnp.arange(nblk) * block_k
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)        # (B,S,KV,G,dh)


def attention_apply(params, x, positions, *, n_heads, n_kv_heads, head_dim,
                    rope_theta=10_000.0, rope_fraction=1.0, causal=True,
                    dense_threshold: int = 2048) -> jnp.ndarray:
    """Self-attention for train/prefill.  x (B,S,D)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                           positions, rope_theta, rope_fraction)
    qg = _group_heads(q, n_kv_heads)
    if S <= dense_threshold:
        out = dense_attention(qg, k, v, causal=causal)
    else:
        out = flash_attention_scan(qg, k, v, causal=causal)
    out = out.reshape(B, S, n_heads * head_dim)
    out = shard(tp_row_matmul(out, shard(params["wo"], "heads", None)),
                "batch", "act_seq", None)
    return out


def decode_attention_apply(params, x, cache_k, cache_v, pos, *, n_heads,
                           n_kv_heads, head_dim, rope_theta=10_000.0,
                           rope_fraction=1.0) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  x (B,1,D); cache_k/v (B,Smax,KV,dh); pos scalar
    current length.  Returns (out (B,1,D), new_k, new_v)."""
    B, _, D = x.shape
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim,
                           positions, rope_theta, rope_fraction)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    qg = _group_heads(q, n_kv_heads)                 # (B,1,KV,G,dh)
    scale = head_dim ** -0.5
    s = jnp.einsum("bskgd,btkd->bkgst", qg,
                   cache_k.astype(qg.dtype)).astype(jnp.float32) * scale
    tpos = jnp.arange(cache_k.shape[1])[None, :]
    s = jnp.where((tpos <= pos)[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(qg.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cache_v.astype(qg.dtype))
    out = out.reshape(B, 1, n_heads * head_dim) @ shard(params["wo"],
                                                        "heads", None)
    return shard(out, "batch", None, None), cache_k, cache_v
