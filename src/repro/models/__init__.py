from . import attention, layers, mamba2, mla, moe
from .transformer import decode_step, forward, init_cache, init_params, loss
