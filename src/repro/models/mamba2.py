"""Mamba2 block via SSD (state-space duality), TPU-adapted.

The chunked algorithm is fully *vectorized*: intra-chunk terms are batched
einsums over (batch, n_chunks, chunk, heads, ...) and the inter-chunk state
recurrence uses ``jax.lax.associative_scan`` (log-depth combines — every
flop visible to HLO cost analysis, MXU-friendly shapes).  A Pallas kernel
for the chunk core lives in ``kernels/ssd``.

Decode is the O(1)-per-token recurrent update on the (H, P, N) state, which
is what makes the 500k-context cells runnable for ssm/hybrid archs.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SSMConfig
from ..distributed.sharding import shard
from .layers import _init_dense, rmsnorm


def mamba2_init(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_in = cfg.expand * d_model
    n_heads = d_in // cfg.head_dim
    gn = cfg.n_groups * cfg.d_state
    conv_ch = d_in + 2 * gn
    ks = jax.random.split(key, 8)
    return {
        "w_z": _init_dense(ks[0], d_model, d_in, dtype),
        "w_x": _init_dense(ks[1], d_model, d_in, dtype),
        "w_B": _init_dense(ks[2], d_model, gn, dtype),
        "w_C": _init_dense(ks[3], d_model, gn, dtype),
        "w_dt": _init_dense(ks[4], d_model, n_heads, dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "conv": (jax.random.normal(ks[5], (cfg.conv_width, conv_ch),
                                   jnp.float32) / cfg.conv_width).astype(dtype),
        "norm": {"scale": jnp.ones((d_in,), dtype)},
        "out_proj": _init_dense(ks[6], d_in, d_model, dtype),
    }


def _causal_conv(x, w):
    """Depthwise causal conv via explicit shifts.  x (B,S,C), w (W,C)."""
    W = w.shape[0]
    out = x * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out


def _ssd_chunked(xh, dt, dA, B_, C_, chunk: int):
    """Chunked SSD core.

    xh (B,S,H,P)  inputs per head
    dt (B,S,H)    softplus step sizes
    dA (B,S,H)    dt * A  (negative)
    B_ (B,S,G,N)  input projections  (G groups broadcast over H)
    C_ (B,S,G,N)  output projections
    -> y (B,S,H,P)
    """
    B, S, H, P = xh.shape
    G, N = B_.shape[-2], B_.shape[-1]
    nc = S // chunk
    rep = H // G

    def chunks(t, extra=()):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:])

    xc = chunks(xh)                              # (B,nc,Q,H,P)
    dtc = chunks(dt)                             # (B,nc,Q,H)
    dAc = chunks(dA)                             # (B,nc,Q,H)
    Bc = jnp.repeat(chunks(B_), rep, axis=-2)    # (B,nc,Q,H,N)
    Cc = jnp.repeat(chunks(C_), rep, axis=-2)

    # Cumulative within-chunk log decay.
    l = jnp.cumsum(dAc, axis=2)                  # (B,nc,Q,H)
    l_last = l[:, :, -1]                         # (B,nc,H)

    # --- intra-chunk (quadratic in chunk length, attention-like)
    # decay(i,j) = exp(l_i - l_j) for i >= j
    diff = l[:, :, :, None, :] - l[:, :, None, :, :]        # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)        # (B,nc,Qi,Qj,H)
    w = scores * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(xc.dtype), xc)

    # --- chunk summary states: S_c = sum_j exp(l_last - l_j) dt_j B_j x_j^T
    sdec = jnp.exp(l_last[:, :, None] - l)                   # (B,nc,Q,H)
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp",
                        (sdec * dtc).astype(xc.dtype), Bc, xc)

    # --- inter-chunk recurrence via associative scan over chunks:
    #     H_c = exp(l_last_c) * H_{c-1} + S_c
    a = jnp.exp(l_last).astype(jnp.float32)                  # (B,nc,H)
    s = states.astype(jnp.float32)

    def combine(x1, x2):
        a1, s1 = x1
        a2, s2 = x2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_acc, h_acc = jax.lax.associative_scan(combine, (a, s), axis=1)
    # State *entering* chunk c is h_acc[c-1]; chunk 0 enters with zeros.
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_acc[:, :1]), h_acc[:, :-1]], axis=1)

    # --- inter-chunk contribution: y_i += C_i . (exp(l_i) * H_prev)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         Cc.astype(jnp.float32),
                         h_prev) * jnp.exp(l)[..., None]
    y = y_intra.astype(jnp.float32) + y_inter
    return y.reshape(B, S, H, P)


def mamba2_apply(params, u, cfg: SSMConfig) -> jnp.ndarray:
    """Full-sequence SSD block.  u (B,S,D) -> (B,S,D)."""
    B, S, D = u.shape
    d_in = cfg.expand * D
    H = d_in // cfg.head_dim
    gn = cfg.n_groups * cfg.d_state
    z = u @ shard(params["w_z"], None, "heads")
    xBC = jnp.concatenate(
        [u @ shard(params["w_x"], None, "heads"),
         u @ shard(params["w_B"], None, None),
         u @ shard(params["w_C"], None, None)], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv"]))
    x = shard(xBC[..., :d_in], "batch", None, "heads")
    B_ = xBC[..., d_in: d_in + gn].reshape(B, S, cfg.n_groups, cfg.d_state)
    C_ = xBC[..., d_in + gn:].reshape(B, S, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(
        (u @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"])                            # (H,) negative
    dA = dt * A
    xh = x.reshape(B, S, H, cfg.head_dim)
    # Pad the sequence to a chunk multiple (appended steps are causal-safe).
    pad = (-S) % cfg.chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = _ssd_chunked(xh, dt, dA, B_, C_, cfg.chunk)[:, :S]
    xh = xh[:, :S]
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y)
    return shard(y @ shard(params["out_proj"], "heads", None),
                 "batch", "act_seq", None)


def mamba2_decode_init_cache(batch: int, d_model: int, cfg: SSMConfig, dtype):
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    gn = cfg.n_groups * cfg.d_state
    return {
        "state": jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * gn), dtype),
    }


def mamba2_decode_apply(params, u, cache, cfg: SSMConfig
                        ) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrent update.  u (B,1,D)."""
    B, _, D = u.shape
    d_in = cfg.expand * D
    H = d_in // cfg.head_dim
    gn = cfg.n_groups * cfg.d_state
    z = u @ params["w_z"]
    xBC_t = jnp.concatenate(
        [u @ params["w_x"], u @ params["w_B"], u @ params["w_C"]], axis=-1)
    window = jnp.concatenate([cache["conv"], xBC_t], axis=1)  # (B,W,C)
    conv_out = (window * params["conv"][None]).sum(axis=1, keepdims=True)
    xBC = jax.nn.silu(conv_out)
    x = xBC[..., :d_in].reshape(B, H, cfg.head_dim)
    B_ = xBC[..., d_in: d_in + gn].reshape(B, cfg.n_groups, cfg.d_state)
    C_ = xBC[..., d_in + gn:].reshape(B, cfg.n_groups, cfg.d_state)
    rep = H // cfg.n_groups
    Bh = jnp.repeat(B_, rep, axis=1)                          # (B,H,N)
    Ch = jnp.repeat(C_, rep, axis=1)
    dt = jax.nn.softplus(
        (u[:, 0] @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                       # (B,H)
    state = cache["state"] * a[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bh.astype(jnp.float32), x.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + params["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(u.dtype) * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y)
    out = shard(y @ params["out_proj"], "batch", None, None)
    new_cache = {"state": state, "conv": window[:, 1:]}
    return out, new_cache
