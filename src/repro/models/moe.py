"""Mixture-of-Experts with explicit expert parallelism (shard_map).

Design (TPU-native, GSPMD-scatter-free):
  * activations are batch-sharded over (pod, data) and *replicated* over
    the ``model`` axis — so each model shard can locally build the dispatch
    buffer for its own E/16 experts with plain sort/scatter (device-local,
    no partitioning ambiguity);
  * expert weights are sharded (experts -> model, d_model -> data);
    the d_model contraction runs on local D-slices and finishes with a
    ``psum`` over "data" (cheaper than fsdp-gathering the weights);
  * per-token outputs are combined with a ``psum`` over "model" (each
    token's top-k experts live on <= k model shards).

Wire cost per layer ~= psum(E_loc,C,F_e) over data + psum(T_loc,D) over
model — the collective schedule the roofline sees and §Perf iterates on.

Without an active mesh (smoke tests) the same math runs single-device.
Over-capacity tokens are dropped (capacity-factor semantics); shared
experts run densely on every token.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.compat import shard_map

from ..configs.base import MoEConfig
from ..distributed.sharding import current_rules, shard
from .layers import _act, _init_dense, ffn_apply, ffn_init


def moe_init(key, d_model: int, cfg: MoEConfig, glu: bool, dtype) -> dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.n_routed, cfg.d_expert
    p = {
        "router": _init_dense(ks[0], d_model, E, jnp.float32),
        "w_up": _stack_init(ks[1], E, d_model, F, dtype),
        "w_down": _stack_init(ks[2], E, F, d_model, dtype),
    }
    if glu:
        p["w_gate"] = _stack_init(ks[3], E, d_model, F, dtype)
    if cfg.n_shared:
        shared_f = cfg.d_shared_expert or cfg.d_expert * cfg.n_shared
        p["shared"] = ffn_init(ks[4], d_model, shared_f, glu, dtype)
    return p


def _stack_init(key, e: int, d_in: int, d_out: int, dtype):
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def _positions_in_expert(idx: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """idx (T, k) -> position of each choice within its expert (T, k),
    by stable sort + run ranking (no (T,E,C) one-hot blow-up)."""
    T, K = idx.shape
    flat = idx.reshape(T * K)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_sorted = jnp.arange(T * K) - starts[sorted_e]
    inv = jnp.argsort(order)
    return pos_sorted[inv].reshape(T, K).astype(jnp.int32)


def route(router_w, x: jnp.ndarray, cfg: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (T,D) -> (gates (T,k) fp32, experts (T,k) int32)."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)


def _expert_ffn_local(buf, w_gate, w_up, w_down, act: str, glu: bool,
                      data_axis: Optional[str]):
    """buf (E_loc, C, D); expert weights arrive d_model-sharded over the
    data axis (ZeRO-3 storage) and are all-gathered for use — tokens
    differ across data shards, so the contraction itself must be local."""
    if data_axis is not None:
        w_up = jax.lax.all_gather(w_up, data_axis, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, data_axis, axis=2, tiled=True)
        if glu:
            w_gate = jax.lax.all_gather(w_gate, data_axis, axis=1, tiled=True)
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    if glu:
        gate = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        h = _act(act)(gate) * up
    else:
        h = _act(act)(up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_local(x, router_w, w_gate, w_up, w_down, cfg: MoEConfig, act: str,
               glu: bool, capacity: int, e_first, n_local: int,
               model_axis: Optional[str], data_axis: Optional[str]):
    """Per-shard MoE body.  x (T_loc, D) replicated over model axis."""
    T, D = x.shape
    gates, idx = route(router_w, x, cfg)                   # (T,k)
    pos = _positions_in_expert(idx, cfg.n_routed)
    keep = pos < capacity
    mine = keep & (idx >= e_first) & (idx < e_first + n_local)
    local_e = jnp.clip(idx - e_first, 0, n_local - 1)
    # Scatter my tokens into (E_loc, C, D); non-mine rows target C (dropped).
    pos_c = jnp.where(mine, pos, capacity)
    buf = jnp.zeros((n_local, capacity, D), x.dtype)
    buf = buf.at[local_e, pos_c].add(
        x[:, None, :] * mine[..., None].astype(x.dtype), mode="drop")
    out_buf = _expert_ffn_local(buf, w_gate, w_up, w_down, act, glu,
                                data_axis)
    y = out_buf.at[local_e, pos_c].get(mode="fill", fill_value=0)
    y = (y * (gates[..., None] * mine[..., None]).astype(y.dtype)).sum(axis=1)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y


SMALL_T_THRESHOLD = 4096     # decode/small-batch: replicate tokens, not weights


def _moe_small_t(params, x, cfg: MoEConfig, act: str, glu: bool, rules):
    """Decode-path MoE: tokens are tiny (B tokens of D), so replicating
    them (~MBs) and keeping expert weights sharded-in-place beats fsdp
    weight gathers (~GBs/layer) by ~3 orders of magnitude.

    Expert placement follows the rules' "experts" mapping: over "model"
    (training rules; d_model fsdp slices finished with a psum over "data",
    valid because every data shard sees the SAME tokens here) or over
    ("model","data") (serve rules; e.g. one DeepSeek-V3 expert per chip,
    weights fully resident, zero per-layer weight traffic)."""
    mesh = rules.mesh
    B, S, D = x.shape
    T = B * S
    e_axes = rules.resolve("experts", cfg.n_routed)
    e_axes = (e_axes,) if isinstance(e_axes, str) else tuple(e_axes or ())
    if not e_axes:
        e_axes = ("model",)
    n_shards = 1
    for a in e_axes:
        n_shards *= mesh.shape[a]
    n_local = cfg.n_routed // n_shards
    C = max(int(math.ceil(T * cfg.top_k / cfg.n_routed
                          * cfg.capacity_factor)), cfg.top_k)
    d_axes = rules.resolve("fsdp", D)
    has_data = d_axes is not None and "data" not in e_axes
    w_gate = params.get("w_gate")

    def body(x_rep, router_w, wg, wu, wd):
        xt = x_rep.reshape(T, D)
        gates, idx = route(router_w, xt, cfg)
        pos = _positions_in_expert(idx, cfg.n_routed)
        keep = pos < C
        shard_idx = 0
        for a in e_axes:
            shard_idx = shard_idx * mesh.shape[a] + jax.lax.axis_index(a)
        e_first = shard_idx * n_local
        mine = keep & (idx >= e_first) & (idx < e_first + n_local)
        local_e = jnp.clip(idx - e_first, 0, n_local - 1)
        pos_c = jnp.where(mine, pos, C)
        buf = jnp.zeros((n_local, C, D), xt.dtype)
        buf = buf.at[local_e, pos_c].add(
            xt[:, None, :] * mine[..., None].astype(xt.dtype), mode="drop")
        if has_data:
            d_loc = wu.shape[1]
            d_lo = jax.lax.axis_index("data") * d_loc
            buf_d = jax.lax.dynamic_slice_in_dim(buf, d_lo, d_loc, axis=2)
            up = jnp.einsum("ecd,edf->ecf", buf_d, wu)
            if glu:
                gate = jnp.einsum("ecd,edf->ecf", buf_d, wg)
                up, gate = jax.lax.psum((up, gate), "data")
                h = _act(act)(gate) * up
            else:
                h = _act(act)(jax.lax.psum(up, "data"))
            out_part = jnp.einsum("ecf,efd->ecd", h, wd)   # local D slice
            out_buf = jax.lax.all_gather(out_part, "data", axis=2, tiled=True)
        else:
            out_buf = _expert_ffn_local(buf, wg, wu, wd, act, glu, None)
        y = out_buf.at[local_e, pos_c].get(mode="fill", fill_value=0)
        y = (y * (gates[..., None] * mine[..., None]).astype(y.dtype)
             ).sum(axis=1)
        y = jax.lax.psum(y, e_axes)
        return y.reshape(B, S, D)

    d_spec = "data" if has_data else None
    wspec = P(e_axes if len(e_axes) > 1 else e_axes[0], d_spec, None)
    wdspec = P(e_axes if len(e_axes) > 1 else e_axes[0], None, d_spec)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None), P(None, None), wspec, wspec, wdspec),
        out_specs=P(None, None, None),
        check_vma=False,
    )(x, params["router"], w_gate if glu else params["w_up"],
      params["w_up"], params["w_down"])


def moe_apply(params, x: jnp.ndarray, cfg: MoEConfig, act: str, glu: bool,
              n_groups: Optional[int] = None) -> jnp.ndarray:
    """x (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    rules = current_rules()
    mesh = rules.mesh if rules is not None else None
    w_gate = params.get("w_gate")

    if mesh is not None and "model" in mesh.shape \
            and cfg.n_routed % mesh.shape["model"] == 0 \
            and B * S <= SMALL_T_THRESHOLD:
        y = _moe_small_t(params, x, cfg, act, glu, rules)
        if cfg.n_shared:
            y = y + ffn_apply(params["shared"], x, act, glu)
        return shard(y, "batch", None, None)

    if mesh is not None and "model" in mesh.shape \
            and cfg.n_routed % mesh.shape["model"] == 0:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n_batch = 1
        for a in batch_axes:
            n_batch *= mesh.shape[a]
        T_total = B * S
        if T_total % max(n_batch, 1) == 0 and n_batch > 1:
            T_loc = T_total // n_batch
            C = max(int(math.ceil(T_loc * cfg.top_k / cfg.n_routed
                                  * cfg.capacity_factor)), cfg.top_k)
            n_model = mesh.shape["model"]
            n_local = cfg.n_routed // n_model
            d_shard = "data" if (
                "data" in mesh.shape and D % mesh.shape["data"] == 0) else None

            def body(xl, router_w, wg, wu, wd):
                e_first = jax.lax.axis_index("model") * n_local
                return _moe_local(
                    xl.reshape(-1, D), router_w, wg, wu, wd, cfg, act, glu,
                    C, e_first, n_local, "model", d_shard,
                ).reshape(xl.shape)

            wspec = P("model", d_shard, None)
            wdspec = P("model", None, d_shard)
            y = shard_map(
                body, mesh=mesh,
                in_specs=(P(batch_axes, None, None), P(None, None),
                          wspec, wspec, wdspec),
                out_specs=P(batch_axes, None, None),
                check_vma=False,
            )(x, params["router"],
              w_gate if glu else params["w_up"],   # placeholder slot if no glu
              params["w_up"], params["w_down"])
            y = shard(y, "batch", "act_seq", None)
        else:
            y = _moe_local(x.reshape(-1, D), params["router"], w_gate,
                           params["w_up"], params["w_down"], cfg, act, glu,
                           _default_capacity(B * S, cfg), 0, cfg.n_routed,
                           None, None).reshape(B, S, D)
    else:
        y = _moe_local(x.reshape(-1, D), params["router"], w_gate,
                       params["w_up"], params["w_down"], cfg, act, glu,
                       _default_capacity(B * S, cfg), 0, cfg.n_routed,
                       None, None).reshape(B, S, D)

    if cfg.n_shared:
        y = y + ffn_apply(params["shared"], x, act, glu)
    return y


def _default_capacity(T: int, cfg: MoEConfig) -> int:
    return max(int(math.ceil(T * cfg.top_k / cfg.n_routed
                             * cfg.capacity_factor)), cfg.top_k)


def load_balance_loss(router_w, x_flat, cfg: MoEConfig) -> jnp.ndarray:
    """Auxiliary load-balancing loss (Switch-style f*P)."""
    logits = (x_flat.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.top_k)
    onehot = jax.nn.one_hot(idx, cfg.n_routed).sum(-2)
    f = onehot.mean(axis=tuple(range(onehot.ndim - 1)))
    p = probs.mean(axis=tuple(range(probs.ndim - 1)))
    return cfg.n_routed * jnp.sum(f * p)
