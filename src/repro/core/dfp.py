"""Direct Future Prediction network (Dosovitskiy & Koltun '17) as adapted by
MRSch (paper §II-B, §III, §IV-C).

Three input modules:
  * state module   — MLP  state_dim -> 4000 -> 1000 -> 512 (leaky rectifier);
                     a CNN variant is kept for the Fig. 3 ablation.
  * measurement    — 3 fully-connected layers of 128 units.
  * goal           — 3 fully-connected layers of 128 units.

The joint representation (concat, 768) feeds two parallel streams (dueling,
Wang et al.):
  * expectation stream E(j)            -> (T*M,)
  * action stream      A(j)            -> (A, T*M), normalized to zero mean
                                          across actions.
Prediction for action a:  p_a = E + (A_a - mean_a A)   reshaped (T, M) —
the predicted *change* of each measurement at each temporal offset.

Action scoring:  u(a) = sum_tau w_tau * sum_m g_m * p_a[tau, m]
with fixed temporal weights w (DFP default (0,0,0,0.5,0.5,1)) and the
dynamic goal vector g from Eq. (1).

Training target for the taken action: f[tau, m] = m_{t+tau} - m_t (clamped
to episode end), loss = MSE over the taken action's prediction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.backend import mlp_forward, resolve_backend
from ..nn.modules import (conv1d_apply, conv1d_init, dense_apply, dense_init,
                          leaky_relu, mlp_init)
from ..nn.queue_encoder import (QueueEncoderConfig, queue_encoder_init,
                                queue_state_features)

STATE_MODULES = ("mlp", "cnn", "attention")


@dataclass(frozen=True)
class DFPConfig:
    state_dim: int
    n_measurements: int                       # M (one per resource)
    n_actions: int                            # A = window size W
    offsets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    temporal_weights: Tuple[float, ...] = (0.0, 0.0, 0.0, 0.5, 0.5, 1.0)
    state_hidden: Tuple[int, ...] = (4000, 1000)   # paper §IV-C
    state_out: int = 512
    module_hidden: int = 128                  # measurement/goal modules
    stream_hidden: int = 512
    state_module: str = "mlp"                 # "mlp" | "cnn" (Fig. 3 ablation)
    #                                           | "attention" (queue encoder)
    cnn_channels: Tuple[int, ...] = (8, 16)
    cnn_width: int = 9
    cnn_stride: int = 4
    # Queue-as-tokens attention state module (repro.nn.queue_encoder) —
    # only read when state_module == "attention".
    attn_queue: int = 128                     # Q: job-token buffer size
    attn_dim: int = 64                        # d_model
    attn_heads: int = 4
    attn_layers: int = 2
    attn_mlp_mult: int = 2
    backend: str = "xla"                      # "xla" | "pallas" (fused kernel)

    def __post_init__(self):
        resolve_backend(self.backend)
        if self.state_module not in STATE_MODULES:
            raise ValueError(f"unknown state_module {self.state_module!r}; "
                             f"expected one of {STATE_MODULES}")
        if self.state_module == "attention":
            expect = (self.attn_queue * (self.n_measurements + 2) + 1
                      + 2 * self.n_measurements)
            if self.state_dim != expect:
                raise ValueError(
                    f"attention state_dim mismatch: got {self.state_dim}, "
                    f"layout Q*(M+2)+1+2M = {expect} for "
                    f"attn_queue={self.attn_queue} M={self.n_measurements}")

    @property
    def n_offsets(self) -> int:
        return len(self.offsets)

    @property
    def pred_dim(self) -> int:
        return self.n_offsets * self.n_measurements

    @property
    def queue_encoder(self) -> QueueEncoderConfig:
        """Encoder architecture derived from the DFP shape contract."""
        return QueueEncoderConfig(
            queue_cap=self.attn_queue,
            job_dim=self.n_measurements + 2,
            ctx_dim=2 * self.n_measurements,
            window=self.n_actions,
            d_model=self.attn_dim,
            n_heads=self.attn_heads,
            n_layers=self.attn_layers,
            mlp_mult=self.attn_mlp_mult,
            out_dim=self.state_out,
        )


def init_params(key: jax.Array, cfg: DFPConfig):
    ks = jax.random.split(key, 8)
    params = {}
    if cfg.state_module == "mlp":
        sizes = [cfg.state_dim, *cfg.state_hidden, cfg.state_out]
        params["state"] = mlp_init(ks[0], sizes)
    elif cfg.state_module == "attention":
        params["state"] = queue_encoder_init(ks[0], cfg.queue_encoder)
    else:  # CNN ablation: 1-D convs over the state vector.
        convs = []
        in_ch = 1
        length = cfg.state_dim
        ck = jax.random.split(ks[0], len(cfg.cnn_channels))
        for i, ch in enumerate(cfg.cnn_channels):
            convs.append(conv1d_init(ck[i], in_ch, ch, cfg.cnn_width))
            in_ch = ch
            length = -(-length // cfg.cnn_stride)
        params["state"] = {
            "convs": convs,
            "proj": dense_init(ks[1], length * in_ch, cfg.state_out),
        }
    params["measurement"] = mlp_init(
        ks[2], [cfg.n_measurements, cfg.module_hidden, cfg.module_hidden,
                cfg.module_hidden])
    params["goal"] = mlp_init(
        ks[3], [cfg.n_measurements, cfg.module_hidden, cfg.module_hidden,
                cfg.module_hidden])
    joint = cfg.state_out + 2 * cfg.module_hidden
    params["expectation"] = mlp_init(ks[4], [joint, cfg.stream_hidden,
                                             cfg.pred_dim])
    params["action"] = mlp_init(ks[5], [joint, cfg.stream_hidden,
                                        cfg.n_actions * cfg.pred_dim])
    return params


def _state_features(params, cfg: DFPConfig, state: jnp.ndarray) -> jnp.ndarray:
    if cfg.state_module == "mlp":
        return mlp_forward(params["state"], state,
                           final_activation="leaky_relu", backend=cfg.backend)
    if cfg.state_module == "attention":
        return queue_state_features(params["state"], cfg.queue_encoder,
                                    state, backend=cfg.backend)
    # CNN ablation stays on plain XLA ops (conv has no fused kernel).
    x = state[..., :, None]                       # (B, L, 1)
    for conv in params["state"]["convs"]:
        x = leaky_relu(conv1d_apply(conv, x, stride=cfg.cnn_stride))
    x = x.reshape(*x.shape[:-2], -1)
    return leaky_relu(dense_apply(params["state"]["proj"], x))


def predict(params, cfg: DFPConfig, state: jnp.ndarray, meas: jnp.ndarray,
            goal: jnp.ndarray) -> jnp.ndarray:
    """Batched forward pass.

    state (B, state_dim), meas (B, M), goal (B, M)
    -> predictions (B, A, T, M): per-action future measurement deltas.

    Every dense module dispatches on ``cfg.backend``: plain XLA ops or
    the fused-MLP Pallas kernel (forward and backward).
    """
    s = _state_features(params, cfg, state)
    m = mlp_forward(params["measurement"], meas,
                    final_activation="leaky_relu", backend=cfg.backend)
    g = mlp_forward(params["goal"], goal,
                    final_activation="leaky_relu", backend=cfg.backend)
    j = jnp.concatenate([s, m, g], axis=-1)
    e = mlp_forward(params["expectation"], j, backend=cfg.backend)  # (B, T*M)
    a = mlp_forward(params["action"], j, backend=cfg.backend)       # (B, A*T*M)
    a = a.reshape(*a.shape[:-1], cfg.n_actions, cfg.pred_dim)
    a = a - a.mean(axis=-2, keepdims=True)                        # dueling norm
    p = e[..., None, :] + a                                       # (B, A, T*M)
    return p.reshape(*p.shape[:-1], cfg.n_offsets, cfg.n_measurements)


def action_values(params, cfg: DFPConfig, state, meas, goal) -> jnp.ndarray:
    """u(a) = sum_tau w_tau sum_m g_m * p[a, tau, m]   -> (B, A)."""
    p = predict(params, cfg, state, meas, goal)
    w = jnp.asarray(cfg.temporal_weights, p.dtype)                # (T,)
    return jnp.einsum("batm,t,bm->ba", p, w, goal)


def loss_fn(params, cfg: DFPConfig, batch) -> jnp.ndarray:
    """MSE between the taken action's predicted and realized future deltas.

    batch: dict with state (B,S), meas (B,M), goal (B,M), action (B,),
    target (B,T,M), target_mask (B,T) — mask handles episode-end clamping.
    """
    p = predict(params, cfg, batch["state"], batch["meas"], batch["goal"])
    taken = jnp.take_along_axis(
        p, batch["action"][:, None, None, None].astype(jnp.int32), axis=1
    )[:, 0]                                                       # (B, T, M)
    err = (taken - batch["target"]) ** 2
    mask = batch["target_mask"][..., None]
    return (err * mask).sum() / jnp.maximum(mask.sum() * cfg.n_measurements, 1.0)


@functools.partial(jax.jit, static_argnums=(1,))
def greedy_action(params, cfg: DFPConfig, state, meas, goal,
                  valid_mask) -> jnp.ndarray:
    """Argmax over valid window slots (invalid slots masked to -inf)."""
    u = action_values(params, cfg, state[None], meas[None], goal[None])[0]
    u = jnp.where(valid_mask, u, -jnp.inf)
    return jnp.argmax(u)


@functools.partial(jax.jit, static_argnums=(1,))
def greedy_actions_packed(params, cfg: DFPConfig, packed) -> jnp.ndarray:
    """Batched greedy selection: ONE forward pass for N pending decisions.

    ``packed`` is one (N, state_dim + 2M + A) buffer with a row per
    decision, [state | meas | goal | valid] — a lockstep round pays
    per-call host->device transfer overhead on every input array, so the
    rollout engine ships a single buffer and we slice it on device.

    On the ``xla`` backend this is a ``vmap`` over the single-decision
    scorer, so each row's own goal vector weights its own prediction —
    environments with heterogeneous goals (different contention
    regimes, Eq. 1) batch together correctly.  The ``pallas`` backend
    scores the batch directly (``action_values`` is fully batched and
    its goal einsum is already per-row), so the fused kernel sees the
    real padded (width, dim) matmul instead of width vmapped
    single-row calls.
    """
    sd, m, a = cfg.state_dim, cfg.n_measurements, cfg.n_actions
    states = packed[:, :sd]
    meas = packed[:, sd:sd + m]
    goals = packed[:, sd + m:sd + 2 * m]
    masks = packed[:, sd + 2 * m:sd + 2 * m + a] > 0.5

    if cfg.backend == "pallas":
        u = action_values(params, cfg, states, meas, goals)
        return jnp.argmax(jnp.where(masks, u, -jnp.inf), axis=-1)

    def one(state, mrow, goal, mask):
        u = action_values(params, cfg, state[None], mrow[None], goal[None])[0]
        return jnp.argmax(jnp.where(mask, u, -jnp.inf))

    return jax.vmap(one)(states, meas, goals, masks)
