"""Curriculum training driver for the MRSch agent (paper §III-D, §V-B)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..sim.cluster import ResourceSpec
from ..sim.simulator import SimResult, run_trace
from .agent import MRSchAgent


@dataclass
class TrainLog:
    episode_losses: List[float] = field(default_factory=list)
    episode_metrics: List[Dict[str, float]] = field(default_factory=list)
    wall_seconds: float = 0.0


def train_agent(agent: MRSchAgent, resources: Sequence[ResourceSpec],
                jobsets: Sequence[Sequence], epochs: int = 1,
                verbose: bool = False) -> TrainLog:
    """Run the agent through ordered jobsets with exploration + learning."""
    log = TrainLog()
    t0 = time.time()
    agent.training = True
    for epoch in range(epochs):
        for i, jobs in enumerate(jobsets):
            result = run_trace(resources, jobs, agent,
                               window=agent.config.window)
            loss = agent.end_episode()
            if loss is not None:
                log.episode_losses.append(loss)
            log.episode_metrics.append(result.metrics.as_row())
            if verbose:
                u = result.metrics.utilization
                print(f"[train] epoch {epoch} set {i}: loss={loss} "
                      f"eps={agent.epsilon:.3f} util={u}")
    agent.training = False
    log.wall_seconds = time.time() - t0
    return log


def evaluate(policy, resources: Sequence[ResourceSpec],
             jobs: Sequence, window: int = 10) -> SimResult:
    """Deterministic evaluation run (no exploration, no learning)."""
    was_training = getattr(policy, "training", False)
    if hasattr(policy, "training"):
        policy.training = False
    result = run_trace(resources, jobs, policy, window=window)
    if hasattr(policy, "training"):
        policy.training = was_training
    return result
