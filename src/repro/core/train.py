"""Curriculum training drivers for the MRSch agent (paper §III-D, §V-B).

Two ways to run the same training loop:

* ``train_agent`` with no ``config`` — the classic sequential driver: one
  trace at a time through ``run_trace``, gradient steps at each episode
  end.  Kept as the reference implementation.
* ``train_agent`` with a ``TrainConfig`` (or ``train_agent_vectorized``
  with explicit ``EnvSlot`` lanes) — batched experience collection: N
  environments advance in lockstep through
  ``repro.sim.vector.VectorSimulator``, every decision round is answered
  by ONE jitted epsilon-greedy DFP forward, transitions land in per-env
  episode accumulators, and whenever any lane finishes a trace its
  episode is flushed to replay and trained on while the other lanes keep
  collecting (optionally with extra gradient steps interleaved every
  round).  Lanes can carry different traces, seeds, and scaled-down
  resource configs (see ``repro.workloads.sweep.build_train_mix``), so a
  single batch exercises heterogeneous Eq.-(1) goal vectors.

With ``n_envs=1`` the vectorized driver consumes the host RNG in exactly
the sequential order, so both drivers produce identical trajectories,
losses, and metrics for the same seed — the tier-1 equivalence test in
``tests/test_train.py`` pins this.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from ..obs.metrics import MetricsRegistry
from ..obs.profiling import annotate
from ..sim.cluster import ResourceSpec
from ..sim.job import Job
from ..sim.simulator import SimConfig, SimResult, Simulator, run_trace
from ..sim.vector import VectorSimulator
from .agent import MRSchAgent


@dataclass(frozen=True)
class TrainConfig:
    """Knobs for the vectorized curriculum driver."""
    n_envs: int = 8                  # lockstep environment lanes
    epochs: int = 1                  # passes over every lane's jobset queue
    window: Optional[int] = None     # None -> agent.config.window
    backfill: bool = True            # EASY backfilling in every lane
    grad_steps_per_round: int = 0    # extra train steps interleaved per
    #                                  lockstep round (0 = train only when
    #                                  an episode completes)
    backend: Optional[str] = None    # None -> keep the agent's backend;
    #                                  "xla" | "pallas" re-routes the agent
    #                                  via set_backend (persists after the
    #                                  run; fused-MLP Pallas kernel)
    state_module: Optional[str] = None  # None -> keep the agent's module;
    #                                  anything else must MATCH it (the
    #                                  parameter trees differ across
    #                                  modules, so it cannot be switched
    #                                  on a live agent — build the agent
    #                                  with the right AgentConfig instead)
    verbose: bool = False


@dataclass
class EnvSlot:
    """One environment lane of the vectorized trainer.

    ``jobsets`` is a queue of ``(label, trace)`` pairs consumed in order;
    when a trace drains, the lane is refilled with the next one.
    ``resources`` defaults to the shared cluster spec; a lane may instead
    carry a scaled-down variant (same resource names, capacities no larger
    than the agent's reference cluster) to diversify contention regimes.
    """
    jobsets: List[Tuple[str, List[Job]]]
    resources: Optional[Sequence[ResourceSpec]] = None
    tag: str = ""


@dataclass
class TrainLog:
    episode_losses: List[float] = field(default_factory=list)
    episode_metrics: List[Dict[str, float]] = field(default_factory=list)
    episodes: List[Dict] = field(default_factory=list)   # per-episode rows
    round_losses: List[float] = field(default_factory=list)
    wall_seconds: float = 0.0
    decisions: int = 0
    rounds: int = 0

    @property
    def decisions_per_sec(self) -> float:
        return self.decisions / max(self.wall_seconds, 1e-9)


def train_agent(agent: MRSchAgent, resources: Sequence[ResourceSpec],
                jobsets: Sequence[Sequence], epochs: int = 1,
                verbose: bool = False,
                config: Optional[TrainConfig] = None,
                registry: Optional[MetricsRegistry] = None) -> TrainLog:
    """Run the agent through ordered jobsets with exploration + learning.

    Without ``config`` this is the sequential reference loop.  With a
    ``TrainConfig`` the jobsets are dealt round-robin across
    ``config.n_envs`` lockstep lanes and collected through the batched
    rollout engine (``train_agent_vectorized``).
    """
    if config is not None:
        slots = slots_from_jobsets(resources, jobsets, config.n_envs)
        cfg = config
        # Honor the legacy positional knobs unless the config overrides them.
        if epochs != 1 and cfg.epochs == 1:
            cfg = replace(cfg, epochs=epochs)
        if verbose and not cfg.verbose:
            cfg = replace(cfg, verbose=True)
        return train_agent_vectorized(agent, slots, cfg, registry=registry)
    log = TrainLog()
    t0 = time.time()
    agent.training = True
    for epoch in range(epochs):
        for i, jobs in enumerate(jobsets):
            result = run_trace(resources, jobs, agent,
                               window=agent.config.window)
            loss = agent.end_episode()
            if loss is not None:
                log.episode_losses.append(loss)
            row = result.metrics.as_row()
            log.episode_metrics.append(row)
            log.episodes.append({"env": 0, "jobset": f"set{i}",
                                 "epoch": epoch, "loss": loss,
                                 "epsilon": agent.epsilon,
                                 "decisions": result.decisions, **row})
            log.decisions += result.decisions
            if verbose:
                u = result.metrics.utilization
                print(f"[train] epoch {epoch} set {i}: loss={loss} "
                      f"eps={agent.epsilon:.3f} util={u}")
    agent.training = False
    log.wall_seconds = time.time() - t0
    return log


def slots_from_jobsets(resources: Sequence[ResourceSpec],
                       jobsets: Sequence[Sequence], n_envs: int,
                       labels: Optional[Sequence[str]] = None
                       ) -> List[EnvSlot]:
    """Deal an ordered jobset list round-robin across ``n_envs`` lanes."""
    n_envs = max(1, min(int(n_envs), len(jobsets) or 1))
    slots = [EnvSlot(jobsets=[], resources=resources, tag=f"env{i}")
             for i in range(n_envs)]
    for k, jobs in enumerate(jobsets):
        label = labels[k] if labels is not None else f"set{k}"
        slots[k % n_envs].jobsets.append((label, list(jobs)))
    return slots


def _check_lane_resources(agent: MRSchAgent,
                          resources: Sequence[ResourceSpec]) -> None:
    names = tuple(r.name for r in resources)
    if names != tuple(agent.enc.resource_names):
        raise ValueError(
            f"lane resources {names} do not match the agent's encoding "
            f"{tuple(agent.enc.resource_names)}")
    for r, cap in zip(resources, agent.enc.capacities):
        if r.capacity > cap:
            raise ValueError(
                f"lane resource {r.name!r} capacity {r.capacity} exceeds "
                f"the agent's reference capacity {cap}; the state encoding "
                "only pads smaller clusters")


def train_agent_vectorized(agent: MRSchAgent, slots: Sequence[EnvSlot],
                           config: TrainConfig = TrainConfig(),
                           registry: Optional[MetricsRegistry] = None
                           ) -> TrainLog:
    """Batched curriculum training over heterogeneous environment lanes.

    Every lockstep round collects one decision from each live lane with a
    single jitted epsilon-greedy forward; a lane that drains its trace
    flushes its episode to replay, runs the jitted train step
    (``agent.end_episode``), and is refilled with its next jobset so the
    batch stays wide.  Reports per-episode metrics plus decisions/sec.

    ``registry`` (a ``repro.obs.MetricsRegistry``) receives live training
    telemetry: loss / grad-norm / epsilon / decisions-per-sec gauges and
    per-lane episode and decision counters.
    """
    log = TrainLog()
    if config.backend is not None:
        agent.set_backend(config.backend)
    if (config.state_module is not None
            and config.state_module != agent.config.state_module):
        raise ValueError(
            f"TrainConfig.state_module={config.state_module!r} does not "
            f"match the agent's {agent.config.state_module!r}: state-module "
            "parameter trees are structurally different, so the module "
            "cannot be swapped on a live agent — construct the agent with "
            "AgentConfig(state_module=...) instead")
    lanes = [s for s in slots if s.jobsets]
    if not lanes:
        return log
    window = config.window or agent.config.window
    queues: List[List[Tuple[str, List[Job]]]] = [
        list(lane.jobsets) * max(1, config.epochs) for lane in lanes]
    lane_res: List[Sequence[ResourceSpec]] = []
    for lane in lanes:
        res = lane.resources
        if res is None:
            raise ValueError(f"lane {lane.tag!r} has no resources")
        _check_lane_resources(agent, res)
        lane_res.append(list(res))
    active: List[str] = [""] * len(lanes)

    def make_sim(i: int) -> Optional[Simulator]:
        if not queues[i]:
            return None
        label, jobs = queues[i].pop(0)
        active[i] = label
        return Simulator(lane_res[i], jobs, agent,
                         SimConfig(window=window, backfill=config.backfill))

    t0 = time.perf_counter()
    agent.training = True
    agent.begin_vector_episodes(len(lanes))
    sims = [make_sim(i) for i in range(len(lanes))]
    # Lanes are non-empty by construction, so every initial sim exists.
    vec = VectorSimulator(sims, policy=agent)

    def refill(i: int, result: SimResult) -> Optional[Simulator]:
        with annotate("mrsch.train.episode_flush"):
            loss = agent.end_episode(slot=i)
        if loss is not None:
            log.episode_losses.append(loss)
        row = result.metrics.as_row()
        log.episode_metrics.append(row)
        log.episodes.append({"env": i, "jobset": active[i],
                             "tag": lanes[i].tag, "loss": loss,
                             "epsilon": agent.epsilon,
                             "decisions": result.decisions, **row})
        log.decisions += result.decisions
        if registry is not None:
            lane = {"lane": lanes[i].tag or f"env{i}"}
            registry.counter("train_episodes_total", lane).inc()
            registry.counter("train_decisions_total",
                             lane).inc(result.decisions)
            if loss is not None:
                registry.gauge("train_loss").set(loss)
                registry.histogram("train_episode_loss").observe(loss)
                if agent.last_grad_norm is not None:
                    registry.gauge("train_grad_norm").set(
                        agent.last_grad_norm)
            registry.gauge("train_epsilon").set(agent.epsilon)
            elapsed = time.perf_counter() - t0
            registry.gauge("train_decisions_per_sec").set(
                log.decisions / max(elapsed, 1e-9))
        if config.verbose:
            print(f"[train-vec] env {i} ({lanes[i].tag}) {active[i]}: "
                  f"loss={loss} eps={agent.epsilon:.3f} "
                  f"decisions={result.decisions}")
        return make_sim(i)

    on_round = None
    if config.grad_steps_per_round > 0:
        def on_round(round_idx: int, n_live: int) -> None:
            with annotate("mrsch.train.grad_steps"):
                loss = agent.train_steps(config.grad_steps_per_round)
            if loss is not None:
                log.round_losses.append(loss)

    vec.run(refill=refill, on_round=on_round)
    agent.training = False
    log.rounds = vec.stats.rounds
    log.wall_seconds = time.perf_counter() - t0
    return log


def evaluate(policy, resources: Sequence[ResourceSpec],
             jobs: Sequence, window: int = 10) -> SimResult:
    """Deterministic evaluation run (no exploration, no learning)."""
    was_training = getattr(policy, "training", False)
    if hasattr(policy, "training"):
        policy.training = False
    result = run_trace(resources, jobs, policy, window=window)
    if hasattr(policy, "training"):
        policy.training = was_training
    return result
