"""Episodic experience buffer for DFP training.

Stores one row per scheduling decision: (state, measurement, goal, action),
grouped by episode so future-measurement targets
f[tau, m] = m_{t+tau} - m_t can be materialized at sample time with
episode-end clamping (offsets that cross the episode boundary are masked).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Episode:
    states: np.ndarray       # (n, state_dim) float32
    meas: np.ndarray         # (n, M)
    goals: np.ndarray        # (n, M)
    actions: np.ndarray      # (n,) int32


class EpisodeRecorder:
    def __init__(self):
        self._s: List[np.ndarray] = []
        self._m: List[np.ndarray] = []
        self._g: List[np.ndarray] = []
        self._a: List[int] = []

    def record(self, state, meas, goal, action: int) -> None:
        self._s.append(np.asarray(state, np.float32))
        self._m.append(np.asarray(meas, np.float32))
        self._g.append(np.asarray(goal, np.float32))
        self._a.append(int(action))

    def __len__(self) -> int:
        return len(self._a)

    def finish(self) -> Optional[Episode]:
        if not self._a:
            return None
        ep = Episode(
            states=np.stack(self._s),
            meas=np.stack(self._m),
            goals=np.stack(self._g),
            actions=np.asarray(self._a, np.int32),
        )
        self._s, self._m, self._g, self._a = [], [], [], []
        return ep


class ReplayBuffer:
    def __init__(self, offsets: Sequence[int], capacity_rows: int = 200_000):
        self.offsets = np.asarray(offsets, np.int64)
        self.capacity_rows = capacity_rows
        self.episodes: List[Episode] = []
        self._rows = 0

    def add(self, ep: Episode) -> None:
        self.episodes.append(ep)
        self._rows += len(ep.actions)
        while self._rows > self.capacity_rows and len(self.episodes) > 1:
            old = self.episodes.pop(0)
            self._rows -= len(old.actions)

    @property
    def rows(self) -> int:
        return self._rows

    def sample(self, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
        """Uniform sample over all stored rows; targets computed on the fly."""
        sizes = np.array([len(e.actions) for e in self.episodes])
        cum = np.cumsum(sizes)
        flat = rng.integers(0, cum[-1], size=batch)
        ep_idx = np.searchsorted(cum, flat, side="right")
        row_idx = flat - np.concatenate([[0], cum[:-1]])[ep_idx]

        T = len(self.offsets)
        M = self.episodes[0].meas.shape[1]
        S = self.episodes[0].states.shape[1]
        out = {
            "state": np.empty((batch, S), np.float32),
            "meas": np.empty((batch, M), np.float32),
            "goal": np.empty((batch, M), np.float32),
            "action": np.empty((batch,), np.int32),
            "target": np.zeros((batch, T, M), np.float32),
            "target_mask": np.zeros((batch, T), np.float32),
        }
        for b, (e, t) in enumerate(zip(ep_idx, row_idx)):
            ep = self.episodes[e]
            n = len(ep.actions)
            out["state"][b] = ep.states[t]
            out["meas"][b] = ep.meas[t]
            out["goal"][b] = ep.goals[t]
            out["action"][b] = ep.actions[t]
            future = t + self.offsets
            valid = future < n
            fut = np.minimum(future, n - 1)
            out["target"][b] = ep.meas[fut] - ep.meas[t]
            out["target_mask"][b] = valid.astype(np.float32)
        return out
