"""Episodic experience buffer for DFP training (paper §II-B, §III-C).

DFP (Dosovitskiy & Koltun '17, as adapted by MRSch) is supervised on
*future measurement deltas* rather than a scalar reward, so experience
must stay grouped by episode: the buffer stores one row per scheduling
decision — (state, measurement, goal, action) — and materializes targets
f[tau, m] = m_{t+tau} - m_t at sample time with episode-end clamping
(temporal offsets that cross the episode boundary are masked out of the
loss).  ``EpisodeRecorder`` accumulates one trajectory at a time;
``VectorEpisodeRecorder`` keeps one accumulator per environment slot so
the batched rollout engine (``repro.sim.vector``) can collect N
interleaved trajectories without corrupting any episode's future-delta
targets; ``ReplayBuffer`` holds finished episodes up to a row budget and
serves uniform minibatches to the jitted train step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Episode:
    states: np.ndarray       # (n, state_dim) float32
    meas: np.ndarray         # (n, M)
    goals: np.ndarray        # (n, M)
    actions: np.ndarray      # (n,) int32


class EpisodeRecorder:
    def __init__(self):
        self._s: List[np.ndarray] = []
        self._m: List[np.ndarray] = []
        self._g: List[np.ndarray] = []
        self._a: List[int] = []

    def record(self, state, meas, goal, action: int) -> None:
        self._s.append(np.asarray(state, np.float32))
        self._m.append(np.asarray(meas, np.float32))
        self._g.append(np.asarray(goal, np.float32))
        self._a.append(int(action))

    def __len__(self) -> int:
        return len(self._a)

    def finish(self) -> Optional[Episode]:
        if not self._a:
            return None
        ep = Episode(
            states=np.stack(self._s),
            meas=np.stack(self._m),
            goals=np.stack(self._g),
            actions=np.asarray(self._a, np.int32),
        )
        self._s, self._m, self._g, self._a = [], [], [], []
        return ep


class VectorEpisodeRecorder:
    """Per-environment episode accumulators for batched collection.

    The lockstep rollout engine interleaves decisions from N environments;
    routing each transition to its own slot keeps every episode contiguous
    so the DFP future-measurement targets stay well-defined.  Slots are
    created on first use, so one recorder serves any batch width.
    """

    def __init__(self, n_envs: int = 0):
        self._slots: Dict[int, EpisodeRecorder] = {
            i: EpisodeRecorder() for i in range(n_envs)}

    def slot(self, i: int) -> EpisodeRecorder:
        rec = self._slots.get(i)
        if rec is None:
            rec = self._slots[i] = EpisodeRecorder()
        return rec

    def record(self, i: int, state, meas, goal, action: int) -> None:
        self.slot(i).record(state, meas, goal, action)

    def finish(self, i: int) -> Optional[Episode]:
        """Close slot ``i``'s episode (None if nothing was recorded)."""
        return self.slot(i).finish()

    def pending_rows(self) -> int:
        return sum(len(r) for r in self._slots.values())

    def __len__(self) -> int:
        return len(self._slots)


class ReplayBuffer:
    def __init__(self, offsets: Sequence[int], capacity_rows: int = 200_000):
        self.offsets = np.asarray(offsets, np.int64)
        self.capacity_rows = capacity_rows
        self.episodes: List[Episode] = []
        self._rows = 0

    def add(self, ep: Episode) -> None:
        self.episodes.append(ep)
        self._rows += len(ep.actions)
        while self._rows > self.capacity_rows and len(self.episodes) > 1:
            old = self.episodes.pop(0)
            self._rows -= len(old.actions)

    @property
    def rows(self) -> int:
        return self._rows

    def sample(self, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
        """Uniform sample over all stored rows; targets computed on the fly.

        Rows are gathered episode-by-episode with fancy indexing rather
        than one python iteration per row — sampling sits on the training
        hot path (``grad_steps_per_episode`` minibatches per episode).
        """
        sizes = np.array([len(e.actions) for e in self.episodes])
        cum = np.cumsum(sizes)
        flat = rng.integers(0, cum[-1], size=batch)
        ep_idx = np.searchsorted(cum, flat, side="right")
        row_idx = flat - np.concatenate([[0], cum[:-1]])[ep_idx]

        T = len(self.offsets)
        M = self.episodes[0].meas.shape[1]
        S = self.episodes[0].states.shape[1]
        out = {
            "state": np.empty((batch, S), np.float32),
            "meas": np.empty((batch, M), np.float32),
            "goal": np.empty((batch, M), np.float32),
            "action": np.empty((batch,), np.int32),
            "target": np.zeros((batch, T, M), np.float32),
            "target_mask": np.zeros((batch, T), np.float32),
        }
        for e in np.unique(ep_idx):
            sel = np.flatnonzero(ep_idx == e)
            ep = self.episodes[e]
            n = len(ep.actions)
            t = row_idx[sel]
            out["state"][sel] = ep.states[t]
            out["meas"][sel] = ep.meas[t]
            out["goal"][sel] = ep.goals[t]
            out["action"][sel] = ep.actions[t]
            future = t[:, None] + self.offsets[None, :]
            valid = future < n
            fut = np.minimum(future, n - 1)
            out["target"][sel] = ep.meas[fut] - ep.meas[t][:, None, :]
            out["target_mask"][sel] = valid.astype(np.float32)
        return out
