"""The unified ``Policy`` protocol shared by every rollout engine.

Before this module the repo carried three divergent policy surfaces:
``agent.select`` (sequential ``Simulator``), per-policy ``select_batch``
adapter shims (``VectorSimulator`` / the evaluation matrix), and the
service replay path (``serve.ServicePolicy``).  The device-resident
rollout engine (``repro.sim.device``) forces a single contract, because
the policy must now be callable *inside* a traced program:

``init_state()``
    Return the policy's device-side state pytree (network parameters for
    NN policies, ``None`` for stateless ones).  Pure read — calling it
    never mutates the policy.

``score_window(policy_state, obs)``
    Pure, traceable scoring of a batch of decisions: ``obs`` is either a
    batch of packed decision rows ``[state | meas | goal | valid]``
    (``encoding.encode_decision_row`` layout) when the policy sets
    ``requires_obs = True``, or just the ``(B, W)`` window-valid mask
    when it does not need observations.  Returns ``(B, A)`` scores; the
    engine masks invalid slots and takes the argmax.  Must be built from
    ``jax.numpy`` ops so the same function serves the jitted device
    rollout and the host-side batched adapter below.

``select(ctx)``
    The host-side single-decision stage (unchanged API — external
    callers of ``agent.select`` keep working; ``SchedulingPolicy`` in
    ``repro.sim.simulator`` remains as a deprecation alias for this
    stage of the protocol).

``WindowPolicy`` is the convenience base that derives the host batched
stage (``select_batch``) from ``score_window``, so a policy written for
the device engine automatically drives ``VectorSimulator`` and the
evaluation matrix with no adapter shim.  Policies with host-only state
(``GAOptimizer``'s cached plan, the serving layer's remote round trip)
declare ``score_window = None`` and the engines fall back to their
sequential ``select`` stage.
"""
from __future__ import annotations

from typing import Optional, Protocol, Sequence, runtime_checkable

import jax.numpy as jnp
import numpy as np

from ..sim.simulator import SchedContext
from .encoding import EncodingConfig, decision_row_dim, encode_decision_row


@runtime_checkable
class Policy(Protocol):
    """One policy, three engine-facing stages (see module docstring)."""

    def select(self, ctx: SchedContext) -> int:
        """Host stage: index into ``ctx.window`` for one decision."""
        ...

    def init_state(self):
        """Device stage: the policy-state pytree threaded through jit."""
        ...

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        """Device stage: pure ``(B, obs)`` -> ``(B, A)`` slot scores."""
        ...


def supports_batch(policy) -> bool:
    """True when the engines may batch this policy's decisions."""
    return callable(getattr(policy, "select_batch", None))


def supports_device(policy) -> bool:
    """True when the policy can run inside the jitted device rollout."""
    return (callable(getattr(policy, "score_window", None))
            and callable(getattr(policy, "init_state", None)))


class WindowPolicy:
    """Base class deriving the host batched stage from ``score_window``.

    Subclasses implement ``score_window`` (jax.numpy, pure) and set:

    ``requires_obs``
        ``True`` (default) — the engines build packed decision rows for
        ``obs``; the subclass must provide ``enc`` (an
        ``EncodingConfig``) fixing the row layout.
        ``False`` — the policy scores from the window-valid mask alone
        (FCFS-style static preferences); no encoding work is done.

    ``training`` — when True the derived ``select_batch`` refuses to
        run: training trajectories are policy-specific (episode buffers,
        exploration RNG order) and must go through the policy's own
        ``select``/``select_batch`` implementation.
    """

    requires_obs: bool = True
    enc: Optional[EncodingConfig] = None
    training: bool = False

    # ------------------------------------------------------- device stages
    def init_state(self):
        return None

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        raise NotImplementedError

    # --------------------------------------------------------- host stages
    def _encode_rows(self, ctxs: Sequence[SchedContext],
                     n_actions: int) -> np.ndarray:
        """Packed decision rows for the host batched stage.

        Subclasses that only consume the state section may override this
        to skip the measurement/goal encoding work.
        """
        assert self.enc is not None, \
            f"{type(self).__name__}.requires_obs needs an EncodingConfig"
        rows = np.zeros((len(ctxs), decision_row_dim(self.enc, n_actions)),
                        dtype=np.float32)
        for i, c in enumerate(ctxs):
            encode_decision_row(self.enc, c, n_actions, out=rows[i])
        return rows

    def select(self, ctx: SchedContext) -> int:
        return int(self.select_batch([ctx])[0])

    def select_batch(self, ctxs: Sequence[SchedContext]) -> np.ndarray:
        """One ``score_window`` call for N contexts -> greedy actions."""
        if self.training:
            raise RuntimeError(
                f"{type(self).__name__}.select_batch is evaluation-only: "
                "training records a policy-specific trajectory — run "
                "training through the policy's own select path")
        n_actions = self._n_actions(ctxs)
        mask = np.zeros((len(ctxs), n_actions), bool)
        for i, c in enumerate(ctxs):
            mask[i, :min(len(c.window), n_actions)] = True
        if self.requires_obs:
            obs = self._encode_rows(ctxs, n_actions)
        else:
            obs = mask.astype(np.float32)
        scores = np.asarray(self.score_window(self.init_state(),
                                              jnp.asarray(obs)))
        scores = np.where(mask, scores, -np.inf)   # jax output is read-only
        return np.argmax(scores, axis=1).astype(np.int32)

    def _n_actions(self, ctxs: Sequence[SchedContext]) -> int:
        if self.enc is not None:
            return self.enc.window
        return max(len(c.window) for c in ctxs)
