"""Comparison scheduling policies (paper §IV-D).

* FCFS        — list-scheduling extension of first-come-first-serve to
                multi-resource; always selects the head of the window.
* GAOptimizer — multi-objective optimization over the window solved with a
                genetic algorithm (NSGA-II-style non-dominated sorting),
                after Fan et al. "Scheduling Beyond CPUs" [13].
* ScalarRL    — policy-gradient RL with a *fixed-weight* scalar reward
                (0.5 * util_A + 0.5 * util_B ...), the paper's single-
                objective RL strawman.

All policies run under the same simulator machinery (window, reservation,
EASY backfilling), so differences come from the selection rule alone.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.modules import mlp_apply, mlp_init
from ..nn.optim import adam_init, adam_update
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SchedContext
from .encoding import EncodingConfig, encode_measurement, encode_state
from .policy_api import WindowPolicy


class FCFSPolicy(WindowPolicy):
    """Head-of-queue list scheduling.

    Expressed through the ``Policy`` protocol as a static slot
    preference: earlier window slots score higher, so the masked argmax
    always lands on the head.  The batched and device stages come from
    ``WindowPolicy``/``score_window``; ``select`` keeps the trivial host
    fast path (identical result, no array round trip per decision).
    """

    requires_obs = False      # scores need only the window-valid mask

    def select(self, ctx: SchedContext) -> int:
        return 0

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        return -jnp.broadcast_to(
            jnp.arange(obs.shape[-1], dtype=jnp.float32), obs.shape)


# --------------------------------------------------------------------- GA
@dataclass(frozen=True)
class GAConfig:
    population: int = 24
    generations: int = 20
    tournament: int = 3
    crossover_rate: float = 0.9
    mutation_rate: float = 0.25
    seed: int = 0


class GAOptimizer:
    """Window-limited multi-objective GA.

    At each scheduling pass it evolves permutations of the current window;
    fitness = per-resource utilization after greedily packing the
    permutation onto the free resources (immediate effect, as in the
    optimization literature).  Non-dominated sorting + crowding distance
    pick the survivor; the winning permutation is then replayed one
    selection at a time.

    Deliberately no ``select_batch``: the cached plan is keyed to ONE
    trace's clock and window, so sharing an instance across lockstep
    environments would cross-contaminate plans.  The vector engine runs
    GA through its sequential per-environment fallback with one instance
    per environment (``VectorSimulator.from_factory``).
    """

    # Host-only stages of the Policy protocol: the evolving plan cache
    # cannot be expressed as a pure traced function, so every engine
    # must drive GA through its sequential ``select`` stage
    # (``policy_api.supports_device`` reports False).
    init_state = None
    score_window = None

    def __init__(self, config: GAConfig = GAConfig()):
        self.config = config
        self.rng = np.random.default_rng(config.seed)
        self._plan: List[int] = []       # jids in planned order
        self._plan_key: Tuple = ()

    # --- fitness -----------------------------------------------------------
    def _pack_objectives(self, perm, window, free, caps) -> np.ndarray:
        used = {n: 0 for n in caps}
        avail = dict(free)
        for idx in perm:
            job = window[idx]
            if all(job.demands.get(n, 0) <= avail[n] for n in caps):
                for n in caps:
                    d = job.demands.get(n, 0)
                    avail[n] -= d
                    used[n] += d
        busy = {n: caps[n] - free[n] for n in caps}
        return np.array([(busy[n] + used[n]) / max(caps[n], 1) for n in caps])

    @staticmethod
    def _nondominated_rank(objs: np.ndarray) -> np.ndarray:
        n = len(objs)
        rank = np.zeros(n, int)
        for i in range(n):
            for k in range(n):
                if k == i:
                    continue
                if np.all(objs[k] >= objs[i]) and np.any(objs[k] > objs[i]):
                    rank[i] += 1           # i is dominated by k
        return rank

    def _evolve(self, window, free, caps) -> List[int]:
        cfg = self.config
        W = len(window)
        if W == 1:
            return [0]
        pop = [self.rng.permutation(W) for _ in range(cfg.population)]
        pop[0] = np.arange(W)              # seed with FCFS order
        for _ in range(cfg.generations):
            objs = np.stack([self._pack_objectives(p, window, free, caps)
                             for p in pop])
            rank = self._nondominated_rank(objs)
            # crowding proxy: sum of objectives breaks ties inside a front
            score = -rank + 1e-3 * objs.sum(1)
            order = np.argsort(-score)
            elites = [pop[i] for i in order[: cfg.population // 2]]
            children = []
            while len(children) < cfg.population - len(elites):
                a, b = (elites[self.rng.integers(len(elites))] for _ in "ab")
                child = self._ox(a, b) if self.rng.uniform() < cfg.crossover_rate \
                    else a.copy()
                if self.rng.uniform() < cfg.mutation_rate and W > 1:
                    i, k = self.rng.choice(W, 2, replace=False)
                    child[i], child[k] = child[k], child[i]
                children.append(child)
            pop = elites + children
        objs = np.stack([self._pack_objectives(p, window, free, caps)
                         for p in pop])
        rank = self._nondominated_rank(objs)
        best = np.argsort(rank - 1e-3 * objs.sum(1))[0]
        return list(pop[best])

    def _ox(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Order crossover for permutations."""
        n = len(a)
        i, k = sorted(self.rng.choice(n, 2, replace=False))
        child = -np.ones(n, int)
        child[i:k + 1] = a[i:k + 1]
        fill = [x for x in b if x not in child]
        ptr = 0
        for pos in range(n):
            if child[pos] < 0:
                child[pos] = fill[ptr]
                ptr += 1
        return child

    # --- policy ------------------------------------------------------------
    def select(self, ctx: SchedContext) -> int:
        key = (ctx.now, tuple(j.jid for j in ctx.window))
        jids = [j.jid for j in ctx.window]
        if self._plan_key != key or not any(j in jids for j in self._plan):
            caps = dict(ctx.cluster.capacities)
            free = dict(ctx.cluster.free)
            order = self._evolve(ctx.window, free, caps)
            self._plan = [ctx.window[i].jid for i in order]
        # Serve the next planned jid still present in the window.
        for jid in self._plan:
            if jid in jids:
                self._plan = self._plan[self._plan.index(jid) + 1:]
                self._plan_key = (ctx.now, tuple(jids))
                return jids.index(jid)
        return 0


# --------------------------------------------------------------------- RL
@dataclass(frozen=True)
class ScalarRLConfig:
    window: int = 10
    hidden: Tuple[int, ...] = (512, 128)
    lr: float = 3e-4
    gamma: float = 0.99
    weights: Optional[Tuple[float, ...]] = None     # default: uniform 1/R
    seed: int = 0
    entropy_coef: float = 1e-3


@functools.partial(jax.jit, static_argnums=(3,))
def _pg_step(params, opt_state, batch, sizes, lr, entropy_coef):
    def loss(p):
        logits = mlp_apply(p, batch["state"])
        logp = jax.nn.log_softmax(
            jnp.where(batch["mask"], logits, -1e9), axis=-1)
        taken = jnp.take_along_axis(logp, batch["action"][:, None], 1)[:, 0]
        adv = batch["ret"] - batch["ret"].mean()
        pg = -(taken * adv).mean()
        ent = -(jnp.exp(logp) * jnp.where(batch["mask"], logp, 0.0)).sum(-1).mean()
        return pg - entropy_coef * ent
    l, grads = jax.value_and_grad(loss)(params)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                    grad_clip=10.0)
    return params, opt_state, l


class ScalarRLPolicy(WindowPolicy):
    """REINFORCE over window slots with a fixed-weight scalar reward.

    Evaluation batching and the device stage both come from the
    ``Policy`` protocol: ``score_window`` is one masked-logits forward,
    consumed by ``WindowPolicy.select_batch`` on the host and by the
    device rollout engine in-graph.  Training stays on the sequential
    ``select`` path — the REINFORCE episode buffers assume one
    contiguous trajectory, and ``WindowPolicy`` enforces that by
    refusing batched selection while ``training`` is set.
    """

    def __init__(self, resources: Sequence[ResourceSpec],
                 config: ScalarRLConfig = ScalarRLConfig()):
        self.resources = list(resources)
        self.config = config
        names = tuple(r.name for r in self.resources)
        caps = tuple(r.capacity for r in self.resources)
        self.enc = EncodingConfig(window=config.window, resource_names=names,
                                  capacities=caps)
        R = len(names)
        self.weights = np.asarray(config.weights if config.weights
                                  else [1.0 / R] * R)
        sizes = [self.enc.state_dim, *config.hidden, config.window]
        self.params = mlp_init(jax.random.PRNGKey(config.seed), sizes)
        self.opt_state = adam_init(self.params)
        self.rng = np.random.default_rng(config.seed)
        self.training = False
        self._states: List[np.ndarray] = []
        self._actions: List[int] = []
        self._masks: List[np.ndarray] = []
        self._meas: List[np.ndarray] = []
        self.losses: List[float] = []

    def select(self, ctx: SchedContext) -> int:
        state = encode_state(self.enc, ctx)
        n_valid = min(len(ctx.window), self.config.window)
        mask = np.zeros(self.config.window, bool)
        mask[:n_valid] = True
        logits = np.array(mlp_apply(self.params, jnp.asarray(state)))
        logits[~mask] = -1e9
        if self.training:
            z = logits - logits.max()
            probs = np.exp(z) / np.exp(z).sum()
            action = int(self.rng.choice(self.config.window, p=probs))
            self._states.append(state)
            self._actions.append(action)
            self._masks.append(mask)
            self._meas.append(encode_measurement(self.enc, ctx))
        else:
            action = int(np.argmax(logits))
        return action

    # ------------------------------------------------- Policy protocol
    def init_state(self):
        return self.params

    def score_window(self, policy_state, obs) -> jnp.ndarray:
        """Logits from the state section of the packed row (pure)."""
        return mlp_apply(policy_state, obs[..., : self.enc.state_dim])

    def _encode_rows(self, ctxs: Sequence[SchedContext],
                     n_actions: int) -> np.ndarray:
        # Only the state section feeds the logits; skip the
        # measurement/goal encoding the full decision row would pay for.
        return np.stack([encode_state(self.enc, c) for c in ctxs])

    def end_episode(self) -> Optional[float]:
        if not self.training or len(self._actions) < 2:
            self._states, self._actions, self._masks, self._meas = [], [], [], []
            return None
        meas = np.stack(self._meas)                       # (n, R)
        # Fixed-weight scalar reward observed at the *next* decision.
        scalar = meas @ self.weights
        rewards = np.append(scalar[1:], scalar[-1])
        rets = np.zeros_like(rewards)
        acc = 0.0
        for i in range(len(rewards) - 1, -1, -1):
            acc = rewards[i] + self.config.gamma * acc
            rets[i] = acc
        batch = {
            "state": jnp.asarray(np.stack(self._states)),
            "action": jnp.asarray(np.asarray(self._actions, np.int32)),
            "mask": jnp.asarray(np.stack(self._masks)),
            "ret": jnp.asarray(rets.astype(np.float32)),
        }
        self.params, self.opt_state, loss = _pg_step(
            self.params, self.opt_state, batch, self.config.window,
            self.config.lr, self.config.entropy_coef)
        self._states, self._actions, self._masks, self._meas = [], [], [], []
        self.losses.append(float(loss))
        return float(loss)
