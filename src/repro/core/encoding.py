"""Vector state encoding (paper §III-A) plus the queue-as-tokens layout.

Classic (``state_module`` "mlp" / "cnn") — each waiting job in the
window -> (R + 2) elements:
    [P_i1 .. P_iR,  walltime_estimate,  queued_time]
where P_ij is the requested fraction of resource j's capacity and the two
times are normalized by ``time_scale``.

Each resource *unit* -> 2 elements:
    [availability bit,  (estimated release time - now) if occupied else 0]

Concatenated into one fixed-size vector:
    dim = W*(R+2) + sum_r 2*capacity_r
which reproduces the paper's 11410 for (W=10, 4392 nodes, 1293 BB units).

Attention (``state_module`` "attention") — the window cap is removed:
the first ``queue_cap`` (Q >= W) waiting jobs each become one (R + 2)
token in arrival order (the leading W are exactly the window), followed
by the raw queue length and a 2R cluster-context summary
[free_fraction_r, mean normalized time-to-free over busy units of r]:
    dim = Q*(R+2) + 1 + 2R
The per-unit sections are replaced by the summary because the attention
encoder (``repro.nn.queue_encoder``) consumes tokens, not unit slots —
which is what lets Q grow to hundreds of jobs without the state vector
exploding quadratically.  The packed decision-row contract
``[state | meas | goal | valid]`` is unchanged; rows are just wider.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from ..sim.cluster import TTF_HORIZON, Cluster
from ..sim.job import Job
from ..sim.simulator import SchedContext
from .goal import ctx_goal

DAY = 86400.0

STATE_MODULES = ("mlp", "cnn", "attention")


@dataclass(frozen=True)
class EncodingConfig:
    window: int                      # W
    resource_names: Sequence[str]    # ordered resource list
    capacities: Sequence[int]        # units per resource
    time_scale: float = DAY          # normalizer for all time quantities
    state_module: str = "mlp"        # "mlp"/"cnn" share the classic layout;
    #                                  "attention" = queue-as-tokens layout
    queue_cap: int = 0               # Q, attention layout only (>= window)

    def __post_init__(self):
        if self.state_module not in STATE_MODULES:
            raise ValueError(f"unknown state_module "
                             f"{self.state_module!r}; expected one of "
                             f"{STATE_MODULES}")
        if (self.state_module == "attention"
                and self.queue_cap < max(int(self.window), 1)):
            raise ValueError(
                f"attention encoding needs queue_cap >= window, got "
                f"queue_cap={self.queue_cap} window={self.window} — the "
                "leading window tokens double as the action slots")

    @property
    def n_resources(self) -> int:
        return len(self.resource_names)

    @property
    def job_dim(self) -> int:
        return self.n_resources + 2

    @property
    def ctx_dim(self) -> int:
        """Attention layout: context-summary width (2 per resource)."""
        return 2 * self.n_resources

    @property
    def state_dim(self) -> int:
        if self.state_module == "attention":
            return self.queue_cap * self.job_dim + 1 + self.ctx_dim
        return self.window * self.job_dim + 2 * int(sum(self.capacities))


def _job_static_row(job: Job, key: tuple, caps: Sequence[float],
                    time_scale: float) -> np.ndarray:
    """[P_i1 .. P_iR, walltime_norm] for one window job, cached per job.

    Everything but the queued time is fixed for a given (resource order,
    capacities, time scale) — and this runs for every window slot on every
    scheduling decision, so the row is stashed on the job instance.
    """
    cached = job.__dict__.get("_enc_row")
    if cached is not None and cached[0] == key:
        return cached[1]
    names = key[0]
    row = np.empty(len(names) + 1, np.float32)
    for r, name in enumerate(names):
        row[r] = job.demands.get(name, 0) / caps[r]
    row[-1] = job.walltime / time_scale
    job.__dict__["_enc_row"] = (key, row)
    return row


def encode_state(cfg: EncodingConfig, ctx: SchedContext,
                 out: Optional[np.ndarray] = None) -> np.ndarray:
    """Build the full state vector for one scheduling instance.

    The layout is fixed by ``cfg.capacities`` so one network can observe
    heterogeneous training environments: a context whose cluster has fewer
    units than the reference (a scaled-down lane from
    ``repro.workloads.sweep.build_train_mix``) fills only the leading unit
    slots of each resource section; the absent units read as unavailable
    (availability bit 0, time-to-free 0).  Demand fractions are normalized
    by the context's own cluster capacity, so "half the machine" means the
    same thing in every lane.  ``out``, when given, must be a zeroed
    float32 buffer of ``cfg.state_dim`` (the batched agent writes rows of
    its packed decision buffer directly).
    """
    if out is None:
        out = np.zeros(cfg.state_dim, dtype=np.float32)
    # The cache key is identical for every decision on one cluster (caps
    # are fixed at construction), so stash it there: encoding runs on the
    # per-decision hot path.
    cached = ctx.cluster.__dict__.get("_enc_key")
    if cached is not None and cached[0] is cfg:
        key, caps_t = cached[1], cached[2]
        names = key[0]
    else:
        caps = ctx.cluster.capacities
        names = tuple(cfg.resource_names)
        caps_t = tuple(float(max(int(caps.get(n, c)), 1))
                       for n, c in zip(names, cfg.capacities))
        key = (names, caps_t, cfg.time_scale)
        ctx.cluster.__dict__["_enc_key"] = (cfg, key, caps_t)
    R = cfg.n_resources
    if cfg.state_module == "attention":
        # --- queue-as-tokens layout: [Q*(R+2) | queue_len | 2R context]
        now = ctx.now
        queue = ctx.queue if ctx.queue is not None else ctx.window
        Q = cfg.queue_cap
        for slot, job in enumerate(queue[:Q]):
            base = slot * cfg.job_dim
            out[base: base + R + 1] = _job_static_row(job, key, caps_t,
                                                      cfg.time_scale)
            out[base + R + 1] = (now - job.submit) / cfg.time_scale
        out[Q * cfg.job_dim] = min(len(queue), Q)
        offset = Q * cfg.job_dim + 1
        for r, name in enumerate(cfg.resource_names):
            rel = ctx.cluster.release[name]
            busy = rel > 0.0
            nb = int(busy.sum())
            out[offset] = 1.0 - nb / caps_t[r]               # free fraction
            if nb:
                # Upper clip keeps permanently drained units (release =
                # +inf phantom reservations) from leaking inf features.
                ttf = np.clip(rel[busy] - now, 0.0, TTF_HORIZON).sum() / nb
                out[offset + 1] = ttf / cfg.time_scale       # mean time-to-free
            offset += 2
        return out
    # --- window jobs
    now = ctx.now
    for slot, job in enumerate(ctx.window[: cfg.window]):
        base = slot * cfg.job_dim
        out[base: base + R + 1] = _job_static_row(job, key, caps_t,
                                                  cfg.time_scale)
        out[base + R + 1] = (now - job.submit) / cfg.time_scale
    # --- resource units, written straight into the output buffer (this is
    # the decision hot path: one encode per policy decision)
    offset = cfg.window * cfg.job_dim
    for r, name in enumerate(cfg.resource_names):
        section = int(cfg.capacities[r])
        rel = ctx.cluster.release[name]   # estimated release time, 0 == free
        k = min(rel.shape[0], section)
        rel = rel[:k]
        busy = rel > 0.0
        out[offset: offset + k] = ~busy                          # avail bit
        ttf = out[offset + section: offset + section + k]
        np.subtract(rel, ctx.now, out=ttf, where=busy)           # time-to-free
        np.maximum(ttf, 0.0, out=ttf)
        np.minimum(ttf, TTF_HORIZON, out=ttf)   # drained units release at +inf
        ttf /= cfg.time_scale
        offset += 2 * section
    return out


def encode_measurement(cfg: EncodingConfig, ctx: SchedContext) -> np.ndarray:
    """Measurement vector = instantaneous utilization per resource (§III-A)."""
    util = ctx.cluster.utilization()
    return util.astype(np.float32)


# ------------------------------------------------------------- packed rows
# One decision = one packed row [state | meas | goal | valid-mask]; the
# batched agent path (MRSchAgent.select_batch / _greedy_rows) and the
# decision service (repro.serve) MUST agree on this layout byte for byte
# — bit-identical serving depends on it — so it is defined only here.

def decision_row_dim(cfg: EncodingConfig, n_actions: int) -> int:
    return cfg.state_dim + 2 * cfg.n_resources + n_actions


def encode_decision_row(cfg: EncodingConfig, ctx: SchedContext,
                        n_actions: int, out: np.ndarray,
                        goal: Optional[np.ndarray] = None) -> np.ndarray:
    """Fill one packed decision row in place; returns the goal used.

    ``out`` must be a zeroed float32 buffer of ``decision_row_dim``.
    ``goal`` overrides the Eq. (1) context goal (per-request objective
    steering in the serving layer)."""
    sd, m = cfg.state_dim, cfg.n_resources
    encode_state(cfg, ctx, out=out[:sd])
    out[sd:sd + m] = encode_measurement(cfg, ctx)
    if goal is None:
        goal = ctx_goal(ctx, cfg.resource_names)
    out[sd + m:sd + 2 * m] = goal
    out[sd + 2 * m:sd + 2 * m + min(len(ctx.window), n_actions)] = 1.0
    return goal


def pad_decision_rows(rows: np.ndarray, width: int,
                      cfg: EncodingConfig) -> np.ndarray:
    """Pad packed rows up to ``width``: padded rows are valid everywhere
    and their actions are discarded by the caller."""
    n = rows.shape[0]
    if width == n:
        return rows
    packed = np.zeros((width, rows.shape[1]), dtype=np.float32)
    packed[:n] = rows
    packed[n:, cfg.state_dim + 2 * cfg.n_resources:] = 1.0
    return packed


def encoding_for(cluster: Cluster, window: int,
                 time_scale: float = DAY, state_module: str = "mlp",
                 queue_cap: int = 0) -> EncodingConfig:
    return EncodingConfig(
        window=window,
        resource_names=tuple(cluster.names),
        capacities=tuple(cluster.capacities[n] for n in cluster.names),
        time_scale=time_scale,
        state_module=state_module,
        queue_cap=queue_cap,
    )
