"""Vector state encoding (paper §III-A).

Each waiting job in the window -> (R + 2) elements:
    [P_i1 .. P_iR,  walltime_estimate,  queued_time]
where P_ij is the requested fraction of resource j's capacity and the two
times are normalized by ``time_scale``.

Each resource *unit* -> 2 elements:
    [availability bit,  (estimated release time - now) if occupied else 0]

Concatenated into one fixed-size vector:
    dim = W*(R+2) + sum_r 2*capacity_r
which reproduces the paper's 11410 for (W=10, 4392 nodes, 1293 BB units).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from ..sim.cluster import Cluster
from ..sim.job import Job
from ..sim.simulator import SchedContext

DAY = 86400.0


@dataclass(frozen=True)
class EncodingConfig:
    window: int                      # W
    resource_names: Sequence[str]    # ordered resource list
    capacities: Sequence[int]        # units per resource
    time_scale: float = DAY          # normalizer for all time quantities

    @property
    def n_resources(self) -> int:
        return len(self.resource_names)

    @property
    def job_dim(self) -> int:
        return self.n_resources + 2

    @property
    def state_dim(self) -> int:
        return self.window * self.job_dim + 2 * int(sum(self.capacities))


def encode_state(cfg: EncodingConfig, ctx: SchedContext) -> np.ndarray:
    """Build the full state vector for one scheduling instance."""
    out = np.zeros(cfg.state_dim, dtype=np.float32)
    # --- window jobs
    for slot, job in enumerate(ctx.window[: cfg.window]):
        base = slot * cfg.job_dim
        for r, name in enumerate(cfg.resource_names):
            cap = max(int(cfg.capacities[r]), 1)
            out[base + r] = job.demands.get(name, 0) / cap
        out[base + cfg.n_resources] = job.walltime / cfg.time_scale
        out[base + cfg.n_resources + 1] = (ctx.now - job.submit) / cfg.time_scale
    # --- resource units, written straight into the output buffer (this is
    # the decision hot path: one encode per policy decision)
    offset = cfg.window * cfg.job_dim
    for name in cfg.resource_names:
        rel = ctx.cluster.release[name]   # estimated release time, 0 == free
        k = rel.shape[0]
        busy = rel > 0.0
        out[offset: offset + k] = ~busy                          # avail bit
        ttf = out[offset + k: offset + 2 * k]
        np.subtract(rel, ctx.now, out=ttf, where=busy)           # time-to-free
        np.maximum(ttf, 0.0, out=ttf)
        ttf /= cfg.time_scale
        offset += 2 * k
    return out


def encode_measurement(cfg: EncodingConfig, ctx: SchedContext) -> np.ndarray:
    """Measurement vector = instantaneous utilization per resource (§III-A)."""
    util = ctx.cluster.utilization()
    return util.astype(np.float32)


def encoding_for(cluster: Cluster, window: int,
                 time_scale: float = DAY) -> EncodingConfig:
    return EncodingConfig(
        window=window,
        resource_names=tuple(cluster.names),
        capacities=tuple(cluster.capacities[n] for n in cluster.names),
        time_scale=time_scale,
    )
