"""The MRSch scheduling agent (paper §III).

Wraps the DFP network with: vector state encoding, the Eq. (1) dynamic goal
vector, epsilon-greedy exploration, the episodic replay buffer, and Adam
training on the future-measurement MSE loss.  Implements the simulator's
``SchedulingPolicy`` protocol, so the identical object drives the paper
reproduction benches and the fleet scheduler in ``repro.launch.scheduler``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.backend import resolve_backend
from ..nn.optim import AdamState, adam_init, adam_update
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SchedContext
from .dfp import (DFPConfig, action_values, greedy_actions_packed,
                  init_params, loss_fn)
from .encoding import (EncodingConfig, decision_row_dim, encode_decision_row,
                       encode_measurement, encode_state, pad_decision_rows)
from .goal import ctx_goal
from .replay import EpisodeRecorder, ReplayBuffer, VectorEpisodeRecorder


@dataclass(frozen=True)
class AgentConfig:
    window: int = 10
    offsets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    temporal_weights: Tuple[float, ...] = (0.0, 0.0, 0.0, 0.5, 0.5, 1.0)
    lr: float = 1e-4
    batch_size: int = 64
    grad_steps_per_episode: int = 64
    buffer_rows: int = 200_000
    eps_start: float = 1.0
    eps_decay: float = 0.995          # paper §IV-C: alpha = 0.995
    eps_min: float = 0.02
    state_module: str = "mlp"         # "mlp" | "cnn" | "attention"
    backend: str = "xla"              # "xla" | "pallas" (fused-MLP kernel)
    state_hidden: Tuple[int, ...] = (4000, 1000)
    state_out: int = 512
    module_hidden: int = 128
    stream_hidden: int = 512
    # Queue-as-tokens knobs (state_module == "attention" only): the
    # encoder observes up to ``queue_cap`` waiting jobs instead of the
    # leading window of W.
    queue_cap: int = 128
    attn_dim: int = 64
    attn_heads: int = 4
    attn_layers: int = 2
    attn_mlp_mult: int = 2
    seed: int = 0
    grad_clip: float = 10.0


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2))
def _train_step(cfg: DFPConfig, params, opt_state, batch, lr, grad_clip):
    # Single-step variant, kept for per-step latency measurement
    # (benchmarks/bench_overhead.py); training uses _train_steps_scan.
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                    grad_clip=grad_clip)
    return params, opt_state, loss


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2))
def _train_steps_scan(cfg: DFPConfig, params, opt_state, batches, lr,
                      grad_clip):
    """K gradient steps in ONE dispatch: ``batches`` carries a leading
    step axis and ``lax.scan`` chains the updates, so an episode's whole
    training burst pays a single python->XLA round trip instead of K."""
    def body(carry, batch):
        params, opt_state = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        gnorm = jnp.sqrt(sum(jnp.vdot(g, g)
                             for g in jax.tree_util.tree_leaves(grads)))
        params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                        grad_clip=grad_clip)
        return (params, opt_state), (loss, gnorm)

    (params, opt_state), (losses, gnorms) = jax.lax.scan(
        body, (params, opt_state), batches)
    return params, opt_state, losses, gnorms


@functools.partial(jax.jit, static_argnums=(1,))
def _values(params, cfg: DFPConfig, state, meas, goal, valid_mask):
    u = action_values(params, cfg, state[None], meas[None], goal[None])[0]
    return jnp.where(valid_mask, u, -jnp.inf)


class MRSchAgent:
    """DFP-based multi-resource scheduling agent."""

    def __init__(self, resources: Sequence[ResourceSpec],
                 config: AgentConfig = AgentConfig()):
        self.resources = list(resources)
        self.config = config
        names = tuple(r.name for r in self.resources)
        caps = tuple(r.capacity for r in self.resources)
        attention = config.state_module == "attention"
        self.enc = EncodingConfig(window=config.window, resource_names=names,
                                  capacities=caps,
                                  state_module=config.state_module,
                                  queue_cap=(config.queue_cap if attention
                                             else 0))
        self.dfp = DFPConfig(
            state_dim=self.enc.state_dim,
            n_measurements=len(names),
            n_actions=config.window,
            offsets=config.offsets,
            temporal_weights=config.temporal_weights,
            state_module=config.state_module,
            backend=config.backend,
            state_hidden=config.state_hidden,
            state_out=config.state_out,
            module_hidden=config.module_hidden,
            stream_hidden=config.stream_hidden,
            attn_queue=config.queue_cap,
            attn_dim=config.attn_dim,
            attn_heads=config.attn_heads,
            attn_layers=config.attn_layers,
            attn_mlp_mult=config.attn_mlp_mult,
        )
        key = jax.random.PRNGKey(config.seed)
        self.params = init_params(key, self.dfp)
        self.opt_state = adam_init(self.params)
        self.replay = ReplayBuffer(config.offsets, config.buffer_rows)
        self.recorder = EpisodeRecorder()
        self.vec_recorder = VectorEpisodeRecorder()
        self.rng = np.random.default_rng(config.seed)
        self.epsilon = config.eps_start
        self.training = False
        self.losses: List[float] = []
        self.goal_log: List[np.ndarray] = []
        # Pre-clip global gradient norm of the latest training burst,
        # surfaced into the telemetry registry by the vectorized trainer.
        self.last_grad_norm: Optional[float] = None

    def set_backend(self, backend: str) -> None:
        """Switch the NN execution backend ("xla" | "pallas") in place.

        Parameters are backend-agnostic (same pytree layout), so a
        checkpointed agent can be restored and re-run on either
        backend; the jitted forwards re-specialize on the new
        ``DFPConfig`` automatically (it is a static argument).
        """
        self.dfp = replace(self.dfp, backend=resolve_backend(backend))
        self.config = replace(self.config, backend=backend)

    # ------------------------------------------------------ Policy protocol
    # Device-side stages (repro.core.policy_api): the jitted rollout
    # engine threads ``init_state()`` through its scan and calls
    # ``score_window`` in-graph; the host stages below (``select`` /
    # ``select_batch``) are unchanged, so external callers keep working.
    requires_obs = True

    def init_state(self):
        """Policy-state pytree for the device rollout (the parameters)."""
        return self.params

    def score_window(self, params, obs) -> jnp.ndarray:
        """Action values from packed decision rows (pure, traceable).

        ``obs`` rows follow ``encoding.encode_decision_row``; the valid
        mask is applied by the engine, not here.  A one-row batch is
        numerically identical to the sequential ``_values`` scorer.
        """
        sd, m = self.enc.state_dim, self.enc.n_resources
        return action_values(params, self.dfp, obs[..., :sd],
                             obs[..., sd:sd + m], obs[..., sd + m:sd + 2 * m])

    # ---------------------------------------------------------------- policy
    def _ctx_goal(self, ctx: SchedContext) -> np.ndarray:
        """Eq. (1) goal for this context (shared with the serving layer)."""
        return ctx_goal(ctx, self.enc.resource_names)

    def select(self, ctx: SchedContext) -> int:
        state = encode_state(self.enc, ctx)
        meas = encode_measurement(self.enc, ctx)
        goal = self._ctx_goal(ctx)
        self.goal_log.append(goal)
        n_valid = min(len(ctx.window), self.config.window)
        if self.training and self.rng.uniform() < self.epsilon:
            action = int(self.rng.integers(0, n_valid))
        else:
            mask = np.zeros(self.config.window, bool)
            mask[:n_valid] = True
            u = _values(self.params, self.dfp, jnp.asarray(state),
                        jnp.asarray(meas), jnp.asarray(goal),
                        jnp.asarray(mask))
            action = int(np.argmax(np.asarray(u)))
        if self.training:
            self.recorder.record(state, meas, goal, action)
        return action

    def select_batch(self, ctxs: Sequence[SchedContext],
                     slots: Optional[Sequence[int]] = None) -> np.ndarray:
        """Actions for N pending decisions with ONE jitted forward.

        Used by ``repro.sim.vector.VectorSimulator`` to amortize the
        per-call dispatch overhead across environments.  In evaluation
        mode the actions are greedy and ``slots`` is ignored.  In training
        mode ``slots`` (one environment id per context) is required: each
        row gets an independent epsilon-greedy draw and its transition is
        recorded into that environment's own episode accumulator
        (``VectorEpisodeRecorder``), keeping every trajectory contiguous
        for the DFP future-measurement targets.  The host RNG is consumed
        in row order — one uniform draw per decision, plus one integer
        draw when exploring — exactly as the sequential ``select`` path,
        so an N=1 batched rollout reproduces sequential training
        bit-for-bit given the same seed.
        """
        if self.training and slots is None:
            raise RuntimeError(
                "select_batch without env slots is evaluation-only: "
                "training interleaves N environments, so each context "
                "needs a slot id routing its transition to a per-env "
                "episode accumulator — pass slots=[...] (the vectorized "
                "trainer in repro.core.train does this), or train with "
                "Simulator.run per trace")
        n = len(ctxs)
        sd, m, a = self.enc.state_dim, self.enc.n_resources, self.config.window
        # One packed row per decision (layout shared with the serving
        # layer: encoding.encode_decision_row), encoded straight into a
        # fresh buffer so a round costs one host->device transfer and
        # zero intermediate copies.
        feats = np.zeros((n, decision_row_dim(self.enc, a)), dtype=np.float32)
        for i, c in enumerate(ctxs):
            self.goal_log.append(
                encode_decision_row(self.enc, c, a, out=feats[i]))
        if not self.training:
            return self._greedy_rows(feats)
        # Epsilon-greedy: draw exploration first (host RNG in row order, the
        # same stream the sequential path consumes), then run ONE batched
        # forward over just the exploiting rows — exploring rows never pay
        # for inference, mirroring the sequential fast path.
        acts = np.zeros(n, dtype=np.int32)
        explore = np.empty(n, dtype=bool)
        for i, c in enumerate(ctxs):
            explore[i] = self.rng.uniform() < self.epsilon
            if explore[i]:
                acts[i] = int(self.rng.integers(
                    0, min(len(c.window), a)))
        exploit = np.flatnonzero(~explore)
        if exploit.size:
            acts[exploit] = self._greedy_rows(feats[exploit])
        for i, slot in enumerate(slots):
            self.vec_recorder.record(
                int(slot), feats[i, :sd].copy(), feats[i, sd:sd + m].copy(),
                feats[i, sd + m:sd + 2 * m].copy(), int(acts[i]))
        return acts

    def _greedy_rows(self, rows: np.ndarray) -> np.ndarray:
        """One jitted forward over packed decision rows -> greedy actions.

        Width is padded up to a power of two so the jit cache sees a
        small, fixed set of shapes as environments finish (or explore) at
        different times; padded rows are valid everywhere and their
        actions are discarded.  The numpy buffer goes to the jitted
        function directly — an explicit ``jnp.asarray`` would route the
        transfer through the slow python ``device_put`` path.
        """
        n = rows.shape[0]
        width = 1 << max(n - 1, 0).bit_length()
        packed = pad_decision_rows(rows, width, self.enc)
        acts = greedy_actions_packed(self.params, self.dfp, packed)
        return np.asarray(acts)[:n].astype(np.int32)

    # ---------------------------------------------------------------- train
    def begin_vector_episodes(self, n_envs: int) -> None:
        """Reset the per-environment accumulators for a batched rollout."""
        self.vec_recorder = VectorEpisodeRecorder(n_envs)

    def end_episode(self, slot: Optional[int] = None) -> Optional[float]:
        """Flush the recorded episode, run gradient steps, decay epsilon.

        ``slot=None`` closes the sequential recorder (``select`` path);
        ``slot=i`` closes environment ``i``'s accumulator from a batched
        rollout.  Either way the finished episode enters the shared replay
        buffer and, once the buffer holds a minibatch, triggers
        ``grad_steps_per_episode`` jitted train steps and one epsilon
        decay — environments finishing mid-batch therefore train the
        network while the other environments are still collecting.
        """
        ep = (self.recorder.finish() if slot is None
              else self.vec_recorder.finish(slot))
        if ep is not None:
            self.replay.add(ep)
        if not self.training or self.replay.rows < self.config.batch_size:
            return None
        mean_loss = self.train_steps(self.config.grad_steps_per_episode)
        if mean_loss is None:
            return None
        self.losses.append(mean_loss)
        self.epsilon = max(self.config.eps_min,
                           self.epsilon * self.config.eps_decay)
        return mean_loss

    def train_steps(self, steps: int) -> Optional[float]:
        """Run ``steps`` jitted gradient steps on replay samples.

        Returns the mean loss, or None when the buffer cannot yet fill a
        minibatch.  Used by ``end_episode`` and by the vectorized
        trainer's per-round interleaved updates
        (``TrainConfig.grad_steps_per_round``).
        """
        if self.replay.rows < self.config.batch_size or steps <= 0:
            return None
        samples = [self.replay.sample(self.rng, self.config.batch_size)
                   for _ in range(steps)]
        batches = {k: np.stack([s[k] for s in samples]) for k in samples[0]}
        self.params, self.opt_state, losses, gnorms = _train_steps_scan(
            self.dfp, self.params, self.opt_state, batches,
            self.config.lr, self.config.grad_clip)
        self.last_grad_norm = float(np.asarray(gnorms).mean())
        return float(np.asarray(losses).mean())

    # ---------------------------------------------------------------- io
    def save(self, path: str) -> None:
        flat, treedef = jax.tree_util.tree_flatten(self.params)
        np.savez(path, n=len(flat), epsilon=self.epsilon,
                 **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})

    def load(self, path: str) -> None:
        """Restore ``save``d parameters, validating architecture compatibility.

        The checkpoint must match the agent's current parameter tree leaf
        for leaf (count, shape, dtype) — loading a checkpoint trained with
        a different window / hidden widths / resource count raises a clear
        ``ValueError`` instead of silently unflattening incompatible
        leaves into the live tree.
        """
        from ..checkpoint import check_leaves_compat
        data = np.load(path)
        expected, treedef = jax.tree_util.tree_flatten(self.params)
        n = int(data["n"])
        missing = [f"p{i}" for i in range(n) if f"p{i}" not in data.files]
        if missing:
            raise ValueError(
                f"load({path}): checkpoint claims {n} leaves but arrays "
                f"{missing[:3]}{'...' if len(missing) > 3 else ''} are "
                "absent (truncated or hand-edited archive?)")
        got = [data[f"p{i}"] for i in range(n)]
        check_leaves_compat(expected, got, context=f"load({path})")
        self.params = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in got])
        self.epsilon = float(data["epsilon"])
        self.opt_state = adam_init(self.params)
