"""The MRSch scheduling agent (paper §III).

Wraps the DFP network with: vector state encoding, the Eq. (1) dynamic goal
vector, epsilon-greedy exploration, the episodic replay buffer, and Adam
training on the future-measurement MSE loss.  Implements the simulator's
``SchedulingPolicy`` protocol, so the identical object drives the paper
reproduction benches and the fleet scheduler in ``repro.launch.scheduler``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.optim import AdamState, adam_init, adam_update
from ..sim.cluster import ResourceSpec
from ..sim.simulator import SchedContext
from .dfp import (DFPConfig, action_values, greedy_actions_packed,
                  init_params, loss_fn)
from .encoding import EncodingConfig, encode_measurement, encode_state
from .goal import goal_vector
from .replay import EpisodeRecorder, ReplayBuffer


@dataclass(frozen=True)
class AgentConfig:
    window: int = 10
    offsets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    temporal_weights: Tuple[float, ...] = (0.0, 0.0, 0.0, 0.5, 0.5, 1.0)
    lr: float = 1e-4
    batch_size: int = 64
    grad_steps_per_episode: int = 64
    buffer_rows: int = 200_000
    eps_start: float = 1.0
    eps_decay: float = 0.995          # paper §IV-C: alpha = 0.995
    eps_min: float = 0.02
    state_module: str = "mlp"         # "mlp" | "cnn"
    state_hidden: Tuple[int, ...] = (4000, 1000)
    state_out: int = 512
    module_hidden: int = 128
    seed: int = 0
    grad_clip: float = 10.0


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2))
def _train_step(cfg: DFPConfig, params, opt_state, batch, lr, grad_clip):
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                    grad_clip=grad_clip)
    return params, opt_state, loss


@functools.partial(jax.jit, static_argnums=(1,))
def _values(params, cfg: DFPConfig, state, meas, goal, valid_mask):
    u = action_values(params, cfg, state[None], meas[None], goal[None])[0]
    return jnp.where(valid_mask, u, -jnp.inf)


class MRSchAgent:
    """DFP-based multi-resource scheduling agent."""

    def __init__(self, resources: Sequence[ResourceSpec],
                 config: AgentConfig = AgentConfig()):
        self.resources = list(resources)
        self.config = config
        names = tuple(r.name for r in self.resources)
        caps = tuple(r.capacity for r in self.resources)
        self.enc = EncodingConfig(window=config.window, resource_names=names,
                                  capacities=caps)
        self.dfp = DFPConfig(
            state_dim=self.enc.state_dim,
            n_measurements=len(names),
            n_actions=config.window,
            offsets=config.offsets,
            temporal_weights=config.temporal_weights,
            state_module=config.state_module,
            state_hidden=config.state_hidden,
            state_out=config.state_out,
            module_hidden=config.module_hidden,
        )
        key = jax.random.PRNGKey(config.seed)
        self.params = init_params(key, self.dfp)
        self.opt_state = adam_init(self.params)
        self.replay = ReplayBuffer(config.offsets, config.buffer_rows)
        self.recorder = EpisodeRecorder()
        self.rng = np.random.default_rng(config.seed)
        self.epsilon = config.eps_start
        self.training = False
        self.losses: List[float] = []
        self.goal_log: List[np.ndarray] = []

    # ---------------------------------------------------------------- policy
    def select(self, ctx: SchedContext) -> int:
        state = encode_state(self.enc, ctx)
        meas = encode_measurement(self.enc, ctx)
        goal = goal_vector(ctx, self.enc.resource_names, self.enc.capacities)
        self.goal_log.append(goal)
        n_valid = min(len(ctx.window), self.config.window)
        if self.training and self.rng.uniform() < self.epsilon:
            action = int(self.rng.integers(0, n_valid))
        else:
            mask = np.zeros(self.config.window, bool)
            mask[:n_valid] = True
            u = _values(self.params, self.dfp, jnp.asarray(state),
                        jnp.asarray(meas), jnp.asarray(goal),
                        jnp.asarray(mask))
            action = int(np.argmax(np.asarray(u)))
        if self.training:
            self.recorder.record(state, meas, goal, action)
        return action

    def select_batch(self, ctxs: Sequence[SchedContext]) -> np.ndarray:
        """Greedy actions for N pending decisions in ONE jitted forward.

        Used by ``repro.sim.vector.VectorSimulator`` to amortize the
        per-call dispatch overhead across environments.  Evaluation only:
        the episode recorder and the epsilon schedule are per-trajectory
        state, so interleaving N environments through them would corrupt
        the DFP future-measurement targets.
        """
        if self.training:
            raise RuntimeError(
                "select_batch is evaluation-only: training interleaves N "
                "environments through one episode recorder, corrupting the "
                "future-measurement targets; train with Simulator.run per "
                "trace instead")
        n = len(ctxs)
        sd, m, a = self.enc.state_dim, self.enc.n_resources, self.config.window
        # One packed row per decision ([state | meas | goal | valid]) so a
        # round costs a single host->device transfer.  Width is padded up to
        # a power of two so the jit cache sees a small, fixed set of shapes
        # as environments finish at different times; padded rows are valid
        # everywhere and their actions are discarded.
        width = 1 << max(n - 1, 0).bit_length()
        packed = np.zeros((width, sd + 2 * m + a), dtype=np.float32)
        packed[n:, sd + 2 * m:] = 1.0
        for i, c in enumerate(ctxs):
            packed[i, :sd] = encode_state(self.enc, c)
            packed[i, sd:sd + m] = encode_measurement(self.enc, c)
            goal = goal_vector(c, self.enc.resource_names,
                               self.enc.capacities)
            packed[i, sd + m:sd + 2 * m] = goal
            self.goal_log.append(goal)
            packed[i, sd + 2 * m:sd + 2 * m + min(len(c.window), a)] = 1.0
        acts = greedy_actions_packed(self.params, self.dfp,
                                     jnp.asarray(packed))
        return np.asarray(acts)[:n].astype(np.int32)

    # ---------------------------------------------------------------- train
    def end_episode(self) -> Optional[float]:
        """Flush the recorded episode, run gradient steps, decay epsilon."""
        ep = self.recorder.finish()
        if ep is not None:
            self.replay.add(ep)
        if not self.training or self.replay.rows < self.config.batch_size:
            return None
        total = 0.0
        for _ in range(self.config.grad_steps_per_episode):
            batch = self.replay.sample(self.rng, self.config.batch_size)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, loss = _train_step(
                self.dfp, self.params, self.opt_state, batch,
                self.config.lr, self.config.grad_clip)
            total += float(loss)
        mean_loss = total / self.config.grad_steps_per_episode
        self.losses.append(mean_loss)
        self.epsilon = max(self.config.eps_min,
                           self.epsilon * self.config.eps_decay)
        return mean_loss

    # ---------------------------------------------------------------- io
    def save(self, path: str) -> None:
        flat, treedef = jax.tree_util.tree_flatten(self.params)
        np.savez(path, n=len(flat), epsilon=self.epsilon,
                 **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})

    def load(self, path: str) -> None:
        data = np.load(path)
        flat = [jnp.asarray(data[f"p{i}"]) for i in range(int(data["n"]))]
        treedef = jax.tree_util.tree_structure(self.params)
        self.params = jax.tree_util.tree_unflatten(treedef, flat)
        self.epsilon = float(data["epsilon"])
        self.opt_state = adam_init(self.params)
