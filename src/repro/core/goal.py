"""Dynamic resource prioritizing (paper §III-B, Eq. 1).

    r_j = sum_i P_ij * t_i / sum_j sum_i P_ij * t_i

summed over ALL jobs in the system — queued jobs (t_i = user walltime
estimate) and running jobs (t_i = remaining walltime estimate).  r_j is the
normalized ideal completion time of resource j's outstanding demand: the
fiercer the contention for a resource, the larger its goal weight.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..sim.simulator import SchedContext


def goal_vector(ctx: SchedContext, resource_names: Sequence[str],
                capacities: Sequence[int]) -> np.ndarray:
    caps = np.maximum(np.asarray(capacities, dtype=np.float64), 1.0)
    R = len(resource_names)
    demand_time = np.zeros(R, dtype=np.float64)

    # Queued jobs (full queue, not just the window): user walltime estimate.
    # Built as one (J, R) matvec — this runs on every scheduling decision,
    # so per-job array construction would dominate the decision hot path.
    queued = ctx.queue if ctx.queue is not None else ctx.window
    if queued:
        dem = np.array([[j.demands.get(n, 0) for n in resource_names]
                        for j in queued], dtype=np.float64)
        wall = np.array([j.walltime for j in queued], dtype=np.float64)
        demand_time += wall @ dem / caps

    # Running jobs: remaining estimated time.
    running = ctx.cluster.running_jobs()
    if running:
        dem = np.array([[rj.job.demands.get(n, 0) for n in resource_names]
                        for rj in running], dtype=np.float64)
        rem = np.array([max(rj.est_end - ctx.now, 0.0) for rj in running],
                       dtype=np.float64)
        demand_time += rem @ dem / caps

    total = demand_time.sum()
    if total <= 0:
        return np.full(R, 1.0 / R, dtype=np.float32)
    return (demand_time / total).astype(np.float32)
