"""Dynamic resource prioritizing (paper §III-B, Eq. 1).

    r_j = sum_i P_ij * t_i / sum_j sum_i P_ij * t_i

summed over ALL jobs in the system — queued jobs (t_i = user walltime
estimate) and running jobs (t_i = remaining walltime estimate).  r_j is the
normalized ideal completion time of resource j's outstanding demand: the
fiercer the contention for a resource, the larger its goal weight.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..sim.simulator import SchedContext


def ctx_goal(ctx: SchedContext, resource_names: Sequence[str]) -> np.ndarray:
    """Eq. (1) goal vector against the context's OWN cluster capacities.

    Identical to using the reference capacities on the homogeneous
    cluster; on scaled-down training environments (see
    ``repro.workloads.sweep.build_train_mix``) it keeps the contention
    normalization honest for that environment.  The capacity array is
    cached on the cluster instance — this runs on every decision, for
    the agent's sequential/batched paths and the serving layer alike.
    """
    names = tuple(resource_names)
    cache = ctx.cluster.__dict__.setdefault("_goal_caps", {})
    cached = cache.get(names)
    if cached is None:
        caps = ctx.cluster.capacities
        cached = cache[names] = np.maximum(
            np.asarray([caps[n] for n in names], np.float64), 1.0)
    return goal_vector(ctx, names, cached)


def goal_vector(ctx: SchedContext, resource_names: Sequence[str],
                capacities: Sequence[int]) -> np.ndarray:
    names = tuple(resource_names)
    R = len(names)
    rng_r = range(R)
    acc = [0.0] * R

    # Queued jobs (full queue, not just the window): user walltime estimate.
    # This runs on every scheduling decision, so the per-job demand rows
    # come from Job.demand_row's instance cache and accumulate in plain
    # Python floats — numpy per-job ops would pay ~1us dispatch each.
    queued = ctx.queue if ctx.queue is not None else ctx.window
    if queued:
        for j in queued:
            w = j.walltime
            row = j.demand_row(names)
            for r in rng_r:
                acc[r] += w * row[r]

    # Running jobs: remaining estimated time.
    now = ctx.now
    for rj in ctx.cluster.running.values():
        rem = rj.est_end - now
        if rem > 0.0:
            row = rj.job.demand_row(names)
            for r in rng_r:
                acc[r] += rem * row[r]

    if isinstance(capacities, np.ndarray) and capacities.dtype == np.float64:
        caps = np.maximum(capacities, 1.0)   # hot path: no list conversion
    else:
        caps = np.maximum(np.asarray(capacities, dtype=np.float64), 1.0)
    demand_time = np.asarray(acc, dtype=np.float64) / caps
    total = demand_time.sum()
    if total <= 0:
        return np.full(R, 1.0 / R, dtype=np.float32)
    return (demand_time / total).astype(np.float32)
