"""The paper's primary contribution: the MRSch DFP scheduling agent."""
from .agent import AgentConfig, MRSchAgent
from .dfp import (DFPConfig, action_values, greedy_action,
                  greedy_actions_packed, init_params, loss_fn, predict)
from .encoding import (EncodingConfig, decision_row_dim, encode_decision_row,
                       encode_measurement, encode_state, encoding_for,
                       pad_decision_rows)
from .goal import ctx_goal, goal_vector
from .policies import FCFSPolicy, GAConfig, GAOptimizer, ScalarRLConfig, ScalarRLPolicy
from .policy_api import Policy, WindowPolicy, supports_batch, supports_device
from .replay import Episode, EpisodeRecorder, ReplayBuffer, VectorEpisodeRecorder
from .train import (EnvSlot, TrainConfig, TrainLog, evaluate,
                    slots_from_jobsets, train_agent, train_agent_vectorized)

__all__ = [
    "AgentConfig", "MRSchAgent", "DFPConfig", "action_values", "greedy_action",
    "greedy_actions_packed", "init_params", "loss_fn", "predict", "EncodingConfig", "encode_measurement",
    "encode_state", "encoding_for", "decision_row_dim", "encode_decision_row",
    "pad_decision_rows", "ctx_goal", "goal_vector",
    "Policy", "WindowPolicy", "supports_batch", "supports_device",
    "FCFSPolicy", "GAConfig",
    "GAOptimizer", "ScalarRLConfig", "ScalarRLPolicy", "Episode",
    "EpisodeRecorder", "ReplayBuffer", "VectorEpisodeRecorder",
    "EnvSlot", "TrainConfig", "TrainLog", "evaluate", "slots_from_jobsets",
    "train_agent", "train_agent_vectorized",
]
