"""The paper's primary contribution: the MRSch DFP scheduling agent."""
from .agent import AgentConfig, MRSchAgent
from .dfp import (DFPConfig, action_values, greedy_action,
                  greedy_actions_packed, init_params, loss_fn, predict)
from .encoding import EncodingConfig, encode_measurement, encode_state, encoding_for
from .goal import goal_vector
from .policies import FCFSPolicy, GAConfig, GAOptimizer, ScalarRLConfig, ScalarRLPolicy
from .replay import Episode, EpisodeRecorder, ReplayBuffer
from .train import TrainLog, evaluate, train_agent

__all__ = [
    "AgentConfig", "MRSchAgent", "DFPConfig", "action_values", "greedy_action",
    "greedy_actions_packed", "init_params", "loss_fn", "predict", "EncodingConfig", "encode_measurement",
    "encode_state", "encoding_for", "goal_vector", "FCFSPolicy", "GAConfig",
    "GAOptimizer", "ScalarRLConfig", "ScalarRLPolicy", "Episode",
    "EpisodeRecorder", "ReplayBuffer", "TrainLog", "evaluate", "train_agent",
]
