from ..sim.lifecycle import DrainEvent, FaultSchedule
from .drift import (DriftPhase, DriftSchedule, PhaseResult, apply_drift,
                    run_phases, segment_jobs, step_schedule)
from .jobsets import Curriculum, build_curriculum, real_jobsets, sampled_jobsets, synthetic_jobsets
from .registry import (ScenarioSpec, build_jobs, build_many, get_scenario,
                       register, register_swf, scenario_names)
from .scenarios import SCENARIOS, build_scenarios, derive_scenario, with_power
from .sweep import (SweepTask, build_sweep, build_train_mix, run_sweep,
                    scale_resources)
from .theta import THETA_BB_UNITS, THETA_NODES, ThetaConfig, generate_trace, jobs_from_swf

__all__ = [
    "Curriculum", "build_curriculum", "real_jobsets", "sampled_jobsets",
    "synthetic_jobsets", "SCENARIOS", "build_scenarios", "derive_scenario",
    "with_power", "SweepTask", "build_sweep", "build_train_mix", "run_sweep",
    "scale_resources",
    "DriftPhase", "DriftSchedule", "PhaseResult", "apply_drift",
    "run_phases", "segment_jobs", "step_schedule",
    "DrainEvent", "FaultSchedule",
    "ScenarioSpec", "build_jobs", "build_many", "get_scenario",
    "register", "register_swf", "scenario_names",
    "THETA_BB_UNITS", "THETA_NODES", "ThetaConfig",
    "generate_trace", "jobs_from_swf",
]
