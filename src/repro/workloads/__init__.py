from .jobsets import Curriculum, build_curriculum, real_jobsets, sampled_jobsets, synthetic_jobsets
from .scenarios import SCENARIOS, build_scenarios, derive_scenario, with_power
from .sweep import (SweepTask, build_sweep, build_train_mix, run_sweep,
                    scale_resources)
from .theta import THETA_BB_UNITS, THETA_NODES, ThetaConfig, generate_trace, jobs_from_swf

__all__ = [
    "Curriculum", "build_curriculum", "real_jobsets", "sampled_jobsets",
    "synthetic_jobsets", "SCENARIOS", "build_scenarios", "derive_scenario",
    "with_power", "SweepTask", "build_sweep", "build_train_mix", "run_sweep",
    "scale_resources",
    "THETA_BB_UNITS", "THETA_NODES", "ThetaConfig",
    "generate_trace", "jobs_from_swf",
]
