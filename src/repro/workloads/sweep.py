"""Scenario-sweep harness: scenarios x seeds, sequential or vectorized.

The paper's results (§V) come from sweeping a policy across workload
scenarios S1-S10 with multiple trace seeds.  ``build_sweep`` materializes
the (scenario, seed) task grid; ``run_sweep`` evaluates one policy over it
either one trace at a time or through the batched
``repro.sim.VectorSimulator`` rollout engine, and reports decision
throughput either way so the two modes can be compared apples-to-apples.
``build_train_mix`` deals the same grid across the lockstep lanes of the
vectorized trainer (``repro.core.train.train_agent_vectorized``) —
optionally with scaled-down resource variants per lane — so one training
batch spans heterogeneous traces, seeds, and contention regimes
(exercising the paper's §III-B dynamic goal vectors heterogeneously).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.train import EnvSlot
from ..sim.cluster import ResourceSpec
from ..sim.job import Job
from ..sim.simulator import SimConfig, SimResult, Simulator
from ..sim.vector import VectorSimulator
from .scenarios import build_scenarios
from .theta import ThetaConfig


@dataclass(frozen=True)
class SweepTask:
    scenario: str
    seed: int


def build_sweep(cfg: ThetaConfig, scenarios: Sequence[str] = ("S1", "S2",
                "S3", "S4", "S5"), seeds: Sequence[int] = (1, 2, 3),
                power: bool = False) -> List[Tuple[SweepTask, List[Job]]]:
    """The (scenario x seed) task grid, each with its derived trace."""
    out: List[Tuple[SweepTask, List[Job]]] = []
    for seed in seeds:
        sets = build_scenarios(cfg, names=scenarios, power=power, seed=seed)
        for name in scenarios:
            out.append((SweepTask(name, seed), sets[name]))
    return out


def scale_resources(resources: Sequence[ResourceSpec],
                    scale: float) -> List[ResourceSpec]:
    """Shrink a cluster spec (same resources, ``scale``x the units)."""
    if not 0.0 < scale <= 1.0:
        raise ValueError(f"scale must be in (0, 1], got {scale}")
    return [ResourceSpec(r.name, max(1, round(r.capacity * scale)), r.unit)
            for r in resources]


def build_train_mix(cfg: ThetaConfig,
                    scenarios: Sequence[str] = ("S1", "S2", "S3", "S4", "S5"),
                    seeds: Sequence[int] = (1, 2, 3), n_envs: int = 8,
                    power: bool = False,
                    resource_scales: Optional[Sequence[float]] = None
                    ) -> List[EnvSlot]:
    """Heterogeneous lane assignments for the vectorized trainer.

    Builds the (scenario x seed) trace grid and deals it round-robin
    across ``n_envs`` lockstep lanes, so one training batch mixes
    different workload scenarios and trace seeds.  ``resource_scales``
    optionally cycles scaled-down cluster variants across the lanes
    (e.g. ``(1.0, 0.75, 0.5)``), diversifying contention — and therefore
    the Eq. (1) goal vectors the agent learns to condition on — within a
    single batch.  The agent must be built on the unscaled ``cfg``
    resources; smaller lanes are padded by the state encoding.
    """
    tasks = build_sweep(cfg, scenarios=scenarios, seeds=seeds, power=power)
    n_envs = max(1, min(int(n_envs), len(tasks)))
    base = cfg.resources(
        power_budget_kw=cfg.default_power_budget_kw() if power else None)
    slots: List[EnvSlot] = []
    for i in range(n_envs):
        res = base
        tag = f"env{i}"
        if resource_scales:
            scale = resource_scales[i % len(resource_scales)]
            res = scale_resources(base, scale)
            tag = f"env{i}@{scale:g}x"
        slots.append(EnvSlot(jobsets=[], resources=res, tag=tag))
    for k, (task, jobs) in enumerate(tasks):
        slots[k % n_envs].jobsets.append(
            (f"{task.scenario}/seed{task.seed}", jobs))
    return slots


def _row(task: SweepTask, result: SimResult) -> Dict:
    return {
        "scenario": task.scenario,
        "seed": task.seed,
        "decisions": result.decisions,
        "n_unstarted": result.n_unstarted,
        **{k: round(float(v), 4) for k, v in result.metrics.as_row().items()},
    }


def run_sweep(resources: Sequence[ResourceSpec],
              tasks: Sequence[Tuple[SweepTask, List[Job]]], policy,
              config: Optional[SimConfig] = None, vector: int = 0) -> Dict:
    """Evaluate ``policy`` over every sweep task.

    vector=0/1 runs traces one at a time (the classic loop); vector=N
    advances N environments in lockstep with batched policy inference.
    Tasks beyond N are processed in successive groups of N.  ``config``
    comes from ``SimConfig.for_engine`` (window/backfill live there, not
    in per-harness kwargs); it defaults to the engine implied by
    ``vector``.
    """
    engine = "vector" if vector and vector > 1 else "sequential"
    sim_cfg = config if config is not None else SimConfig.for_engine(engine)
    t0 = time.perf_counter()
    results: List[SimResult] = []
    vector_stats: List[Dict] = []
    if vector and vector > 1:
        for i in range(0, len(tasks), vector):
            chunk = tasks[i:i + vector]
            vec = VectorSimulator.from_jobsets(
                resources, [jobs for _, jobs in chunk], policy, sim_cfg)
            results.extend(vec.run())
            vector_stats.append(vec.stats.as_dict())
    else:
        for _, jobs in tasks:
            results.append(Simulator(resources, jobs, policy, sim_cfg).run())
    wall = time.perf_counter() - t0
    decisions = sum(r.decisions for r in results)
    out = {
        "mode": f"vector{vector}" if vector and vector > 1 else "sequential",
        "n_tasks": len(tasks),
        "wall_seconds": round(wall, 4),
        "decisions": decisions,
        "decisions_per_sec": round(decisions / max(wall, 1e-9), 2),
        "tasks": [_row(t, r) for (t, _), r in zip(tasks, results)],
    }
    if vector_stats:
        out["vector_stats"] = vector_stats
    return out
