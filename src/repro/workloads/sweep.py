"""Scenario-sweep harness: scenarios x seeds, sequential or vectorized.

The paper's results (§V) come from sweeping a policy across workload
scenarios S1-S10 with multiple trace seeds.  ``build_sweep`` materializes
the (scenario, seed) task grid; ``run_sweep`` evaluates one policy over it
either one trace at a time or through the batched
``repro.sim.VectorSimulator`` rollout engine, and reports decision
throughput either way so the two modes can be compared apples-to-apples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from ..sim.cluster import ResourceSpec
from ..sim.job import Job
from ..sim.simulator import SimConfig, SimResult, Simulator
from ..sim.vector import VectorSimulator
from .scenarios import build_scenarios
from .theta import ThetaConfig


@dataclass(frozen=True)
class SweepTask:
    scenario: str
    seed: int


def build_sweep(cfg: ThetaConfig, scenarios: Sequence[str] = ("S1", "S2",
                "S3", "S4", "S5"), seeds: Sequence[int] = (1, 2, 3),
                power: bool = False) -> List[Tuple[SweepTask, List[Job]]]:
    """The (scenario x seed) task grid, each with its derived trace."""
    out: List[Tuple[SweepTask, List[Job]]] = []
    for seed in seeds:
        sets = build_scenarios(cfg, names=scenarios, power=power, seed=seed)
        for name in scenarios:
            out.append((SweepTask(name, seed), sets[name]))
    return out


def _row(task: SweepTask, result: SimResult) -> Dict:
    return {
        "scenario": task.scenario,
        "seed": task.seed,
        "decisions": result.decisions,
        "n_unstarted": result.n_unstarted,
        **{k: round(float(v), 4) for k, v in result.metrics.as_row().items()},
    }


def run_sweep(resources: Sequence[ResourceSpec],
              tasks: Sequence[Tuple[SweepTask, List[Job]]], policy,
              window: int = 10, backfill: bool = True,
              vector: int = 0) -> Dict:
    """Evaluate ``policy`` over every sweep task.

    vector=0/1 runs traces one at a time (the classic loop); vector=N
    advances N environments in lockstep with batched policy inference.
    Tasks beyond N are processed in successive groups of N.
    """
    sim_cfg = SimConfig(window=window, backfill=backfill)
    t0 = time.perf_counter()
    results: List[SimResult] = []
    vector_stats: List[Dict] = []
    if vector and vector > 1:
        for i in range(0, len(tasks), vector):
            chunk = tasks[i:i + vector]
            vec = VectorSimulator.from_jobsets(
                resources, [jobs for _, jobs in chunk], policy, sim_cfg)
            results.extend(vec.run())
            vector_stats.append(vec.stats.as_dict())
    else:
        for _, jobs in tasks:
            results.append(Simulator(resources, jobs, policy, sim_cfg).run())
    wall = time.perf_counter() - t0
    decisions = sum(r.decisions for r in results)
    out = {
        "mode": f"vector{vector}" if vector and vector > 1 else "sequential",
        "n_tasks": len(tasks),
        "wall_seconds": round(wall, 4),
        "decisions": decisions,
        "decisions_per_sec": round(decisions / max(wall, 1e-9), 2),
        "tasks": [_row(t, r) for (t, _), r in zip(tasks, results)],
    }
    if vector_stats:
        out["vector_stats"] = vector_stats
    return out
