"""Named, parameterized scenario registry (ROADMAP: "as many scenarios
as you can imagine").

One lookup point for every workload the repo can replay, so sweeps,
benches, the evaluation matrix (``repro.eval.matrix``) and CI all speak
the same scenario names:

* the paper's S1–S10 contention/power families (Table III, §V-E),
* the raw Theta-like base trace,
* real-trace replay via SWF files (:func:`register_swf`),
* new synthetic families — pronounced diurnal cycles, bursty campaign
  submissions, size-skewed mixes,
* drifting workloads (§V-D) whose distribution shifts mid-trace via
  ``drift.DriftSchedule`` transformers.

Every scenario builds deterministically from ``(ThetaConfig, seed)``; the
registry is import-time populated and extensible at runtime via
:func:`register` (plugins, tests, SWF drop-ins).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..sim.job import Job
from ..sim.lifecycle import DrainEvent, FaultSchedule
from .drift import DriftPhase, DriftSchedule, apply_drift, step_schedule
from .scenarios import SCENARIOS as _PAPER_SCENARIOS
from .scenarios import build_scenarios, with_power
from .theta import ThetaConfig, generate_trace, jobs_from_swf

Builder = Callable[..., List[Job]]     # (cfg, seed, **params) -> jobs


@dataclass(frozen=True)
class ScenarioSpec:
    """A named, parameterized workload family.

    ``build(cfg, seed, **params)`` produces the trace; ``drift`` (when
    set) is applied afterwards with a seed derived from ``seed``; then
    ``power`` attaches §V-E power profiles.  ``faults`` is NOT applied to
    the trace — it is the scenario's deterministic node-outage plan, and
    engines consume it directly (``Simulator(..., faults=...)``); runners
    that build jobs from a name must forward ``get_scenario(name).faults``
    alongside.  ``tags`` support filtered selection (e.g. every "drift"
    scenario for the adaptation bench).
    """
    name: str
    description: str
    build: Builder
    family: str = "synthetic"  # paper|base|synthetic|drift|workflow|faulty|swf
    params: Dict[str, object] = field(default_factory=dict)
    drift: Optional[DriftSchedule] = None
    power: bool = False
    faults: Optional[FaultSchedule] = None
    tags: Tuple[str, ...] = ()


_REGISTRY: Dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec, overwrite: bool = False) -> ScenarioSpec:
    if not overwrite and spec.name in _REGISTRY:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}") \
            from None


def scenario_names(family: Optional[str] = None,
                   tag: Optional[str] = None) -> List[str]:
    """Registered names, optionally filtered by family and/or tag."""
    out = []
    for name, spec in sorted(_REGISTRY.items()):
        if family is not None and spec.family != family:
            continue
        if tag is not None and tag not in spec.tags:
            continue
        out.append(name)
    return out


def build_jobs(name: str, cfg: ThetaConfig, seed: int = 1,
               **overrides) -> List[Job]:
    """Materialize one scenario's trace, deterministically for a seed."""
    spec = get_scenario(name)
    params = {**spec.params, **overrides}
    jobs = spec.build(cfg, seed, **params)
    if spec.drift is not None:
        jobs = apply_drift(jobs, spec.drift, cfg, seed=seed + 101)
    if spec.power:
        jobs = with_power(jobs, cfg, seed=seed + 7)
    return jobs


def build_many(names: Sequence[str], cfg: ThetaConfig,
               seed: int = 1) -> Dict[str, List[Job]]:
    return {n: build_jobs(n, cfg, seed=seed) for n in names}


# ------------------------------------------------------------------ builders
def _reseeded(cfg: ThetaConfig, seed: int) -> ThetaConfig:
    """Per-(scenario, seed) trace variant of the base config."""
    return replace(cfg, seed=cfg.seed + 7919 * seed)


def _paper(cfg: ThetaConfig, seed: int, scenario: str = "S1") -> List[Job]:
    return build_scenarios(cfg, names=(scenario,), seed=seed)[scenario]


def _theta_base(cfg: ThetaConfig, seed: int) -> List[Job]:
    return generate_trace(_reseeded(cfg, seed))


def _diurnal(cfg: ThetaConfig, seed: int, amplitude: float = 0.95,
             weekend_factor: float = 0.35) -> List[Job]:
    """Pronounced day/night + weekend arrival cycles (queue breathes)."""
    return generate_trace(replace(_reseeded(cfg, seed),
                                  diurnal_amplitude=amplitude,
                                  weekend_factor=weekend_factor))


def _bursty(cfg: ThetaConfig, seed: int, campaign_mean: float = 8.0,
            within_gap_s: float = 120.0) -> List[Job]:
    """Campaign submissions: jobs arrive in tight bursts with long gaps.

    Re-times the base trace's jobs: arrivals are regrouped into campaigns
    of geometric size (mean ``campaign_mean``), ~``within_gap_s`` apart
    inside a campaign, with the inter-campaign gaps stretched so the
    total span is preserved (same load, very different queue dynamics).
    """
    jobs = sorted(generate_trace(_reseeded(cfg, seed)),
                  key=lambda j: (j.submit, j.jid))
    if len(jobs) < 2:
        return jobs
    rng = np.random.default_rng(1000 + seed)
    span = jobs[-1].submit - jobs[0].submit
    sizes: List[int] = []
    while sum(sizes) < len(jobs):
        sizes.append(1 + rng.geometric(1.0 / campaign_mean))
    n_campaigns = len(sizes)
    in_burst = sum(min(s, len(jobs)) for s in sizes) * within_gap_s
    gap_mean = max((span - in_burst) / max(n_campaigns, 1), within_gap_s)
    out, t, k = [], jobs[0].submit, 0
    for s in sizes:
        for _ in range(s):
            if k >= len(jobs):
                break
            nj = jobs[k].copy()
            nj.submit = t
            out.append(nj)
            t += rng.exponential(within_gap_s)
            k += 1
        t += rng.exponential(gap_mean)
    return out


def _flood(cfg: ThetaConfig, seed: int, span_s: float = 1800.0) -> List[Job]:
    """Queue flood: the whole trace submits within ``span_s`` seconds.

    Re-times the base trace's submits uniformly into a short span, so
    the waiting queue holds hundreds of jobs at once from the first
    scheduling pass — the regime where the classic W-window encoding is
    blind to nearly all of the backlog (``truncated_jobs`` explodes) and
    the queue-as-tokens attention encoder has signal to exploit.
    """
    jobs = generate_trace(_reseeded(cfg, seed))
    rng = np.random.default_rng(2000 + seed)
    t0 = min(j.submit for j in jobs) if jobs else 0.0
    out = []
    for j, dt in zip(jobs, rng.uniform(0.0, span_s, len(jobs))):
        nj = j.copy()
        nj.submit = t0 + float(dt)
        out.append(nj)
    return sorted(out, key=lambda j: (j.submit, j.jid))


def _compressed(cfg: ThetaConfig, seed: int, factor: float = 6.0) -> List[Job]:
    """Sustained oversubscription: submit times compressed ``factor``x.

    Unlike the one-shot flood, arrivals keep their relative pattern —
    the queue builds steadily to a deep sustained backlog instead of one
    spike, exercising long-queue dynamics across the whole trace.
    """
    jobs = generate_trace(_reseeded(cfg, seed))
    t0 = min(j.submit for j in jobs) if jobs else 0.0
    out = []
    for j in jobs:
        nj = j.copy()
        nj.submit = t0 + (j.submit - t0) / factor
        out.append(nj)
    return sorted(out, key=lambda j: (j.submit, j.jid))


_SKEW_SMALL = (0.30, 0.24, 0.18, 0.12, 0.07, 0.04, 0.03, 0.01, 0.007, 0.003)
_SKEW_LARGE = (0.02, 0.03, 0.04, 0.05, 0.08, 0.12, 0.18, 0.22, 0.16, 0.10)


def _size_skew(cfg: ThetaConfig, seed: int,
               weights: Sequence[float] = _SKEW_SMALL) -> List[Job]:
    return generate_trace(replace(_reseeded(cfg, seed),
                                  size_weights=tuple(weights)))


def _drifted_paper(cfg: ThetaConfig, seed: int,
                   scenario: str = "S2") -> List[Job]:
    """Base jobs for drift scenarios: a paper family pre-drift."""
    return _paper(cfg, seed, scenario=scenario)


def _workflow_pipelines(cfg: ThetaConfig, seed: int, chain_len: int = 4,
                        workflow_frac: float = 0.5,
                        think_s: float = 300.0) -> List[Job]:
    """Linear pipeline DAGs: stage k depends on stage k-1.

    Walks the base trace in submit order and, with probability
    ``workflow_frac``, folds the next ``chain_len`` jobs into one
    pipeline: all stages are submitted with the root (the user submits
    the whole workflow at once) but each stays HELD until its predecessor
    finishes plus ``think_s`` of post-processing think time.
    """
    jobs = sorted(generate_trace(_reseeded(cfg, seed)),
                  key=lambda j: (j.submit, j.jid))
    rng = np.random.default_rng(5000 + seed)
    out = [j.copy() for j in jobs]
    i = 0
    while i + chain_len <= len(out):
        if rng.uniform() < workflow_frac:
            root = out[i]
            for k in range(1, chain_len):
                stage = out[i + k]
                stage.deps = (out[i + k - 1].jid,)
                stage.think_time = float(think_s)
                stage.submit = root.submit
            i += chain_len
        else:
            i += 1
    return sorted(out, key=lambda j: (j.submit, j.jid))


def _workflow_ensembles(cfg: ThetaConfig, seed: int, width: int = 4,
                        ensemble_frac: float = 0.4,
                        think_s: float = 60.0) -> List[Job]:
    """Fan-out/fan-in DAGs: root -> ``width`` members -> collector.

    The ensemble members run concurrently once the root finishes; the
    collector fans in on ALL members (a multi-parent dependency, which a
    linear SWF "preceding job" field cannot express).
    """
    jobs = sorted(generate_trace(_reseeded(cfg, seed)),
                  key=lambda j: (j.submit, j.jid))
    rng = np.random.default_rng(6000 + seed)
    out = [j.copy() for j in jobs]
    group = width + 2
    i = 0
    while i + group <= len(out):
        if rng.uniform() < ensemble_frac:
            root = out[i]
            members = out[i + 1: i + 1 + width]
            collector = out[i + 1 + width]
            for m in members:
                m.deps = (root.jid,)
                m.think_time = float(think_s)
                m.submit = root.submit
            collector.deps = tuple(m.jid for m in members)
            collector.think_time = float(think_s)
            collector.submit = root.submit
            i += group
        else:
            i += 1
    return sorted(out, key=lambda j: (j.submit, j.jid))


def _faulty_jobs(cfg: ThetaConfig, seed: int, fail_fraction: float = 0.2,
                 max_attempts: int = 2) -> List[Job]:
    """Base trace where a fraction of jobs carry mid-run failure points.

    Afflicted jobs fail 1..``max_attempts`` times at uniform positions
    within the runtime before an attempt finally survives, exercising the
    requeue path (and FAILED exhaustion when attempts exceed the
    schedule's ``max_requeues``).
    """
    rng = np.random.default_rng(4000 + seed)
    out = []
    for j in generate_trace(_reseeded(cfg, seed)):
        nj = j.copy()
        if rng.uniform() < fail_fraction:
            k = int(rng.integers(1, max_attempts + 1))
            nj.fail_times = tuple(
                float(f) * nj.runtime
                for f in sorted(rng.uniform(0.15, 0.85, size=k)))
        out.append(nj)
    return out


def register_swf(name: str, path: str, description: str = "",
                 overwrite: bool = False) -> ScenarioSpec:
    """Register a real-trace replay scenario backed by an SWF file.

    The seed is ignored (a real trace has one realization); ``n_nodes``
    clamps per-job demands to the configured cluster.
    """
    def _build(cfg: ThetaConfig, seed: int, **_params) -> List[Job]:
        return jobs_from_swf(path, n_nodes=cfg.n_nodes)

    return register(ScenarioSpec(
        name=name, family="swf", build=_build,
        description=description or f"SWF replay of {path}",
        tags=("swf", "replay")), overwrite=overwrite)


# ------------------------------------------------------------------ defaults
def _register_defaults() -> None:
    for s, (frac, lo_tb, halve) in _PAPER_SCENARIOS.items():
        register(ScenarioSpec(
            name=s, family="paper", build=_paper, params={"scenario": s},
            description=(f"Table III {s}: {frac:.0%} of jobs request BB in "
                         f"[{lo_tb:g}, 285] TB" + (", node demand halved"
                                                   if halve else "")),
            tags=("paper", "table3")))
        s_pow = f"S{int(s[1:]) + 5}"
        register(ScenarioSpec(
            name=s_pow, family="paper", build=_paper,
            params={"scenario": s_pow},
            description=f"§V-E {s_pow}: {s} plus 100–215 W/node power "
                        "profile under the scaled 500 kW budget",
            tags=("paper", "three-resource", "power")))
    register(ScenarioSpec(
        name="theta-base", family="base", build=_theta_base,
        description="Raw Theta-like synthetic trace (Darshan-style BB mix)",
        tags=("base",)))
    register(ScenarioSpec(
        name="diurnal-heavy", family="synthetic", build=_diurnal,
        description="Pronounced diurnal/weekend arrival cycles "
                    "(amplitude 0.95, weekends at 35%)",
        tags=("synthetic", "arrival")))
    register(ScenarioSpec(
        name="bursty-campaigns", family="synthetic", build=_bursty,
        description="Campaign submissions: geometric bursts (~8 jobs, "
                    "~2 min spacing) separated by long idle gaps",
        tags=("synthetic", "arrival")))
    register(ScenarioSpec(
        name="huge-queue-flood", family="synthetic", build=_flood,
        description="Whole trace submitted within 30 min: hundreds of "
                    "jobs waiting at once (window truncation stress)",
        tags=("synthetic", "huge-queue", "arrival")))
    register(ScenarioSpec(
        name="huge-queue-sustained", family="synthetic", build=_compressed,
        description="Submit times compressed 6x: sustained deep backlog "
                    "for the full trace span",
        tags=("synthetic", "huge-queue", "arrival")))
    register(ScenarioSpec(
        name="size-skew-small", family="synthetic", build=_size_skew,
        params={"weights": _SKEW_SMALL},
        description="Job-size mix skewed toward small jobs "
                    "(capacity fragmentation regime)",
        tags=("synthetic", "size")))
    register(ScenarioSpec(
        name="size-skew-large", family="synthetic", build=_size_skew,
        params={"weights": _SKEW_LARGE},
        description="Job-size mix skewed toward capability-class jobs "
                    "(blocking/backfill regime)",
        tags=("synthetic", "size")))
    register(ScenarioSpec(
        name="drift-bb-surge", family="drift", build=_drifted_paper,
        params={"scenario": "S1"},
        drift=step_schedule(at=0.5, bb_fraction=0.85, bb_scale=1.25),
        description="§V-D shift: S1 trace whose BB demand surges at "
                    "mid-trace (85% of jobs request BB, sizes +25%)",
        tags=("drift", "bb")))
    register(ScenarioSpec(
        name="drift-arrival-ramp", family="drift", build=_drifted_paper,
        params={"scenario": "S2"},
        drift=DriftSchedule(mode="ramp", phases=(
            DriftPhase(start=0.0),
            DriftPhase(start=1.0, rate_scale=2.5))),
        description="§V-D shift: S2 trace whose arrival rate ramps to "
                    "2.5x over the trace span",
        tags=("drift", "arrival")))
    register(ScenarioSpec(
        name="drift-node-shift", family="drift", build=_drifted_paper,
        params={"scenario": "S3"},
        drift=DriftSchedule(phases=(
            DriftPhase(start=0.0),
            DriftPhase(start=0.4, node_scale=1.6, bb_fraction=0.2),
            DriftPhase(start=0.8, node_scale=0.7, bb_fraction=0.8))),
        description="§V-D shift: S3 trace flipping from CPU-heavy "
                    "(nodes x1.6, BB 20%) to BB-heavy (nodes x0.7, BB 80%)",
        tags=("drift", "node", "bb")))
    register(ScenarioSpec(
        name="workflow-pipelines", family="workflow",
        build=_workflow_pipelines,
        description="Half the trace folded into 4-stage pipeline DAGs "
                    "(submit-with-root, 5 min think time between stages)",
        tags=("workflow", "deps")))
    register(ScenarioSpec(
        name="workflow-ensembles", family="workflow",
        build=_workflow_ensembles,
        description="Fan-out/fan-in ensembles: root -> 4 members -> "
                    "collector (multi-parent fan-in joins)",
        tags=("workflow", "deps")))
    register(ScenarioSpec(
        name="faulty-jobs", family="faulty", build=_faulty_jobs,
        description="20% of jobs fail mid-run up to 2 times before an "
                    "attempt survives (requeue stress)",
        tags=("faulty", "requeue")))
    register(ScenarioSpec(
        name="faulty-drain", family="faulty", build=_theta_base,
        faults=FaultSchedule(relative=True, drains=(
            DrainEvent(time=0.30, resource="node", unit_frac=0.25,
                       duration=0.15),
            DrainEvent(time=0.60, resource="bb", unit_frac=0.30,
                       duration=0.10),
        )),
        description="Base trace under scheduled outages: 25% of nodes "
                    "drain at 30% of the span (15% long), 30% of BB at "
                    "60% (10% long); residents are killed and requeued",
        tags=("faulty", "drain")))
    register(ScenarioSpec(
        name="drift-failure-wave", family="drift", build=_drifted_paper,
        params={"scenario": "S1"},
        drift=DriftSchedule(phases=(
            DriftPhase(start=0.0, fail_fraction=0.0),
            DriftPhase(start=0.4, fail_fraction=0.30),
            DriftPhase(start=0.8, fail_fraction=0.0))),
        description="§V-D-style reliability shift: a mid-trace wave where "
                    "30% of arriving jobs fail once mid-run and requeue",
        tags=("drift", "faulty", "requeue")))


_register_defaults()
