"""Workloads S1–S5 (Table III) and the 3-resource case study S6–S10 (§V-E).

S1–S5 re-draw each job's burst-buffer request from the base trace's request
pool restricted to a range, for a controlled contention sweep:

  S1: 50 % of jobs request BB, sizes in [ 5 TB, 285 TB]
  S2: 75 %                         [ 5 TB, 285 TB]
  S3: 50 %                         [20 TB, 285 TB]
  S4: 75 %                         [20 TB, 285 TB]
  S5: S4 with node requests halved (less CPU contention)

S6–S10 add a power profile to S1–S5 jobs: per-node draw uniform in
100–215 W (KNL 7230 TDP 215 W), system budget 500 kW (scaled
proportionally for reduced clusters).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from ..sim.job import Job
from .theta import THETA_BB_UNITS, ThetaConfig, generate_trace

SCENARIOS = {
    # name: (frac of jobs with BB request, min TB, halve nodes)
    "S1": (0.50, 5.0, False),
    "S2": (0.75, 5.0, False),
    "S3": (0.50, 20.0, False),
    "S4": (0.75, 20.0, False),
    "S5": (0.75, 20.0, True),
}


def _bb_pool_tb(cfg: ThetaConfig, rng: np.random.Generator, lo: float) -> np.ndarray:
    """Empirical-style pool of BB requests in [lo, 285] TB (log-uniform-ish
    heavy tail like the trace's large movers)."""
    raw = 10 ** rng.uniform(math.log10(lo), math.log10(cfg.bb_max_tb), size=4096)
    return raw


def bb_pool_units(cfg: ThetaConfig, rng: np.random.Generator,
                  lo_tb: float = 5.0) -> np.ndarray:
    """Heavy-tailed BB request pool in cluster *units*, clamped to capacity.

    The single source of the scenario-style request distribution: the TB
    range scales with the cluster so mini systems see the same
    *fractional* contention the paper's full system does.  Shared by the
    Table III derivations here and the §V-D drift transformers
    (``drift.apply_drift``) so drifted traces stay in family.
    """
    scale = cfg.bb_units / THETA_BB_UNITS
    unit_tb = 1.26e3 / THETA_BB_UNITS
    tb = _bb_pool_tb(cfg, rng, lo_tb) * scale
    return np.minimum(np.ceil(tb / unit_tb), cfg.bb_units).astype(int)


def derive_scenario(base: List[Job], cfg: ThetaConfig, name: str,
                    seed: int = 1) -> List[Job]:
    frac, lo_tb, halve = SCENARIOS[name]
    # stable per-scenario offset (NOT hash(): str hashing is salted per
    # process, which made benchmark runs non-reproducible across invocations)
    rng = np.random.default_rng(seed + sum(ord(c) for c in name))
    pool = bb_pool_units(cfg, rng, lo_tb)
    jobs = []
    for j in base:
        nj = j.copy()
        if halve:
            nj.demands["node"] = max(1, nj.demands["node"] // 2)
        nj.demands["bb"] = int(rng.choice(pool)) if rng.uniform() < frac else 0
        jobs.append(nj)
    return jobs


def with_power(jobs: List[Job], cfg: ThetaConfig, seed: int = 2,
               idle_w: float = 60.0, lo_w: float = 100.0,
               hi_w: float = 215.0) -> List[Job]:
    """Attach a power demand (kW units) to every job: nodes x per-node watts."""
    rng = np.random.default_rng(seed)
    out = []
    for j in jobs:
        nj = j.copy()
        per_node = rng.uniform(lo_w, hi_w)
        nj.demands["power"] = max(1, int(math.ceil(
            nj.demands["node"] * per_node / 1000.0)))
        out.append(nj)
    return out


def build_scenarios(cfg: ThetaConfig, names: Sequence[str] = ("S1", "S2", "S3", "S4", "S5"),
                    power: bool = False, seed: int = 1) -> Dict[str, List[Job]]:
    base = generate_trace(cfg)
    out = {}
    for name in names:
        key = name
        src = name
        if name.startswith("S") and int(name[1:]) > 5:
            # S6-S10 mirror S1-S5 with power profiles.
            src = f"S{int(name[1:]) - 5}"
            power = True
        jobs = derive_scenario(base, cfg, src, seed=seed)
        if power:
            jobs = with_power(jobs, cfg, seed=seed + 7)
        out[key] = jobs
    return out
