"""Time-varying workload drift (paper §V-D "dynamic resource prioritizing").

MRSch's headline claim is that the DFP agent *adapts* its policy when the
workload mix changes mid-stream.  The static S1–S10 families in
``scenarios.py`` cannot exercise that: every job in a scenario is drawn
from one distribution.  This module makes traces drift over time:

* :class:`DriftPhase` / :class:`DriftSchedule` — a piecewise (or ramped)
  schedule of distribution parameters over the trace span: the fraction
  of jobs requesting burst buffer, a multiplier on BB request sizes, a
  multiplier on node demands, and an arrival-rate multiplier.
* :func:`apply_drift` — transform a job list according to a schedule,
  seeded and deterministic.  Arrival-rate drift warps inter-arrival gaps;
  the per-job fields are redrawn/scaled from the parameters in force at
  the job's (original) position in the trace.
* :func:`segment_jobs` + :func:`run_phases` — the §V-D adaptation
  experiment: split a drifted trace into consecutive phases and walk a
  policy through them via ``VectorSimulator.run``'s ``refill`` hook, so
  each phase yields its own ``SimResult`` and the per-phase metrics show
  whether the policy re-prioritizes after the shift.

Drift *scenarios* (named, buildable traces) live in ``registry.py``; this
module owns the transformation machinery.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..sim.cluster import ResourceSpec
from ..sim.job import Job
from ..sim.simulator import SimConfig, SimResult, Simulator
from ..sim.vector import VectorSimulator
from .scenarios import bb_pool_units
from .theta import ThetaConfig

_MULT_FIELDS = ("bb_scale", "node_scale", "rate_scale")


@dataclass(frozen=True)
class DriftPhase:
    """Distribution parameters in force from ``start`` (fraction of span).

    ``bb_fraction`` — when set, jobs arriving in this phase have their BB
    request *redrawn*: with this probability they get a request from the
    scenario-style heavy-tailed pool, otherwise none.  ``None`` leaves
    the trace's own BB demands untouched.
    ``bb_scale`` / ``node_scale`` — multipliers on BB / node demands.
    ``rate_scale`` — arrival-rate multiplier (>1 compresses gaps).
    ``fail_fraction`` — when set, jobs arriving in this phase are given a
    mid-run failure point (one requeue-triggering fault drawn uniformly
    inside the runtime) with this probability; ``None`` leaves any
    ``fail_times`` already on the trace untouched, ``0.0`` strips them.
    """
    start: float
    bb_fraction: Optional[float] = None
    bb_scale: float = 1.0
    node_scale: float = 1.0
    rate_scale: float = 1.0
    fail_fraction: Optional[float] = None

    def __post_init__(self):
        if not 0.0 <= self.start <= 1.0:
            raise ValueError(f"phase start must be in [0, 1], got {self.start}")
        for name in _MULT_FIELDS:
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")
        if (self.fail_fraction is not None
                and not 0.0 <= self.fail_fraction <= 1.0):
            raise ValueError(
                f"fail_fraction must be in [0, 1], got {self.fail_fraction}")


@dataclass(frozen=True)
class DriftSchedule:
    """Ordered phases over the trace span.

    mode="piecewise" applies each phase's parameters verbatim from its
    start; mode="ramp" linearly interpolates the multipliers between
    consecutive phase starts (``bb_fraction`` interpolates only when both
    endpoints are set).  The first phase must start at 0.
    """
    phases: Tuple[DriftPhase, ...]
    mode: str = "piecewise"

    def __post_init__(self):
        if not self.phases:
            raise ValueError("schedule needs at least one phase")
        starts = [p.start for p in self.phases]
        if starts != sorted(starts) or starts[0] != 0.0:
            raise ValueError("phases must be sorted by start, first at 0.0")
        if self.mode not in ("piecewise", "ramp"):
            raise ValueError(f"unknown drift mode {self.mode!r}")

    # ------------------------------------------------------------- lookup
    def params_at(self, frac: float) -> Dict[str, Optional[float]]:
        """Effective parameters at ``frac`` in [0, 1] of the trace span."""
        frac = min(max(frac, 0.0), 1.0)
        phases = self.phases
        k = 0
        for i, p in enumerate(phases):
            if p.start <= frac:
                k = i
        cur = phases[k]
        out = {f.name: getattr(cur, f.name) for f in fields(cur)
               if f.name != "start"}
        if self.mode == "ramp" and k + 1 < len(phases):
            nxt = phases[k + 1]
            span = nxt.start - cur.start
            w = (frac - cur.start) / span if span > 0 else 1.0
            for name in _MULT_FIELDS:
                out[name] = ((1 - w) * getattr(cur, name)
                             + w * getattr(nxt, name))
            if cur.bb_fraction is not None and nxt.bb_fraction is not None:
                out["bb_fraction"] = ((1 - w) * cur.bb_fraction
                                      + w * nxt.bb_fraction)
            if cur.fail_fraction is not None and nxt.fail_fraction is not None:
                out["fail_fraction"] = ((1 - w) * cur.fail_fraction
                                        + w * nxt.fail_fraction)
        return out


def step_schedule(at: float = 0.5, *, bb_fraction: float = 0.85,
                  bb_scale: float = 1.0, node_scale: float = 1.0,
                  rate_scale: float = 1.0) -> DriftSchedule:
    """The canonical §V-D experiment: one mid-trace distribution shift."""
    return DriftSchedule(phases=(
        DriftPhase(start=0.0),
        DriftPhase(start=at, bb_fraction=bb_fraction, bb_scale=bb_scale,
                   node_scale=node_scale, rate_scale=rate_scale),
    ))


def apply_drift(jobs: Sequence[Job], schedule: DriftSchedule,
                cfg: ThetaConfig, seed: int = 0) -> List[Job]:
    """Transform ``jobs`` per the schedule; deterministic for a seed.

    Phase position is evaluated on the *original* timeline (job rank in
    span), so rate warping never shifts which distribution a job draws
    from.  Returns fresh copies sorted by warped submit time.
    """
    if not jobs:
        return []
    ordered = sorted(jobs, key=lambda j: (j.submit, j.jid))
    rng = np.random.default_rng(seed)
    pool = bb_pool_units(cfg, rng)
    t0 = ordered[0].submit
    span = max(ordered[-1].submit - t0, 1e-9)
    out: List[Job] = []
    warped = t0
    prev = t0
    for j in ordered:
        frac = (j.submit - t0) / span
        p = schedule.params_at(frac)
        warped += (j.submit - prev) / p["rate_scale"]
        prev = j.submit
        nj = j.copy()
        nj.submit = warped
        nj.demands["node"] = min(
            max(1, int(round(nj.demands.get("node", 1) * p["node_scale"]))),
            cfg.n_nodes)
        if p["bb_fraction"] is not None:
            bb = int(rng.choice(pool)) if rng.uniform() < p["bb_fraction"] else 0
        else:
            bb = nj.demands.get("bb", 0)
        nj.demands["bb"] = min(int(round(bb * p["bb_scale"])), cfg.bb_units)
        if p["fail_fraction"] is not None:
            # One mid-run fault per afflicted job; both draws are consumed
            # even when the job stays healthy, so raising fail_fraction
            # only adds failures instead of reshuffling which jobs fail.
            u, at = rng.uniform(), rng.uniform(0.15, 0.85)
            if u < p["fail_fraction"]:
                nj.fail_times = (float(at * nj.runtime),)
            else:
                nj.fail_times = ()
        out.append(nj)
    return out


# ---------------------------------------------------------------- phases
def segment_jobs(jobs: Sequence[Job], n_segments: int,
                 rebase: bool = True) -> List[List[Job]]:
    """Split a trace into consecutive equal-time segments of its span.

    With ``rebase`` each segment's submits are shifted to start at 0 so
    every segment is a self-contained episode (wait/slowdown metrics stay
    comparable across phases).  Empty segments are kept (as empty lists)
    so phase indices always align with the schedule.
    """
    if n_segments < 1:
        raise ValueError("n_segments must be >= 1")
    jobs = sorted(jobs, key=lambda j: (j.submit, j.jid))
    if not jobs:
        return [[] for _ in range(n_segments)]
    t0, t1 = jobs[0].submit, jobs[-1].submit
    span = max(t1 - t0, 1e-9)
    segments: List[List[Job]] = [[] for _ in range(n_segments)]
    for j in jobs:
        k = min(int((j.submit - t0) / span * n_segments), n_segments - 1)
        segments[k].append(j)
    if rebase:
        rebased = []
        for seg in segments:
            base = seg[0].submit if seg else 0.0
            out = []
            for j in seg:
                nj = j.copy()
                nj.submit = j.submit - base
                out.append(nj)
            rebased.append(out)
        segments = rebased
    return segments


@dataclass
class PhaseResult:
    env: int
    phase: int
    result: SimResult


def run_phases(policy, resources: Sequence[ResourceSpec],
               phases_per_env: Sequence[Sequence[Sequence[Job]]],
               config: Optional[SimConfig] = None,
               on_round=None, policy_factory=None) -> List[PhaseResult]:
    """Walk each lockstep lane through its phase sequence (§V-D).

    ``phases_per_env[i]`` is the ordered list of jobsets lane ``i`` plays;
    when a lane drains a phase, the ``refill`` hook immediately seeds it
    with the next one, so the decision batch stays wide across the whole
    drift experiment and each phase still yields its own ``SimResult``.
    ``config`` comes from ``SimConfig.for_engine`` (window/backfill live
    there); ``on_round`` is forwarded to ``VectorSimulator.run`` (the
    §V-D goal trace can be logged there).

    Sequential stateful policies (``GAOptimizer``'s plan cache) must not
    be shared across lanes: pass ``policy_factory`` (with ``policy=None``)
    to give every lane its own instance; sharing a ``select_batch``-less
    policy across >1 lanes is rejected.
    """
    sim_cfg = config if config is not None else SimConfig.for_engine("vector")
    if policy_factory is not None:
        env_policies = [policy_factory() for _ in phases_per_env]
        shared = None
    else:
        if not hasattr(policy, "select_batch") and len(phases_per_env) > 1:
            raise ValueError(
                "sharing a sequential policy across lanes cross-"
                "contaminates its per-trace state — pass policy_factory= "
                "for one instance per lane")
        env_policies = [policy] * len(phases_per_env)
        shared = policy if hasattr(policy, "select_batch") else None
    cursors = [0] * len(phases_per_env)
    labels: List[Tuple[int, int]] = []    # completion-order (env, phase)

    def make_sim(env: int) -> Optional[Simulator]:
        seq = phases_per_env[env]
        while cursors[env] < len(seq) and not seq[cursors[env]]:
            cursors[env] += 1             # skip empty phases
        if cursors[env] >= len(seq):
            return None
        jobs = seq[cursors[env]]
        cursors[env] += 1
        return Simulator(resources, jobs, env_policies[env], sim_cfg)

    def refill(env: int, _result: SimResult) -> Optional[Simulator]:
        labels.append((env, cursors[env] - 1))
        return make_sim(env)

    sims, live_envs = [], []
    for env in range(len(phases_per_env)):
        sim = make_sim(env)
        if sim is not None:
            sims.append(sim)
            live_envs.append(env)
    if not sims:
        return []
    vec = VectorSimulator(sims, policy=shared)
    # refill receives slot indices into `sims`; map back to env ids.
    results = vec.run(refill=lambda i, r: refill(live_envs[i], r),
                      on_round=on_round)
    return [PhaseResult(env=e, phase=p, result=r)
            for (e, p), r in zip(labels, results)]
