"""Curriculum jobsets for the three-phase training strategy (paper §III-D).

Phase 1 — *sampled*: jobs sampled from the training trace with controlled
Poisson arrivals at the trace's mean inter-arrival time (easiest regime).
Phase 2 — *real*: contiguous slices of the trace with natural burstiness.
Phase 3 — *synthetic*: freshly generated jobsets mimicking the trace's
hourly/daily patterns and marginals, exposing unseen states.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from ..sim.job import Job
from .theta import ThetaConfig, generate_trace


def _renumber(jobs: List[Job]) -> List[Job]:
    """Reassign contiguous jids in submit order, remapping workflow edges.

    ``deps`` reference jids from the source trace; a slice/sample that
    renumbers without remapping would silently rewire DAGs onto unrelated
    jobs.  Edges whose parent was not selected into this jobset are
    dropped (the child behaves as a root), as are self-edges — a sampled
    set that re-times jobs can otherwise not guarantee acyclicity."""
    ordered = sorted(jobs, key=lambda x: x.submit)
    remap = {j.jid: i for i, j in enumerate(ordered)}
    out = []
    for i, j in enumerate(ordered):
        nj = j.copy()
        nj.jid = i
        nj.deps = tuple(remap[d] for d in j.deps
                        if d in remap and remap[d] != i)
        out.append(nj)
    return out


def sampled_jobsets(trace: Sequence[Job], n_sets: int, jobs_per_set: int,
                    seed: int = 0) -> List[List[Job]]:
    """Random draws with rates smoothed to the trace average (phase 1)."""
    rng = np.random.default_rng(seed)
    submits = np.array([j.submit for j in trace])
    mean_iat = float(np.diff(np.sort(submits)).mean()) if len(trace) > 1 else 60.0
    sets = []
    for _ in range(n_sets):
        picks = rng.choice(len(trace), size=min(jobs_per_set, len(trace)),
                           replace=False)
        arrivals = np.cumsum(rng.exponential(mean_iat, size=len(picks)))
        js = []
        for t, k in zip(arrivals, picks):
            nj = trace[k].copy()
            nj.submit = float(t)
            js.append(nj)
        sets.append(_renumber(js))
    return sets


def real_jobsets(trace: Sequence[Job], n_sets: int,
                 jobs_per_set: int) -> List[List[Job]]:
    """Contiguous slices with original arrival gaps (phase 2)."""
    trace = sorted(trace, key=lambda j: j.submit)
    sets = []
    step = max(1, (len(trace) - jobs_per_set) // max(n_sets, 1))
    for i in range(n_sets):
        lo = min(i * step, max(0, len(trace) - jobs_per_set))
        chunk = [j.copy() for j in trace[lo: lo + jobs_per_set]]
        if not chunk:
            break
        t0 = chunk[0].submit
        for j in chunk:
            j.submit -= t0
        sets.append(_renumber(chunk))
    return sets


def synthetic_jobsets(cfg: ThetaConfig, n_sets: int, jobs_per_set: int,
                      seed: int = 100) -> List[List[Job]]:
    """Fresh generator draws (phase 3) — same marginals, unseen sequences."""
    sets = []
    for i in range(n_sets):
        c = ThetaConfig(**{**cfg.__dict__, "seed": seed + i,
                           "duration_days": max(1.0, jobs_per_set / cfg.jobs_per_day)})
        js = generate_trace(c)[:jobs_per_set]
        sets.append(_renumber(js))
    return sets


@dataclass
class Curriculum:
    """Ordered jobsets for agent training; ``order`` permutes the phases to
    reproduce the Fig. 4 ablation (e.g. 'srs' = sampled, real, synthetic)."""

    sampled: List[List[Job]]
    real: List[List[Job]]
    synthetic: List[List[Job]]

    def ordered(self, order: str = "sampled_real_synthetic") -> List[List[Job]]:
        phases = {
            "sampled": self.sampled, "real": self.real,
            "synthetic": self.synthetic,
        }
        out: List[List[Job]] = []
        for p in order.split("_"):
            out.extend(phases[p])
        return out


def build_curriculum(cfg: ThetaConfig, trace: Sequence[Job],
                     n_sampled: int = 10, n_real: int = 10,
                     n_synth: int = 20, jobs_per_set: int = 5000,
                     seed: int = 0) -> Curriculum:
    """Paper §V-B: 10 sampled + 10 real + 20 synthetic jobsets."""
    return Curriculum(
        sampled=sampled_jobsets(trace, n_sampled, jobs_per_set, seed=seed),
        real=real_jobsets(trace, n_real, jobs_per_set),
        synthetic=synthetic_jobsets(cfg, n_synth, jobs_per_set, seed=seed + 100),
    )
