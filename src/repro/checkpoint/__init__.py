from .store import (CheckpointManager, check_leaves_compat, latest_step,
                    restore_pytree, save_pytree)
