"""Fault-tolerant checkpointing with cross-mesh resharding (elastic restart).

Layout:  <dir>/step_<n>/
            manifest.json        tree structure, shapes, dtypes, metadata
            shard_000.npz        leaf arrays (single-writer; per-host shards
                                 on multi-host runs via ``process_index``)
Features:
  * atomic commit (write to .tmp, rename) — a killed save never corrupts
  * async save (background thread) so the train loop isn't blocked
  * restore onto ANY mesh: arrays are loaded host-side then ``device_put``
    with the *target* sharding, so a 512-chip checkpoint restores on 256
    chips and vice versa (elastic scaling); tested in tests/test_checkpoint
  * keeps the newest K checkpoints (GC)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, jax.tree_util.tree_structure(tree)


_NPZ_NATIVE = (np.float32, np.float64, np.int32, np.int64,
               np.uint8, np.int8, np.uint16, np.int16,
               np.float16, np.bool_, np.uint32, np.uint64)


def _resolve_dtype(name: str) -> np.dtype:
    """True dtype from its manifest name: numpy natives (complex64, ...)
    resolve directly, ml_dtypes extensions (bfloat16, float8_*) by
    attribute lookup."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def check_leaves_compat(expected, got, context: str = "checkpoint") -> None:
    """Raise ``ValueError`` unless ``got`` matches ``expected`` leaf for leaf.

    Both are flat leaf sequences (``jax.tree_util.tree_flatten`` order).
    Guards every path that unflattens foreign arrays into a live param
    tree — ``MRSchAgent.load`` and the serving layer's hot-reload — so an
    incompatible checkpoint (different window, hidden widths, resource
    count) fails loudly instead of silently producing a corrupt tree.
    """
    expected = list(expected)
    got = list(got)
    if len(got) != len(expected):
        raise ValueError(
            f"{context}: incompatible parameter tree — {len(got)} leaves, "
            f"expected {len(expected)} (was it saved from a different "
            "architecture?)")
    for i, (e, g) in enumerate(zip(expected, got)):
        e_shape, g_shape = tuple(np.shape(e)), tuple(np.shape(g))
        if e_shape != g_shape:
            raise ValueError(
                f"{context}: leaf {i} shape mismatch — checkpoint "
                f"{g_shape}, expected {e_shape} (different window / hidden "
                "sizes / resource count?)")
        e_dtype = np.asarray(e).dtype if not hasattr(e, "dtype") else e.dtype
        g_dtype = np.asarray(g).dtype if not hasattr(g, "dtype") else g.dtype
        if g_dtype != e_dtype:
            raise ValueError(
                f"{context}: leaf {i} dtype mismatch — checkpoint "
                f"{g_dtype}, expected {e_dtype}")


def save_pytree(tree, directory: str, step: int, extra: Optional[dict] = None
                ) -> str:
    """Atomic synchronous save.

    Leaves whose dtype npz can't store natively are byte-viewed:
    2-byte dtypes (bfloat16) as uint16 with the same shape, everything
    else (fp8, complex, ...) as uint8 with a trailing itemsize axis.
    The manifest always records the *logical* shape and dtype, so
    ``restore_pytree`` can invert either view.
    """
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        shape = list(arr.shape)
        if arr.dtype not in _NPZ_NATIVE:
            arr = np.ascontiguousarray(arr)
            arr = arr.view(np.uint16) if arr.itemsize == 2 \
                else arr.view(np.uint8).reshape(*arr.shape, arr.itemsize)
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": p, "key": key, "shape": shape, "dtype": true_dtype})
    np.savez(os.path.join(tmp, "shard_000.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_pytree(template, directory: str, step: Optional[int] = None,
                   shardings=None):
    """Restore into the structure of ``template``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, arrays are placed with the
    *target* mesh's sharding — the elastic-restart path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_000.npz"))
    by_path = {leaf["path"]: data[leaf["key"]] for leaf in manifest["leaves"]}
    paths, leaves, treedef = _flatten(template)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(leaves))
    meta_by_path = {leaf["path"]: leaf for leaf in manifest["leaves"]}
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        arr = by_path[p]
        meta = meta_by_path[p]
        true_dtype = meta["dtype"]
        if str(arr.dtype) != true_dtype:          # byte-viewed on save
            dt = _resolve_dtype(true_dtype)
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.uint8:             # (*shape, itemsize) bytes
                arr = arr.reshape(-1).view(dt).reshape(meta["shape"])
            else:                                 # 2-byte view, same shape
                arr = arr.view(dt)
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {p}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def _step_numbers(directory: str) -> list:
    """Committed checkpoint steps in ``directory``, ascending.  Entries
    that merely look step-like (``step_backup/`` left by an operator)
    are skipped, not fatal — the serving hot-reload watcher polls this
    on a loop and must keep finding real checkpoints regardless."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for n in os.listdir(directory):
        parts = n.split("_")
        # Exactly step_<digits>: in-flight .tmp commits, step_7_backup
        # copies, and other step-ish names are all not committed steps.
        if len(parts) == 2 and parts[0] == "step" and parts[1].isdigit():
            steps.append(int(parts[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _step_numbers(directory)
    return max(steps) if steps else None


class CheckpointManager:
    """Async save + retention policy + preemption-safe flush.

    A failed background save (full disk, bad dtype, ...) is never
    silent: the worker exception is captured and re-raised from
    ``wait()`` — and therefore from the next ``save_async``/``save``/
    ``restore_latest``, which all flush first.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._async_exc: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, tree, step: int, extra: Optional[dict] = None):
        self.wait()
        # Materialize on host *before* backgrounding so donated/updated
        # buffers can't be mutated under us.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_pytree(host_tree, self.directory, step, extra)
                self._gc()
            except BaseException as e:          # surfaced by wait()
                self._async_exc = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, tree, step: int, extra: Optional[dict] = None):
        self.wait()
        save_pytree(tree, self.directory, step, extra)
        self._gc()

    def wait(self):
        """Join any in-flight async save; re-raise its failure, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._async_exc is not None:
            exc, self._async_exc = self._async_exc, None
            raise exc

    def restore_latest(self, template, shardings=None):
        self.wait()
        return restore_pytree(template, self.directory, None, shardings)

    def _gc(self):
        steps = _step_numbers(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
