"""Learning-rate schedules (warmup + cosine / constant / rsqrt)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str = "cosine", peak: float = 3e-4,
                  warmup_steps: int = 2000, total_steps: int = 100_000,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        if kind == "constant":
            return warm
        if kind == "rsqrt":
            return warm * jnp.sqrt(
                jnp.maximum(warmup_steps, 1.0)
                / jnp.maximum(step, warmup_steps))
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return warm * cos
    return sched
