"""AdamW with ZeRO-style sharded state and a factored second moment.

States inherit the parameter sharding (params are already fsdp+tensor
sharded, so optimizer memory is fully distributed = ZeRO-3 semantics).
For >=100B configs ``factored=True`` switches the second moment to an
Adafactor-style row/col estimate and ``m_dtype=bf16`` halves the first
moment, which is what lets deepseek-v3-671b fit 512 x 16 GB:
  params bf16 1.34 TB + m bf16 1.34 TB + factored v (~MBs)  ~= 5.5 GB/chip.

Gradient compression: gradients cross the wire in bf16 (model compute
dtype — GSPMD reduce-scatters them before this module converts to f32 for
clipping/update, so the collective payload is 2 B/element; the roofline
counts it that way).  A further int8 + error-feedback stage would halve
that again at the cost of an extra f32 residual buffer per parameter
(= the memory we just saved with the factored second moment); measured
collective shares in EXPERIMENTS §Perf show grad traffic is < 10 % of
per-step wire for every train cell after the H1 fixes, so the trade is
not taken — recorded as a deliberate non-optimization.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4                  # used when no schedule is passed
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    factored: bool = False            # Adafactor-style second moment
    m_dtype: Any = jnp.float32        # bf16 for giant configs
    min_dim_size_to_factor: int = 128


def _factored_dims(shape):
    """Last two dims if both are large enough (Adafactor convention)."""
    if len(shape) < 2:
        return None
    if shape[-1] < 2 or shape[-2] < 2:
        return None
    return (len(shape) - 2, len(shape) - 1)


def opt_init(params, cfg: OptConfig):
    def init_leaf(p):
        state = {"m": jnp.zeros(p.shape, cfg.m_dtype)}
        dims = _factored_dims(p.shape) if cfg.factored else None
        if dims is not None:
            r, c = dims
            vr_shape = p.shape[:r] + p.shape[r + 1:]      # drop row dim
            vc_shape = p.shape[:c] + p.shape[c + 1:]      # drop col dim
            state["vr"] = jnp.zeros(vr_shape, jnp.float32)
            state["vc"] = jnp.zeros(vc_shape, jnp.float32)
        else:
            state["v"] = jnp.zeros(p.shape, jnp.float32)
        return state

    return {
        "step": jnp.zeros((), jnp.int32),
        "leaves": jax.tree.map(init_leaf, params),
    }


def _clip_by_global_norm(grads, max_norm):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def opt_update(grads, opt_state, params, cfg: OptConfig, lr=None):
    """Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    lr = cfg.lr if lr is None else lr
    grads, gnorm = _clip_by_global_norm(grads, cfg.grad_clip)

    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, s):
        m = cfg.b1 * s["m"].astype(jnp.float32) + (1 - cfg.b1) * g
        new_s = {"m": m.astype(cfg.m_dtype)}
        if "v" in s:
            v = cfg.b2 * s["v"] + (1 - cfg.b2) * jnp.square(g)
            new_s["v"] = v
            denom = jnp.sqrt(v / bc2) + cfg.eps
        else:
            r, c = _factored_dims(p.shape)
            g2 = jnp.square(g) + 1e-30
            vr = cfg.b2 * s["vr"] + (1 - cfg.b2) * g2.mean(axis=r)
            vc = cfg.b2 * s["vc"] + (1 - cfg.b2) * g2.mean(axis=c)
            new_s["vr"], new_s["vc"] = vr, vc
            # v_hat ~= vr (x) vc / mean(vr): rank-1 reconstruction.
            vr_e = jnp.expand_dims(vr, r)
            vc_e = jnp.expand_dims(vc, c)
            mean_vr = vr.mean(axis=-1, keepdims=True)
            mean_vr = jnp.expand_dims(mean_vr, r)
            v = vr_e * vc_e / jnp.maximum(mean_vr, 1e-30)
            denom = jnp.sqrt(v / bc2) + cfg.eps
        u = (m / bc1) / denom
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, new_s

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_s = treedef.flatten_up_to(opt_state["leaves"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_leaves = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, {"step": step, "leaves": new_leaves}, gnorm
