from .adamw import OptConfig, opt_init, opt_update
from .schedule import make_schedule
