"""Vectorized DFP training: N=1 seed-matched equivalence with the
sequential driver, heterogeneous environment lanes, train-mix
construction, and capacity-padded state encoding."""
import numpy as np
import pytest

from repro.core import (AgentConfig, EnvSlot, MRSchAgent, TrainConfig,
                        encode_state, slots_from_jobsets, train_agent,
                        train_agent_vectorized)
from repro.sim import Job, ResourceSpec, SimConfig, Simulator
from repro.workloads import ThetaConfig, build_train_mix, scale_resources

# End-to-end training drivers — the slow CI lane runs these
# (`pytest -m slow`); the fast lane keeps the kernel/unit suites.
pytestmark = pytest.mark.slow

RES = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]


def synth_jobs(seed: int, n: int = 40):
    rng = np.random.default_rng(seed)
    jobs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(40.0))
        runtime = float(rng.uniform(20, 300))
        jobs.append(Job(jid=i, submit=t, runtime=runtime,
                        walltime=runtime * float(rng.uniform(1.0, 2.0)),
                        demands={"node": int(rng.integers(1, 12)),
                                 "bb": int(rng.integers(0, 6))}))
    return jobs


def small_agent(seed: int = 0, **over) -> MRSchAgent:
    kw = dict(state_hidden=(32, 16), state_out=8, module_hidden=4,
              stream_hidden=16, batch_size=16, grad_steps_per_episode=4,
              eps_decay=0.9, seed=seed)
    kw.update(over)
    return MRSchAgent(RES, AgentConfig(**kw))


def test_vectorized_n1_matches_sequential_training():
    """The acceptance equivalence: an N=1 batched rollout consumes the
    host RNG in the sequential order, so trajectories, metrics, losses,
    and the epsilon schedule all match the classic driver exactly."""
    jobsets = [synth_jobs(s) for s in range(3)]
    a_seq, a_vec = small_agent(), small_agent()
    seq = train_agent(a_seq, RES, jobsets)
    vec = train_agent(a_vec, RES, jobsets, config=TrainConfig(n_envs=1))
    assert seq.episode_metrics == vec.episode_metrics
    assert seq.decisions == vec.decisions
    assert len(seq.episode_losses) == len(vec.episode_losses) > 0
    assert np.allclose(seq.episode_losses, vec.episode_losses,
                       rtol=1e-6, atol=0.0)
    assert a_seq.epsilon == a_vec.epsilon
    assert a_seq.replay.rows == a_vec.replay.rows


def test_vectorized_training_multi_env_learns():
    """N=3 lanes with heterogeneous traces AND cluster scales: every
    jobset becomes one trained episode, epsilon decays, and the agent
    still serves batched evaluation afterwards."""
    slots = [
        EnvSlot(jobsets=[("a", synth_jobs(1)), ("b", synth_jobs(2))],
                resources=RES, tag="full"),
        EnvSlot(jobsets=[("c", synth_jobs(3))],
                resources=scale_resources(RES, 0.75), tag="mid"),
        EnvSlot(jobsets=[("d", synth_jobs(4, n=25))],
                resources=scale_resources(RES, 0.5), tag="half"),
    ]
    agent = small_agent()
    log = train_agent_vectorized(agent, slots, TrainConfig(n_envs=3))
    assert len(log.episodes) == 4
    assert {e["tag"] for e in log.episodes} == {"full", "mid", "half"}
    assert log.decisions == sum(e["decisions"] for e in log.episodes)
    assert log.episode_losses and agent.losses
    assert agent.epsilon < 1.0
    assert agent.replay.rows > 0
    assert not agent.training
    # evaluation-mode batched selection still works after training
    sim = Simulator(RES, synth_jobs(9), agent)
    ctx = sim.next_decision()
    acts = agent.select_batch([ctx, ctx])
    assert list(acts) == [agent.select(ctx)] * 2


def test_vectorized_interleaved_round_grad_steps():
    """grad_steps_per_round>0 trains the network mid-collection, once the
    replay buffer can fill a minibatch."""
    agent = small_agent(batch_size=8)
    # Lane 1 finishes early, filling the replay buffer while lane 0 is
    # still mid-trace; the remaining rounds each take a gradient step.
    slots = slots_from_jobsets(RES, [synth_jobs(1, n=40),
                                     synth_jobs(2, n=12)], 2)
    log = train_agent_vectorized(
        agent, slots, TrainConfig(n_envs=2, grad_steps_per_round=1))
    assert len(log.round_losses) > 0
    assert log.rounds > 0


def test_vectorized_training_pallas_backend():
    """Training runs end-to-end through the fused Pallas kernels: the
    TrainConfig.backend switch re-routes the agent, losses stay finite,
    and evaluation-mode batched selection agrees with the xla backend."""
    agent = small_agent(batch_size=8, grad_steps_per_episode=2)
    assert agent.dfp.backend == "xla"
    jobsets = [synth_jobs(0, n=12)]
    log = train_agent(agent, RES, jobsets,
                      config=TrainConfig(n_envs=1, backend="pallas"))
    assert agent.dfp.backend == "pallas"
    assert log.episodes and log.decisions > 0
    assert log.episode_losses
    assert np.all(np.isfinite(log.episode_losses))
    assert agent.epsilon < 1.0
    # eval-mode batched greedy actions match across backends
    sim = Simulator(RES, synth_jobs(9, n=6), agent)
    ctx = sim.next_decision()
    acts_pallas = agent.select_batch([ctx, ctx])
    agent.set_backend("xla")
    acts_xla = agent.select_batch([ctx, ctx])
    assert list(acts_pallas) == list(acts_xla)


def test_slots_from_jobsets_round_robin():
    jobsets = [synth_jobs(s, n=5) for s in range(5)]
    slots = slots_from_jobsets(RES, jobsets, 2)
    assert [len(s.jobsets) for s in slots] == [3, 2]
    assert [label for s in slots for label, _ in s.jobsets] == \
        ["set0", "set2", "set4", "set1", "set3"]
    # never more lanes than jobsets
    assert len(slots_from_jobsets(RES, jobsets, 16)) == 5


def test_build_train_mix_grid_and_scales():
    cfg = ThetaConfig.mini(seed=0, duration_days=0.3, jobs_per_day=80)
    mix = build_train_mix(cfg, scenarios=("S1", "S2"), seeds=(1, 2),
                          n_envs=3, resource_scales=(1.0, 0.5))
    assert len(mix) == 3
    labels = [label for slot in mix for label, _ in slot.jobsets]
    assert sorted(labels) == ["S1/seed1", "S1/seed2", "S2/seed1", "S2/seed2"]
    full = {r.name: r.capacity for r in mix[0].resources}
    half = {r.name: r.capacity for r in mix[1].resources}
    assert half["node"] == max(1, round(full["node"] * 0.5))
    assert mix[1].tag.endswith("@0.5x")
    with pytest.raises(ValueError):
        scale_resources(RES, 1.5)


def test_encode_state_pads_smaller_cluster():
    """A scaled-down lane keeps the reference layout: absent units read
    as unavailable and the vector length never changes."""
    agent = small_agent()
    enc = agent.enc
    jobs = synth_jobs(0, n=6)
    for j in jobs:
        j.demands = {"node": 2, "bb": 1}
    small = scale_resources(RES, 0.5)          # node 8, bb 4
    sim = Simulator(small, jobs, agent, SimConfig(window=enc.window))
    ctx = sim.next_decision()
    state = encode_state(enc, ctx)
    assert state.shape == (enc.state_dim,)
    base = enc.window * enc.job_dim
    # node section: first 8 unit slots live, padded 8 read unavailable
    assert state[base: base + 8].max() == 1.0
    assert np.all(state[base + 8: base + 16] == 0.0)
    # demand fractions normalized by the lane's own capacity (2/8, 1/4)
    assert state[0] == pytest.approx(2 / 8)
    assert state[1] == pytest.approx(1 / 4)


def test_lane_resources_validated():
    agent = small_agent()
    bad_names = [EnvSlot(jobsets=[("x", synth_jobs(0, n=3))],
                         resources=[ResourceSpec("gpu", 4)], tag="bad")]
    with pytest.raises(ValueError, match="do not match"):
        train_agent_vectorized(agent, bad_names, TrainConfig(n_envs=1))
    too_big = [EnvSlot(jobsets=[("x", synth_jobs(0, n=3))],
                       resources=[ResourceSpec("node", 32),
                                  ResourceSpec("bb", 8)], tag="big")]
    with pytest.raises(ValueError, match="exceeds"):
        train_agent_vectorized(agent, too_big, TrainConfig(n_envs=1))
