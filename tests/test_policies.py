"""Baseline policies: FCFS ordering, GA optimizer, scalar RL learning."""
import numpy as np
import pytest

from repro.core import (FCFSPolicy, GAConfig, GAOptimizer, ScalarRLConfig,
                        ScalarRLPolicy, evaluate)
from repro.sim import Cluster, Job, ResourceSpec, run_trace
from repro.sim.simulator import SchedContext


def _ctx(window, caps={"node": 10, "bb": 10}):
    c = Cluster([ResourceSpec(k, v) for k, v in caps.items()])
    return SchedContext(now=0.0, cluster=c, window=window,
                        queue_len=len(window), running=[], queue=list(window))


def test_fcfs_selects_head():
    w = [Job(i, 0, 10, 10, {"node": 1}) for i in range(5)]
    assert FCFSPolicy().select(_ctx(w)) == 0


def test_ga_packs_complementary_jobs():
    """The makespan example of Fig. 1: jobs with complementary demands
    should be co-scheduled; GA must find a better packing than FCFS order
    when FCFS order wastes capacity."""
    # machine: node=10, bb=10
    w = [
        Job(0, 0, 10, 10, {"node": 7, "bb": 1}),   # J1
        Job(1, 0, 10, 10, {"node": 5, "bb": 6}),   # J2 (blocks J1 if first)
        Job(2, 0, 10, 10, {"node": 3, "bb": 3}),   # J3
        Job(3, 0, 10, 10, {"node": 4, "bb": 1}),   # J4
    ]
    ga = GAOptimizer(GAConfig(population=16, generations=12, seed=0))
    ctx = _ctx(w)
    order = ga._evolve(w, dict(ctx.cluster.free), dict(ctx.cluster.capacities))

    def pack(perm):
        free = {"node": 10, "bb": 10}
        used = {"node": 0, "bb": 0}
        for i in perm:
            j = w[i]
            if all(j.demands[k] <= free[k] for k in free):
                for k in free:
                    free[k] -= j.demands[k]
                    used[k] += j.demands[k]
        return used

    ga_used = pack(order)
    fcfs_used = pack(range(4))
    # The GA is multi-objective: its packing must not be Pareto-dominated
    # by the FCFS-order packing (Fig. 1's point is that fixed orderings
    # waste one of the resources).
    dominated = all(fcfs_used[k] >= ga_used[k] for k in ga_used) and \
        any(fcfs_used[k] > ga_used[k] for k in ga_used)
    assert not dominated, (ga_used, fcfs_used)
    assert sum(ga_used.values()) >= 10        # non-trivial packing


def test_ga_runs_full_trace():
    jobs = [Job(i, float(i), 20, 30, {"node": 2 + (i % 3), "bb": i % 2})
            for i in range(30)]
    r = run_trace([ResourceSpec("node", 8), ResourceSpec("bb", 4)], jobs,
                  GAOptimizer(GAConfig(population=8, generations=4)))
    assert len(r.jobs) == 30


def test_scalar_rl_trains_and_evaluates():
    res = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]
    pol = ScalarRLPolicy(res, ScalarRLConfig(hidden=(32, 16)))
    rng = np.random.default_rng(0)
    jobs = [Job(i, float(rng.exponential(50) * i / 4), float(rng.uniform(20, 200)),
                300.0, {"node": int(rng.integers(1, 8)),
                        "bb": int(rng.integers(0, 4))})
            for i in range(40)]
    pol.training = True
    run_trace(res, jobs, pol)
    loss = pol.end_episode()
    assert loss is not None and np.isfinite(loss)
    r = evaluate(pol, res, jobs)
    assert len(r.jobs) == 40


def test_fleet_scheduler_smoke():
    from repro.launch.scheduler import (FleetSpec, job_demands,
                                        schedule_fleet, synth_fleet_trace)
    fleet = FleetSpec()
    d = job_demands("deepseek-v3-671b", "train_4k", fleet)
    assert d["chips"] >= 32       # 671B needs a large slice
    d2 = job_demands("gemma-2b", "decode_32k", fleet)
    assert d2["chips"] <= d["chips"]
    jobs = synth_fleet_trace(fleet, 25, seed=0)
    r = schedule_fleet(jobs, fleet, "fcfs")
    assert len(r.jobs) == 25
