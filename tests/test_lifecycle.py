"""Unified job-lifecycle core: state-machine transitions, workflow DAGs,
requeue-on-failure, fault injection — unit coverage of
``repro.sim.lifecycle`` plus the acceptance pins: three-engine parity on
a workflow and a fault scenario (both NN backends) and a hypothesis
property that topological eligibility order is never violated."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import AgentConfig, FCFSPolicy, MRSchAgent
from repro.sim import (FAILED, FINISHED, DeviceSimulator, DrainEvent,
                       FaultSchedule, Job, ResourceSpec, SimConfig,
                       Simulator, VectorSimulator, pipeline_makespan,
                       workflow_components)
from repro.workloads import ThetaConfig, build_jobs, get_scenario

RES = [ResourceSpec("node", 4)]


def run_seq(jobs, resources=RES, faults=None, policy=None):
    return Simulator(resources, jobs, policy or FCFSPolicy(), SimConfig(),
                     faults=faults).run()


# ------------------------------------------------------------- transitions
def test_dependency_holds_child_until_parent_finishes():
    jobs = [
        Job(0, 0.0, 100.0, 100.0, {"node": 1}),
        Job(1, 0.0, 50.0, 50.0, {"node": 1}, deps=(0,), think_time=30.0),
    ]
    r = run_seq(jobs)
    parent, child = r.jobs
    assert parent.state == FINISHED and child.state == FINISHED
    # Nodes were free the whole time: only the dependency gated the child.
    assert child.start == pytest.approx(parent.end + 30.0)
    assert r.metrics.pipeline_makespan == pytest.approx(child.end - 0.0)


def test_fan_in_waits_for_all_parents():
    jobs = [
        Job(0, 0.0, 60.0, 60.0, {"node": 1}),
        Job(1, 0.0, 200.0, 200.0, {"node": 1}),
        Job(2, 0.0, 10.0, 10.0, {"node": 1}, deps=(0, 1)),
    ]
    r = run_seq(jobs)
    ends = {j.jid: j.end for j in r.jobs}
    assert r.jobs[2].start == pytest.approx(max(ends[0], ends[1]))
    assert len(workflow_components(r.jobs)) == 1


def test_failure_requeues_then_finishes():
    jobs = [Job(0, 0.0, 100.0, 100.0, {"node": 4}, fail_times=(40.0,))]
    r = run_seq(jobs)
    (j,) = r.jobs
    # Attempt 1 dies at t=40, re-enters the queue, attempt 2 completes.
    assert j.state == FINISHED and j.requeues == 1
    assert j.first_start == 0.0 and j.start == pytest.approx(40.0)
    assert j.end == pytest.approx(140.0)
    assert r.metrics.requeues == 1 and r.metrics.n_failed == 0
    assert r.metrics.failed_node_hours == pytest.approx(4 * 40.0 / 3600.0)
    assert r.metrics.completed_work_frac == pytest.approx(
        400.0 / (400.0 + 160.0))


def test_requeue_bound_exhaustion_fails_job():
    faults = FaultSchedule(max_requeues=1)
    jobs = [Job(0, 0.0, 100.0, 100.0, {"node": 1},
                fail_times=(10.0, 10.0, 10.0))]
    r = run_seq(jobs, faults=faults)
    (j,) = r.jobs
    # Two kills exhaust max_requeues=1; the final kill is not a re-entry.
    assert j.state == FAILED and j.requeues == 2
    assert r.metrics.n_failed == 1 and r.metrics.requeues == 1
    assert r.metrics.completed_work_frac == 0.0


def test_parent_failure_cascades_to_held_children():
    faults = FaultSchedule(max_requeues=0)
    jobs = [
        Job(0, 0.0, 100.0, 100.0, {"node": 1}, fail_times=(10.0,)),
        Job(1, 0.0, 50.0, 50.0, {"node": 1}, deps=(0,)),
        Job(2, 0.0, 50.0, 50.0, {"node": 1}, deps=(1,)),
    ]
    r = run_seq(jobs, faults=faults)
    assert [j.state for j in r.jobs] == [FAILED, FAILED, FAILED]
    assert r.metrics.n_failed == 3
    assert r.metrics.pipeline_makespan == 0.0


def test_drain_kills_residents_and_restores():
    faults = FaultSchedule(drains=(
        DrainEvent(time=30.0, resource="node", units=4, duration=20.0),))
    jobs = [Job(0, 0.0, 100.0, 100.0, {"node": 2})]
    r = run_seq(jobs, faults=faults)
    (j,) = r.jobs
    # Killed by the drain at t=30; nodes return at t=50; reruns to 150.
    assert j.state == FINISHED and j.requeues == 1
    assert j.first_start == 0.0
    assert j.start == pytest.approx(50.0) and j.end == pytest.approx(150.0)
    assert r.metrics.failed_node_hours == pytest.approx(2 * 30.0 / 3600.0)


def test_wait_counts_from_first_submission_regression():
    """Pinned: a requeued-then-finished job's wait is measured from its
    ORIGINAL submission to its FIRST start — the kill must not reset it."""
    jobs = [
        Job(0, 0.0, 100.0, 100.0, {"node": 4}),
        Job(1, 10.0, 100.0, 100.0, {"node": 4}, fail_times=(20.0,)),
    ]
    r = run_seq(jobs)
    j1 = r.jobs[1]
    assert j1.first_start == pytest.approx(100.0)
    assert j1.wait == pytest.approx(90.0)
    assert r.metrics.avg_wait == pytest.approx(45.0)


def test_requeued_job_keeps_original_queue_position():
    """A killed job re-enters at its original submit rank, ahead of
    later arrivals that were still waiting."""
    jobs = [
        Job(0, 0.0, 100.0, 100.0, {"node": 4}, fail_times=(50.0,)),
        Job(1, 1.0, 100.0, 100.0, {"node": 4}),
        Job(2, 2.0, 100.0, 100.0, {"node": 4}),
    ]
    r = run_seq(jobs)
    starts = {j.jid: j.start for j in r.jobs}
    assert starts[0] == pytest.approx(50.0)      # retries immediately
    assert starts[1] == pytest.approx(150.0) and starts[2] == pytest.approx(250.0)


def test_fault_schedule_rejects_overlapping_drains():
    faults = FaultSchedule(drains=(
        DrainEvent(time=10.0, resource="node", units=2, duration=50.0),
        DrainEvent(time=30.0, resource="node", units=2, duration=10.0),
    ))
    with pytest.raises(ValueError, match="overlap"):
        run_seq([Job(0, 0.0, 10.0, 10.0, {"node": 1})], faults=faults)


def test_relative_fault_schedule_resolves_against_span():
    faults = FaultSchedule(relative=True, drains=(
        DrainEvent(time=0.5, resource="node", unit_frac=0.5, duration=0.25),))
    jobs = [Job(0, 0.0, 10.0, 10.0, {"node": 1}),
            Job(1, 100.0, 10.0, 10.0, {"node": 1})]
    resolved = faults.resolve(jobs, {"node": 4})
    (d,) = resolved.drains
    assert (d.time, d.units, d.duration) == (50.0, 2, 25.0)


def test_pipeline_makespan_averages_completed_components_only():
    jobs = [
        Job(0, 0.0, 10.0, 10.0, {"node": 1}),
        Job(1, 0.0, 10.0, 10.0, {"node": 1}, deps=(0,)),
        Job(2, 5.0, 10.0, 10.0, {"node": 1}),
        Job(3, 5.0, 10.0, 10.0, {"node": 1}, deps=(2,)),
    ]
    r = run_seq(jobs)
    comp_spans = []
    for comp in workflow_components(r.jobs):
        comp_spans.append(max(j.end for j in comp)
                          - min(j.submit for j in comp))
    assert r.metrics.pipeline_makespan == pytest.approx(np.mean(comp_spans))
    assert pipeline_makespan(r.jobs) == r.metrics.pipeline_makespan


# ------------------------------------------------- three-engine parity pins
def small_agent(resources, seed: int = 0, backend: str = "xla") -> MRSchAgent:
    return MRSchAgent(resources, AgentConfig(
        state_hidden=(32, 16), state_out=8, module_hidden=4, seed=seed,
        backend=backend))


def assert_lifecycle_parity(a, b):
    """Engine results agree on schedule AND lifecycle accounting (host
    f64 vs device f32 clock: ~1e-2 s slack on times)."""
    assert a.decisions == b.decisions
    assert a.n_unstarted == b.n_unstarted
    ra, rb = a.metrics.as_row(), b.metrics.as_row()
    assert ra["requeues"] == rb["requeues"]
    assert ra["n_failed"] == rb["n_failed"]
    assert np.isclose(ra["makespan"], rb["makespan"], atol=1e-2)
    assert np.isclose(ra["pipeline_makespan"], rb["pipeline_makespan"],
                      rtol=1e-5, atol=1e-2)
    assert np.isclose(ra["completed_work_frac"], rb["completed_work_frac"],
                      atol=1e-4)
    assert np.isclose(ra["avg_wait"], rb["avg_wait"], rtol=1e-5, atol=1e-2)
    for ja, jb in zip(a.jobs, b.jobs):
        assert ja.jid == jb.jid and ja.started == jb.started
        assert ja.state == jb.state and ja.requeues == jb.requeues
        if ja.started:
            assert np.isclose(ja.first_start, jb.first_start,
                              rtol=1e-6, atol=1e-2)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("scenario", ["workflow-pipelines", "faulty-drain"])
def test_three_engine_parity_lifecycle(scenario, backend):
    """Acceptance pin: N=1 device and vector reproduce the sequential
    engine round for round on a workflow-DAG and a fault-injection
    scenario, on both NN backends."""
    theta = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=110)
    res = theta.resources()
    jobs = build_jobs(scenario, theta, seed=1)
    faults = get_scenario(scenario).faults
    agent = small_agent(res, backend=backend)
    seq = run_seq(jobs, resources=res, faults=faults, policy=agent)
    vec = VectorSimulator.from_jobsets(
        res, [jobs], agent, SimConfig.for_engine("vector"),
        faults=faults).run()[0]
    dev = DeviceSimulator(res, [jobs], agent, faults=faults).rollout().results[0]
    assert_lifecycle_parity(seq, vec)
    assert_lifecycle_parity(seq, dev)
    # The scenario exercised what it claims to exercise.
    if scenario.startswith("workflow"):
        assert seq.metrics.pipeline_makespan > 0.0
    else:
        assert seq.metrics.requeues > 0


def test_device_parity_fcfs_faulty_jobs_multi_env():
    """FCFS over per-env fault traces: device matches sequential per env."""
    theta = ThetaConfig.mini(seed=0, duration_days=0.3, jobs_per_day=100)
    res = theta.resources()
    jobsets = [build_jobs("faulty-jobs", theta, seed=s) for s in (1, 2)]
    ro = DeviceSimulator(res, jobsets, FCFSPolicy()).rollout()
    for i, jobs in enumerate(jobsets):
        seq = run_seq(jobs, resources=res)
        assert_lifecycle_parity(seq, ro.results[i])
    assert sum(r.metrics.requeues for r in ro.results) > 0


# ----------------------------------------------- topological-order property
def dag_jobset(seed: int):
    """Random DAG jobset: up to 2 parents per job (always earlier jids, so
    acyclic by construction), random arrival order, half the jobs carry a
    mid-run failure point."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(int(rng.integers(3, 11))):
        deps = ()
        if i and rng.uniform() < 0.6:
            k = int(rng.integers(1, min(i, 2) + 1))
            deps = tuple(sorted(rng.choice(i, size=k, replace=False)
                                .tolist()))
        runtime = float(rng.integers(10, 201))
        jobs.append(Job(
            jid=i, submit=float(rng.integers(0, 401)),
            runtime=runtime, walltime=runtime,
            demands={"node": int(rng.integers(1, 5))},
            deps=deps, think_time=float(rng.integers(0, 61)),
            fail_times=((runtime / 2,) if rng.uniform() < 0.5 else ())))
    return jobs


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_topological_eligibility_never_violated(seed):
    """No attempt of a child may start before every parent FINISHED plus
    the child's think time — under arbitrary DAGs, arrival orders, and
    mid-run failures."""
    r = run_seq(dag_jobset(seed))
    by_id = {j.jid: j for j in r.jobs}
    for j in r.jobs:
        if not j.started:
            continue
        for d in j.deps:
            p = by_id[d]
            assert p.state == FINISHED
            assert j.first_start >= p.end + j.think_time - 1e-6
        assert j.first_start >= j.submit
