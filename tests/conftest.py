import os

# Tests run single-device (the dry-run alone forces 512 fake devices, in
# its own process); keep determinism and silence accelerator probing.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
