"""Batched rollout engine: re-entrant stepping, lockstep equivalence,
batched DFP inference, and starvation reporting."""
import numpy as np
import pytest

from repro.core import AgentConfig, FCFSPolicy, MRSchAgent
from repro.sim import (Job, ResourceSpec, SimConfig, Simulator,
                       VectorSimulator, run_trace, run_traces)

RES = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]


def synth_jobs(seed: int, n: int = 40):
    rng = np.random.default_rng(seed)
    jobs = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(40.0))
        runtime = float(rng.uniform(20, 300))
        jobs.append(Job(jid=i, submit=t, runtime=runtime,
                        walltime=runtime * float(rng.uniform(1.0, 2.0)),
                        demands={"node": int(rng.integers(1, 12)),
                                 "bb": int(rng.integers(0, 6))}))
    return jobs


def small_agent(seed: int = 0) -> MRSchAgent:
    return MRSchAgent(RES, AgentConfig(
        state_hidden=(32, 16), state_out=8, module_hidden=4, seed=seed))


def assert_results_equal(a, b):
    assert a.metrics.as_row() == b.metrics.as_row()
    assert a.decisions == b.decisions
    assert a.n_unstarted == b.n_unstarted
    assert [(j.jid, j.start, j.end) for j in a.jobs] \
        == [(j.jid, j.start, j.end) for j in b.jobs]


def test_reentrant_stepping_matches_run():
    """Manually driving next_decision/post_action == the run() adapter."""
    jobs = synth_jobs(3)
    ref = run_trace(RES, jobs, FCFSPolicy())
    sim = Simulator(RES, jobs, FCFSPolicy(), SimConfig(window=10))
    policy = FCFSPolicy()
    while (ctx := sim.next_decision()) is not None:
        sim.post_action(policy.select(ctx))
    assert_results_equal(sim.result(), ref)


@pytest.mark.parametrize("n_envs", [1, 3, 8])
def test_vector_equals_sequential_fcfs(n_envs):
    jobsets = [synth_jobs(seed) for seed in range(n_envs)]
    seq = [run_trace(RES, js, FCFSPolicy()) for js in jobsets]
    vec = run_traces(RES, jobsets, FCFSPolicy())
    for a, b in zip(seq, vec):
        assert_results_equal(a, b)


def test_vector_equals_sequential_agent():
    """Lockstep + batched DFP inference must not change any trajectory,
    even though the environments develop heterogeneous goal vectors."""
    agent = small_agent()
    jobsets = [synth_jobs(seed) for seed in range(4)]
    # sparse-BB variant to force different contention (and goals) in env 0
    for j in jobsets[0]:
        j.demands["bb"] = 0
    seq = [run_trace(RES, js, agent) for js in jobsets]
    vec = run_traces(RES, jobsets, agent)
    for a, b in zip(seq, vec):
        assert_results_equal(a, b)


def test_select_batch_matches_select():
    """One batched forward == N single forwards, row for row."""
    agent = small_agent()
    sims = [Simulator(RES, synth_jobs(seed), agent) for seed in range(3)]
    ctxs = [s.next_decision() for s in sims]
    assert all(c is not None for c in ctxs)
    batch = agent.select_batch(ctxs)
    singles = [agent.select(c) for c in ctxs]
    assert list(batch) == singles


def test_select_batch_training_requires_slots():
    """Interleaving envs without per-env routing would corrupt the DFP
    future-measurement targets, so training-mode batched selection
    demands slot ids; with them, transitions land in the per-env episode
    accumulators."""
    agent = small_agent()
    sim = Simulator(RES, synth_jobs(0), agent)
    ctx = sim.next_decision()
    agent.training = True
    with pytest.raises(RuntimeError, match="evaluation-only"):
        agent.select_batch([ctx])
    agent.begin_vector_episodes(2)
    agent.select_batch([ctx, ctx], slots=[0, 1])
    agent.select_batch([ctx], slots=[1])
    assert len(agent.vec_recorder.slot(0)) == 1
    assert len(agent.vec_recorder.slot(1)) == 2
    assert agent.vec_recorder.finish(0) is not None
    assert agent.vec_recorder.finish(0) is None


def test_vector_stats_show_batching():
    agent = small_agent()
    jobsets = [synth_jobs(seed) for seed in range(4)]
    vec = VectorSimulator.from_jobsets(RES, jobsets, agent)
    results = vec.run()
    st = vec.stats
    assert st.decisions == sum(r.decisions for r in results)
    assert st.policy_calls == st.rounds          # one batched call per round
    assert st.policy_calls < st.decisions        # i.e. batching happened
    assert 1 < st.max_batch <= 4


class _CountingPolicy:
    """Stateful sequential policy: remembers how many decisions it made."""

    def __init__(self):
        self.count = 0

    def select(self, ctx):
        self.count += 1
        return 0


def test_from_factory_policy_survives_refill():
    """Regression: a factory-built engine owns per-slot policy instances;
    a refill hook that hands back a policy-less ``Simulator`` must inherit
    the slot's instance instead of silently resetting its state."""
    made = []

    def factory():
        p = _CountingPolicy()
        made.append(p)
        return p

    vec = VectorSimulator.from_factory(RES, [synth_jobs(0, n=10)], factory)
    extra = [synth_jobs(1, n=10)]

    def refill(i, result):
        return Simulator(RES, extra.pop(), None) if extra else None

    results = vec.run(refill=refill)
    assert len(results) == 2
    assert len(made) == 1                    # no mid-curriculum re-instantiation
    assert vec.sims[0].policy is made[0]
    assert made[0].count == sum(r.decisions for r in results)


def test_unstarted_jobs_reported_not_dropped():
    """A job that can never fit stays in result.jobs and is counted, and
    the wait/slowdown aggregates ignore it instead of going negative."""
    jobs = [
        Job(0, 0.0, 50.0, 60.0, {"node": 4}),
        Job(1, 1.0, 10.0, 20.0, {"node": 99}),   # exceeds capacity forever
    ]
    r = run_trace([ResourceSpec("node", 8)], jobs, FCFSPolicy())
    assert len(r.jobs) == 2
    assert r.n_unstarted == 1
    assert not [j for j in r.jobs if j.jid == 1][0].started
    assert [j.jid for j in r.started_jobs] == [0]
    assert r.metrics.n_jobs == 1
    assert r.metrics.avg_wait >= 0.0
