"""Simulator invariants: allocation, EASY backfill, metrics."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.sim import Cluster, Job, ResourceSpec, SimConfig, Simulator, run_trace
from repro.core import FCFSPolicy


def mk_jobs(spec):
    return [Job(jid=i, submit=s, runtime=r, walltime=w, demands=dict(d))
            for i, (s, r, w, d) in enumerate(spec)]


def test_cluster_allocate_release():
    c = Cluster([ResourceSpec("node", 8), ResourceSpec("bb", 4)])
    j = Job(0, 0.0, 100.0, 120.0, {"node": 5, "bb": 2})
    assert c.fits(j)
    c.allocate(j, 10.0)
    assert c.free == {"node": 3, "bb": 2}
    enc = c.unit_encoding(now=50.0)
    assert enc["node"][:, 0].sum() == 3          # 3 free units
    busy_ttf = enc["node"][enc["node"][:, 0] == 0, 1]
    assert np.allclose(busy_ttf, 80.0)           # est end 130 - now 50
    c.release_job(0)
    assert c.free == {"node": 8, "bb": 4}


def test_earliest_fit_time_orders_releases():
    c = Cluster([ResourceSpec("node", 4)])
    c.allocate(Job(0, 0, 100, 100, {"node": 2}), 0.0)
    c.allocate(Job(1, 0, 50, 60, {"node": 2}), 0.0)
    big = Job(2, 0, 10, 10, {"node": 4})
    assert c.earliest_fit_time(big, 0.0) == 100.0   # needs both releases


def test_fcfs_reservation_blocks_greedy_backfill():
    """A long job must not backfill past the reserved head-of-queue job."""
    jobs = mk_jobs([
        (0.0, 100.0, 100.0, {"node": 3}),       # leaves one node free
        (1.0, 10.0, 10.0, {"node": 4}),         # head: reserved at t=100
        (2.0, 500.0, 500.0, {"node": 1}),       # would delay head if started
        (3.0, 50.0, 50.0, {"node": 1}),         # fits before t=100: backfill
    ])
    res = [ResourceSpec("node", 4)]
    r = run_trace(res, jobs, FCFSPolicy())
    by = {j.jid: j for j in r.jobs}
    assert by[1].start == pytest.approx(100.0)     # reservation honored
    assert by[3].start < 100.0                     # short job backfilled
    assert by[2].start >= 100.0                    # long job did NOT jump


def test_backfill_shadow_resources():
    """Backfill allowed when it doesn't intersect the reservation."""
    jobs = mk_jobs([
        (0.0, 100.0, 100.0, {"node": 3}),
        (1.0, 10.0, 10.0, {"node": 4}),          # reserved at 100
        (2.0, 1000.0, 1000.0, {"node": 1}),      # uses the 1 free node
    ])
    r = run_trace([ResourceSpec("node", 4)], jobs, FCFSPolicy(),
                  backfill=True)
    by = {j.jid: j for j in r.jobs}
    # job 2 finishing long after 100 would steal the head's nodes -> no
    assert by[2].start >= by[1].start


def test_backfill_shadow_accounting_multi_resource():
    """A backfill candidate that fits *now* but would occupy the
    reservation's shadow units must not start; one that stays inside the
    shadow may, and it debits the shadow for later candidates."""
    jobs = mk_jobs([
        (0.0, 100.0, 100.0, {"node": 3, "bb": 0}),   # A: leaves node=1,bb=4
        (1.0, 10.0, 10.0, {"node": 2, "bb": 4}),     # B: head, reserved @100
        (2.0, 500.0, 500.0, {"node": 1, "bb": 1}),   # C: fits now, bb breaks
                                                     #    B's shadow -> wait
        (3.0, 500.0, 500.0, {"node": 1, "bb": 0}),   # D: inside shadow -> go
    ])
    res = [ResourceSpec("node", 4), ResourceSpec("bb", 4)]
    r = run_trace(res, jobs, FCFSPolicy())
    by = {j.jid: j for j in r.jobs}
    assert by[3].start == pytest.approx(3.0)       # D backfilled immediately
    assert by[1].start == pytest.approx(100.0)     # reservation honored
    assert by[2].start >= 100.0                    # C kept out of the shadow


def test_backfill_shadow_debits_accumulate():
    """Two candidates that each fit the shadow alone must not BOTH start
    when together they exceed it (the running-shadow bookkeeping)."""
    jobs = mk_jobs([
        (0.0, 100.0, 100.0, {"node": 2}),            # A: leaves 2 free
        (1.0, 10.0, 10.0, {"node": 3}),              # B: reserved @100,
                                                     #    shadow = 4-3 = 1
        (2.0, 500.0, 500.0, {"node": 1}),            # C: fills the shadow
        (3.0, 500.0, 500.0, {"node": 1}),            # D: shadow exhausted
    ])
    r = run_trace([ResourceSpec("node", 4)], jobs, FCFSPolicy())
    by = {j.jid: j for j in r.jobs}
    assert by[2].start == pytest.approx(2.0)
    assert by[1].start == pytest.approx(100.0)
    assert by[3].start >= 100.0                    # NOT also backfilled


@settings(max_examples=25, deadline=None)
@given(st.lists(
    st.tuples(st.floats(0, 1000), st.floats(1, 500), st.floats(0, 400),
              st.integers(1, 8), st.integers(0, 4)),
    min_size=1, max_size=40))
def test_simulator_invariants(spec):
    """Property: every job runs exactly once, never before submit, and
    capacity is never exceeded at any event time."""
    jobs = [Job(jid=i, submit=s, runtime=r, walltime=r + w,
                demands={"node": n, "bb": b})
            for i, (s, r, w, n, b) in enumerate(spec)]
    res = [ResourceSpec("node", 8), ResourceSpec("bb", 4)]
    r = run_trace(res, jobs, FCFSPolicy())
    assert len(r.jobs) == len(jobs)
    for j in r.jobs:
        assert j.start >= j.submit - 1e-9
        assert j.end == pytest.approx(j.start + j.runtime)
    # capacity check at every start event
    events = sorted(j.start for j in r.jobs)
    for t in events:
        for name, cap in (("node", 8), ("bb", 4)):
            used = sum(j.demands.get(name, 0) for j in r.jobs
                       if j.start <= t < j.end)
            assert used <= cap


def test_metrics_utilization_bounds():
    jobs = mk_jobs([(0.0, 100.0, 100.0, {"node": 4, "bb": 0})])
    r = run_trace([ResourceSpec("node", 4), ResourceSpec("bb", 2)], jobs,
                  FCFSPolicy())
    assert r.metrics.utilization["node"] == pytest.approx(1.0, abs=1e-6)
    assert r.metrics.utilization["bb"] == 0.0
    assert r.metrics.avg_wait == 0.0
    assert r.metrics.avg_slowdown == pytest.approx(1.0)


def test_metrics_as_row_covers_every_field():
    """Regression: ``as_row`` once silently dropped ``max_wait``, so every
    sweep/bench CSV lost the tail-latency column.  Pin that each dataclass
    field appears in the row (utilization expands to util_<name>)."""
    import dataclasses

    from repro.sim.metrics import ScheduleMetrics

    m = ScheduleMetrics(utilization={"node": 0.5, "bb": 0.25}, avg_wait=1.0,
                        avg_slowdown=2.0, avg_bounded_slowdown=1.5,
                        p95_wait=7.0, max_wait=9.0, n_jobs=3, makespan=10.0)
    row = m.as_row()
    for f in dataclasses.fields(ScheduleMetrics):
        if f.name == "utilization":
            continue
        assert row[f.name] == getattr(m, f.name), f.name
    assert row["util_node"] == 0.5 and row["util_bb"] == 0.25
    assert len(row) == len(dataclasses.fields(ScheduleMetrics)) - 1 + 2


def test_truncated_jobs_counts_queue_beyond_window():
    """Regression pin for ``truncated_jobs``: waiting jobs the W-window
    encoding cannot see, summed over decisions, identical across engines.

    Six full-machine jobs all submit at t=0 with window=2, so exactly one
    runs at a time and every decision point is deterministic.  Each
    event yields one decision with k jobs waiting (truncated k-2), then a
    follow-up decision after one start with k-1 waiting (truncated k-3):
    (4+3) + (3+2) + (2+1) + (1+0) + (0+0) + 0 = 16.
    """
    from repro.sim import run_traces, run_traces_device

    jobs = [Job(jid=i, submit=0.0, runtime=100.0, walltime=100.0,
                demands={"node": 4}) for i in range(6)]
    res = [ResourceSpec("node", 4)]
    seq = run_trace(res, jobs, FCFSPolicy(), window=2)
    assert seq.truncated_jobs == 16
    assert seq.metrics.truncated_jobs == 16
    assert seq.metrics.as_row()["truncated_jobs"] == 16
    vec = run_traces(res, [jobs], FCFSPolicy(), window=2)[0]
    dev = run_traces_device(res, [jobs], FCFSPolicy(),
                            SimConfig.for_engine("device", window=2))[0]
    assert vec.truncated_jobs == dev.truncated_jobs == 16
    # A window wide enough for the whole trace truncates nothing.
    assert run_trace(res, jobs, FCFSPolicy(), window=8).truncated_jobs == 0


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.lists(
    st.tuples(st.integers(0, 60), st.integers(1, 300), st.integers(0, 200),
              st.integers(1, 8), st.integers(0, 4)),
    min_size=1, max_size=25))
def test_three_engines_agree_on_f32_exact_traces(spec):
    """Property: the sequential, vector, and device engines produce the
    same schedule.  Times are drawn as integers, which float32 represents
    exactly (< 2**24), so the device engine's f32 clock can introduce no
    rounding and no event-time collisions — every derived metric must
    match across all three engines to numerical noise."""
    from repro.sim import run_traces, run_traces_device

    jobs, t = [], 0.0
    for i, (gap, r, w, n, b) in enumerate(spec):
        t += gap
        jobs.append(Job(jid=i, submit=t, runtime=float(r),
                        walltime=float(r + w),
                        demands={"node": n, "bb": b}))
    res = [ResourceSpec("node", 8), ResourceSpec("bb", 4)]
    seq = run_trace(res, jobs, FCFSPolicy())
    vec = run_traces(res, [jobs], FCFSPolicy())[0]
    dev = run_traces_device(res, [jobs], FCFSPolicy())[0]
    for other in (vec, dev):
        assert other.decisions == seq.decisions
        assert other.n_unstarted == seq.n_unstarted
        ra, rb = seq.metrics.as_row(), other.metrics.as_row()
        for k in ra:
            assert rb[k] == pytest.approx(ra[k], rel=1e-6, abs=1e-6), k
        for ja, jb in zip(seq.jobs, other.jobs):
            assert (ja.jid, ja.started) == (jb.jid, jb.started)
            if ja.started:
                assert jb.start == pytest.approx(ja.start, abs=1e-3)
