"""Hardened SWF parsing against a deliberately messy fixture trace."""
from pathlib import Path

import pytest

from repro.workloads import ThetaConfig, build_jobs, jobs_from_swf, register_swf

FIXTURE = Path(__file__).parent / "data" / "sample.swf"


@pytest.fixture(scope="module")
def jobs():
    return jobs_from_swf(str(FIXTURE), n_nodes=256)


def test_skips_comments_blank_and_malformed(jobs):
    # 10 data-ish lines; kept: jids 1,2,3,5,6,9 (see fixture comments).
    assert [j.jid for j in jobs] == [1, 5, 2, 3, 6, 9]


def test_sorted_by_submit_then_jid(jobs):
    keys = [(j.submit, j.jid) for j in jobs]
    assert keys == sorted(keys)


def test_negative_submit_clamped(jobs):
    j5 = next(j for j in jobs if j.jid == 5)
    assert j5.submit == 0.0
    # req_time (300) < runtime (600): walltime raised to the runtime.
    assert j5.walltime == j5.runtime == 600.0


def test_runtime_sentinel_falls_back_to_request(jobs):
    j3 = next(j for j in jobs if j.jid == 3)
    assert j3.runtime == 5400.0 and j3.walltime == 5400.0


def test_procs_sentinel_falls_back_to_request(jobs):
    j2 = next(j for j in jobs if j.jid == 2)
    assert j2.demands["node"] == 128


def test_oversized_request_clamped_to_cluster(jobs):
    j6 = next(j for j in jobs if j.jid == 6)
    assert j6.demands["node"] == 256


def test_unschedulable_rows_dropped(jobs):
    # jid 4 (all sentinels) and jid 8 (zero runtime, no request) are gone.
    assert {4, 8}.isdisjoint({j.jid for j in jobs})


def test_invariants_hold_for_every_job(jobs):
    for j in jobs:
        assert j.runtime > 0
        assert j.walltime >= j.runtime
        assert j.submit >= 0
        assert 0 < j.demands["node"] <= 256
        assert j.demands["bb"] == 0


def test_workflow_dep_and_think_parsed(jobs):
    j2 = next(j for j in jobs if j.jid == 2)
    assert j2.deps == (1,) and j2.think_time == 120.0


def test_negative_think_clamped(jobs):
    j3 = next(j for j in jobs if j.jid == 3)
    assert j3.deps == (2,) and j3.think_time == 0.0


def test_bogus_predecessors_dropped(jobs):
    # jid 1: forward reference; jid 5: self; jid 6: parent row was
    # unschedulable; jid 9: SWF 0 = "no predecessor" (its stray think
    # time is discarded with the edge).
    for jid in (1, 5, 6, 9):
        j = next(j for j in jobs if j.jid == jid)
        assert j.deps == () and j.think_time == 0.0


def test_truncation_never_leaves_dangling_deps():
    for k in range(1, 7):
        got = jobs_from_swf(str(FIXTURE), n_nodes=256, max_jobs=k)
        kept = {j.jid for j in got}
        for j in got:
            assert set(j.deps) <= kept


def test_max_jobs_truncates():
    got = jobs_from_swf(str(FIXTURE), n_nodes=256, max_jobs=2)
    assert len(got) == 2


def test_swf_registry_scenario():
    """SWF replay rides the scenario registry like any other family."""
    spec = register_swf("swf-fixture", str(FIXTURE), overwrite=True)
    assert spec.family == "swf"
    cfg = ThetaConfig.mini(seed=0)
    jobs = build_jobs("swf-fixture", cfg, seed=1)
    assert [j.jid for j in jobs] == [1, 5, 2, 3, 6, 9]
    # seed is irrelevant for a real trace: identical replay either way
    assert [j.jid for j in build_jobs("swf-fixture", cfg, seed=9)] == \
        [j.jid for j in jobs]
