"""Baseline zoo contracts: every entrant is deterministic, its batched
decisions match its single-context decisions, and the sequential engine
and the lockstep vector engine produce identical trajectories for it on
registry scenarios (>= 2 per policy — the engine-parity gate every new
zoo member must pass)."""
import pytest

from repro.baselines import (CoSchedConfig, CoSchedPolicy, CPConfig,
                             CPDispatcher, DRASConfig, DRASPolicy, PRBConfig,
                             PRBPolicy)
from repro.core.policy_api import supports_batch, supports_device
from repro.sim import SimConfig, Simulator, run_trace, run_traces
from repro.workloads import ThetaConfig
from repro.workloads.registry import build_jobs

CFG = ThetaConfig.mini(seed=0, duration_days=0.35, jobs_per_day=140)
RES = CFG.resources()
SCENARIOS = ("S2", "bursty-campaigns")      # two registry scenarios


def make(name):
    """Fresh zoo instance (same construction the tournament uses)."""
    return {
        "PRB-EWT": lambda: PRBPolicy(RES, PRBConfig()),
        "CP-Dispatch": lambda: CPDispatcher(CPConfig()),
        "DRAS": lambda: DRASPolicy(RES, DRASConfig(seed=0)),
        "CoSchedRL": lambda: CoSchedPolicy(RES, CoSchedConfig(seed=0)),
    }[name]()


ZOO = ("PRB-EWT", "CP-Dispatch", "DRAS", "CoSchedRL")


def assert_results_equal(a, b):
    assert a.metrics.as_row() == b.metrics.as_row()
    assert a.decisions == b.decisions
    assert a.n_unstarted == b.n_unstarted
    assert [(j.jid, j.start, j.end) for j in a.jobs] \
        == [(j.jid, j.start, j.end) for j in b.jobs]


@pytest.fixture(scope="module")
def traces():
    return [build_jobs(s, CFG, seed=1) for s in SCENARIOS]


@pytest.mark.parametrize("name", ZOO)
def test_zoo_is_batchable(name):
    policy = make(name)
    assert supports_batch(policy)
    # the pure score_window entrants also qualify for the device engine
    if name != "CP-Dispatch":
        assert supports_device(policy)


@pytest.mark.parametrize("name", ZOO)
def test_sequential_equals_vector_on_registry_scenarios(name, traces):
    """Engine parity: the lockstep vector engine must not change any
    trajectory vs one-at-a-time sequential simulation."""
    policy = make(name)
    seq = [run_trace(RES, js, policy) for js in traces]
    vec = run_traces(RES, traces, policy)
    for a, b in zip(seq, vec):
        assert_results_equal(a, b)
        assert b.decisions > 0              # the policy actually ran


@pytest.mark.parametrize("name", ZOO)
def test_zoo_policy_is_deterministic(name, traces):
    """Two fresh instances (same config/seed) schedule identically."""
    a = run_traces(RES, traces, make(name))
    b = run_traces(RES, traces, make(name))
    for ra, rb in zip(a, b):
        assert_results_equal(ra, rb)


@pytest.mark.parametrize("name", ZOO)
def test_select_batch_matches_select(name, traces):
    """One batched call over N contexts == N single calls, row for row."""
    policy = make(name)
    sims = [Simulator(RES, js, policy, SimConfig(window=10)) for js in traces]
    ctxs = [s.next_decision() for s in sims]
    assert all(c is not None for c in ctxs)
    batch = [int(a) for a in policy.select_batch(ctxs)]
    assert batch == [int(policy.select(c)) for c in ctxs]


def test_zoo_entrants_differ_from_each_other(traces):
    """The zoo adds signal, not four FCFS clones: on a contended trace
    the entrants' decision sequences are not all identical."""
    outcomes = {name: tuple(r.decisions for r in run_traces(RES, traces,
                                                            make(name)))
                for name in ZOO}
    starts = {name: tuple(j.start for r in run_traces(RES, traces, make(name))
                          for j in r.jobs)
              for name in ZOO}
    assert len(set(starts.values())) > 1, outcomes
