"""End-to-end behaviour tests for the paper's system.

The heavier statistical comparisons (MRSch vs baselines across S1-S5) live
in benchmarks/; here we assert the end-to-end mechanics: the agent trains
(loss finite and decreasing-ish), schedules a full trace without deadlock,
adapts its goal vector, and the fleet integration round-trips.
"""
import numpy as np
import pytest

from repro.core import AgentConfig, FCFSPolicy, MRSchAgent, evaluate, train_agent
from repro.sim import run_trace
from repro.workloads import ThetaConfig, build_scenarios, sampled_jobsets

# Full training runs — exercised by the slow CI lane (`pytest -m slow`).
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    cfg = ThetaConfig.mini(seed=0, duration_days=1.0, jobs_per_day=200)
    res = cfg.resources()
    trace = build_scenarios(cfg, names=("S4",))["S4"]
    return cfg, res, trace


def make_agent(res):
    return MRSchAgent(res, AgentConfig(
        state_hidden=(128, 64), state_out=32, module_hidden=16,
        grad_steps_per_episode=12, batch_size=32, eps_decay=0.8, seed=0))


def test_agent_trains_and_schedules(setup):
    cfg, res, trace = setup
    agent = make_agent(res)
    log = train_agent(agent, res, sampled_jobsets(trace, 3, 120, seed=1))
    assert log.episode_losses, "no training happened"
    assert all(np.isfinite(l) for l in log.episode_losses)
    r = evaluate(agent, res, trace)
    assert len(r.jobs) == len(trace)            # everything ran, no deadlock
    assert all(j.started for j in r.jobs)


def test_goal_vector_tracks_contention(setup):
    """Eq. (1): fiercer BB contention must raise r_BB (Fig. 9's claim).
    Compare the BB-heavy S4 workload against the same jobs with burst
    buffer demands removed (mini-scale S1 vs S4 gaps compress under
    per-unit ceiling rounding, so the sparse-BB base trace is the robust
    light case)."""
    cfg, res, _ = setup
    agent = make_agent(res)
    heavy = build_scenarios(cfg, names=("S4",), seed=3)["S4"]
    agent.goal_log.clear()
    evaluate(agent, res, heavy)
    r_bb = np.array([g[1] for g in agent.goal_log])
    assert r_bb.std() > 0.005                    # dynamic, not fixed
    light = [j.copy() for j in heavy]
    for j in light:
        j.demands["bb"] = 0
    agent.goal_log.clear()
    evaluate(agent, res, light)
    r_bb_light = np.array([g[1] for g in agent.goal_log])
    assert r_bb.mean() > r_bb_light.mean() + 0.05


def test_same_jobs_all_scheduled_as_fcfs(setup):
    """The agent must preserve completeness relative to FCFS."""
    _, res, trace = setup
    agent = make_agent(res)
    r1 = evaluate(agent, res, trace)
    r2 = run_trace(res, trace, FCFSPolicy())
    assert {j.jid for j in r1.jobs} == {j.jid for j in r2.jobs}


def test_fleet_scheduler_end_to_end():
    from repro.launch.scheduler import FleetSpec, schedule_fleet, synth_fleet_trace
    fleet = FleetSpec()
    jobs = synth_fleet_trace(fleet, 30, seed=5)
    r = schedule_fleet(jobs, fleet, "fcfs")
    assert len(r.jobs) == 30
    assert r.metrics.utilization["chips"] > 0
