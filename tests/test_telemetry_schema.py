"""Row-schema stability: ScheduleMetrics.as_row / CSV column order and
the prefix-compat contract committed baselines rely on (older baselines
without the lifecycle columns must still gate newer results)."""
import dataclasses
import importlib.util
import json
from pathlib import Path

from repro.eval import matrix_columns, matrix_csv
from repro.eval.matrix import CORE_COLUMNS, METRIC_COLUMNS
from repro.sim import ResourceSpec
from repro.sim.metrics import ScheduleMetrics

REPO = Path(__file__).resolve().parent.parent

LIFECYCLE_COLUMNS = ("requeues", "n_failed", "failed_node_hours",
                     "completed_work_frac", "pipeline_makespan")

RES = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_bench = _load("check_bench_schema", "tools/check_bench.py")


def sample_metrics() -> ScheduleMetrics:
    return ScheduleMetrics(
        utilization={"node": 0.5, "bb": 0.25}, avg_wait=10.0,
        avg_slowdown=1.5, avg_bounded_slowdown=1.2, p95_wait=30.0,
        max_wait=60.0, n_jobs=40, makespan=1000.0, truncated_jobs=2,
        requeues=3, n_failed=1, failed_node_hours=12.5,
        completed_work_frac=0.9, pipeline_makespan=800.0)


def test_as_row_key_order_matches_matrix_schema():
    row = sample_metrics().as_row()
    assert list(row) == ["util_node", "util_bb"] + list(METRIC_COLUMNS)


def test_as_row_drops_no_dataclass_field():
    m = sample_metrics()
    row = m.as_row()
    scalar = {f.name for f in dataclasses.fields(ScheduleMetrics)
              if f.name != "utilization"}
    assert scalar == set(METRIC_COLUMNS) <= set(row)
    for name in m.utilization:
        assert row[f"util_{name}"] == m.utilization[name]


def test_matrix_columns_order_and_lifecycle_tail():
    cols = matrix_columns(RES)
    assert cols[:len(CORE_COLUMNS)] == list(CORE_COLUMNS)
    assert cols[len(CORE_COLUMNS):len(CORE_COLUMNS) + 2] \
        == ["util_node", "util_bb"]
    # The five lifecycle columns were appended LAST so pre-lifecycle
    # baselines keep prefix-comparing.
    assert cols[-5:] == list(LIFECYCLE_COLUMNS)


def test_csv_header_and_cell_order_follow_columns():
    cols = matrix_columns(RES)
    row = {"policy": "FCFS", "scenario": "S2", "family": "paper",
           "drift": False, "seed": 1, "decisions": 7, "n_unstarted": 0}
    row.update({c: i for i, c in enumerate(cols[len(CORE_COLUMNS):])})
    csv = matrix_csv({"columns": cols, "rows": [row]})
    lines = csv.splitlines()
    assert lines[0] == ",".join(cols)
    assert lines[1].split(",") == [str(row[c]) for c in cols]


def test_pre_lifecycle_baseline_prefix_compares():
    """check_bench's list rule: a baseline columns array shorter than
    the result's gates only the shared prefix — an old baseline still
    accepts rows that grew the lifecycle tail, but a result that LOST
    columns (or reordered them) fails."""
    cols = matrix_columns(RES)
    old = {"columns": cols[:-5]}
    assert check_bench.compare({"columns": cols}, old, rtol=0.0) == []
    # result truncated below the baseline contract -> violation
    assert check_bench.compare(old, {"columns": cols}, rtol=0.0)
    # reordering inside the shared prefix -> violation
    swapped = cols[:-5]
    swapped[0], swapped[1] = swapped[1], swapped[0]
    assert check_bench.compare({"columns": swapped}, old, rtol=0.0)


def test_committed_matrix_baseline_matches_current_schema():
    base = json.loads(
        (REPO / "benchmarks/baselines/matrix.json").read_text())
    res = [ResourceSpec(n, 1) for n in base["config"]["resources"]]
    assert base["columns"] == matrix_columns(res)
    assert base["columns"][-5:] == list(LIFECYCLE_COLUMNS)
