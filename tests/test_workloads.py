"""Workload generator statistics vs the published trace properties."""
import numpy as np
import pytest

from repro.workloads import (SCENARIOS, ThetaConfig, build_curriculum,
                             build_scenarios, derive_scenario, generate_trace,
                             with_power)


def test_theta_full_dims_match_paper():
    cfg = ThetaConfig()
    assert cfg.n_nodes == 4392
    assert cfg.bb_units == 1293
    # 4W + 2*N1 + 2*N2 = 11410 with W=10 (checked in test_dfp too)
    assert 4 * 10 + 2 * cfg.n_nodes + 2 * cfg.bb_units == 11410


def test_base_trace_io_statistics():
    """~40% of jobs with I/O records; ~17.18% moving >1GB (paper §IV-A)."""
    cfg = ThetaConfig.mini(seed=3, duration_days=40, jobs_per_day=200)
    jobs = generate_trace(cfg)
    assert len(jobs) > 3000
    frac_bb = np.mean([j.demands["bb"] > 0 for j in jobs])
    # >1GB movers get >=1 BB unit; small movers round to >=1 unit too at
    # mini scale, so check the big-mover fraction via raw generation stats.
    assert 0.05 < frac_bb < 0.45


def test_jobs_fit_capacity():
    cfg = ThetaConfig.mini(seed=0)
    for j in generate_trace(cfg):
        assert 0 < j.demands["node"] <= cfg.n_nodes
        assert 0 <= j.demands["bb"] <= cfg.bb_units
        assert j.walltime >= j.runtime > 0


@pytest.mark.parametrize("name", list(SCENARIOS))
def test_scenarios_match_table_iii(name):
    cfg = ThetaConfig.mini(seed=1, duration_days=10)
    base = generate_trace(cfg)
    jobs = derive_scenario(base, cfg, name, seed=5)
    frac, lo_tb, halve = SCENARIOS[name]
    got_frac = np.mean([j.demands["bb"] > 0 for j in jobs])
    assert got_frac == pytest.approx(frac, abs=0.08)
    if halve:
        pairs = [(b.demands["node"], j.demands["node"])
                 for b, j in zip(base, jobs)]
        assert all(jn <= max(bn // 2, 1) for bn, jn in pairs)


def test_power_profiles():
    cfg = ThetaConfig.mini(seed=2, duration_days=5)
    jobs = with_power(generate_trace(cfg), cfg)
    for j in jobs:
        watts = j.demands["power"] * 1000.0
        assert watts >= j.demands["node"] * 100.0 - 1000
        assert watts <= j.demands["node"] * 215.0 + 1000


def test_curriculum_structure():
    cfg = ThetaConfig.mini(seed=0, duration_days=6)
    trace = generate_trace(cfg)
    cur = build_curriculum(cfg, trace, n_sampled=2, n_real=2, n_synth=3,
                           jobs_per_set=100)
    assert len(cur.sampled) == 2 and len(cur.real) == 2 \
        and len(cur.synthetic) == 3
    ordered = cur.ordered("sampled_real_synthetic")
    assert len(ordered) == 7
    for js in ordered:
        assert all(js[i].submit <= js[i + 1].submit
                   for i in range(len(js) - 1))
