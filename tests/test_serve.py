"""Decision service: micro-batching, concurrency determinism, shape-bucket
compile cache, checkpoint hot-reload, and service-routed replay parity."""
import threading

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import AgentConfig, MRSchAgent
from repro.core.dfp import greedy_action
from repro.obs import BufferTracer, MetricsRegistry
from repro.serve import (BucketCache, CheckpointWatcher, DecisionResponse,
                         DecisionService, MicroBatcher, ServeConfig,
                         ServiceSim, bucket_widths)
from repro.sim import (Job, ResourceSpec, Simulator, run_trace, run_traces,
                       sim_config)

RES = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]


def synth_jobs(seed: int, n: int = 40):
    rng = np.random.default_rng(seed)
    jobs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(40.0))
        runtime = float(rng.uniform(20, 300))
        jobs.append(Job(jid=i, submit=t, runtime=runtime,
                        walltime=runtime * float(rng.uniform(1.0, 2.0)),
                        demands={"node": int(rng.integers(1, 12)),
                                 "bb": int(rng.integers(0, 6))}))
    return jobs


def small_agent(seed: int = 0, backend: str = "xla") -> MRSchAgent:
    return MRSchAgent(RES, AgentConfig(
        state_hidden=(32, 16), state_out=8, module_hidden=4, seed=seed,
        backend=backend))


def harvest_contexts(agent, n_envs: int = 6, depth: int = 5):
    """Frozen mid-trace contexts: step each env a few decisions in, then
    freeze its pending decision.  A context owns references to its
    simulator's cluster/queue/jobs, so it stays valid after the (never
    advanced again) simulator is dropped."""
    ctxs = []
    for s in range(n_envs):
        sim = Simulator(RES, synth_jobs(s), agent)
        ctx = sim.next_decision()
        for _ in range(depth):
            if ctx is None:
                break
            sim.post_action(agent.select(ctx))
            ctx = sim.next_decision()
        if ctx is not None:
            ctxs.append(ctx)
    assert len(ctxs) >= 4
    return ctxs


def assert_results_equal(a, b):
    assert a.metrics.as_row() == b.metrics.as_row()
    assert a.decisions == b.decisions
    assert a.n_unstarted == b.n_unstarted
    assert [(j.jid, j.start, j.end) for j in a.jobs] \
        == [(j.jid, j.start, j.end) for j in b.jobs]


# ---------------------------------------------------------------- batcher
def test_batcher_results_match_payloads():
    with MicroBatcher(lambda xs: [x * 10 for x in xs], max_batch=4) as mb:
        tickets = [mb.submit(i) for i in range(17)]
        assert [t.result(10.0) for t in tickets] == [i * 10 for i in range(17)]
    st = mb.stats()
    assert st["requests"] == 17
    assert st["max_batch_seen"] <= 4


def test_batcher_error_delivered_to_batch():
    def boom(xs):
        raise RuntimeError("model exploded")
    with MicroBatcher(boom, max_batch=2) as mb:
        t = mb.submit(1)
        with pytest.raises(RuntimeError, match="model exploded"):
            t.result(10.0)


def test_batcher_submit_requires_running():
    mb = MicroBatcher(lambda xs: xs)
    with pytest.raises(RuntimeError, match="not running"):
        mb.submit(1)
    mb.start()
    t = mb.submit(2)
    assert t.result(10.0) == 2
    mb.stop()
    with pytest.raises(RuntimeError, match="not running"):
        mb.submit(3)


def test_batcher_max_wait_coalesces():
    """With a wait budget the worker holds the batch open for stragglers
    instead of dispatching the first payload alone."""
    with MicroBatcher(lambda xs: xs, max_batch=8, max_wait_s=0.2) as mb:
        tickets = []

        def client(i):
            tickets.append(mb.submit(i))
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for t in tickets:
            t.result(10.0)
    st = mb.stats()
    assert st["requests"] == 6
    assert st["max_batch_seen"] >= 2          # some coalescing happened


# ---------------------------------------------------------------- buckets
def test_bucket_widths_and_lookup():
    assert bucket_widths(1) == (1,)
    assert bucket_widths(8) == (1, 2, 4, 8)
    assert bucket_widths(12) == (1, 2, 4, 8, 16)
    cache = BucketCache(12)
    assert cache.width_for(1) == 1
    assert cache.width_for(3) == 4
    assert cache.width_for(12) == 16
    with pytest.raises(ValueError):
        cache.width_for(17)
    with pytest.raises(ValueError):
        cache.width_for(0)


def test_bucket_cache_counts_compiles_once():
    cache = BucketCache(4)
    assert cache.record(4) is True            # first dispatch = trace
    assert cache.record(4) is False
    st = cache.stats()
    assert st["compiles"] == 1
    assert st["dispatches"] == 2
    assert st["bucket_hits"] == 1


def test_service_steady_state_never_retraces():
    """After warmup every batch width maps to an already-compiled bucket:
    the compile count is pinned at the bucket count forever."""
    agent = small_agent()
    with DecisionService(agent, ServeConfig(max_batch=8)) as svc:
        n_buckets = len(svc._buckets.widths)
        assert svc.stats()["buckets"]["compiles"] == n_buckets  # warmup
        ctxs = harvest_contexts(agent)
        for width in (1, 2, 3, len(ctxs)):    # mixed widths, incl. non-pow2
            svc.decide_many(ctxs[:width])
        st = svc.stats()["buckets"]
        assert st["compiles"] == n_buckets    # no steady-state retrace
        assert st["bucket_hits"] > 0


# ---------------------------------------------------------------- determinism
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_concurrent_clients_bit_identical(backend):
    """N client threads through the micro-batcher receive bit-identical
    actions to sequential agent.select on the same contexts."""
    agent = small_agent(backend=backend)
    ctxs = harvest_contexts(agent, n_envs=6 if backend == "xla" else 4)
    expected = [agent.select(c) for c in ctxs]
    with DecisionService(agent, ServeConfig(max_batch=8,
                                            warmup=(backend == "xla"))) as svc:
        results = [None] * len(ctxs)

        def client(i):
            results[i] = svc.decide(ctxs[i])
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(ctxs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert results == expected


def test_goal_override_matches_direct_scoring():
    """A per-request goal override reweights the prediction exactly as
    the jitted single-decision scorer does with that goal."""
    agent = small_agent()
    ctxs = harvest_contexts(agent, n_envs=4)
    override = np.asarray([0.9, 0.1], np.float32)
    with DecisionService(agent, ServeConfig(max_batch=4)) as svc:
        got = [svc.decide(c, goal=override) for c in ctxs]
        with pytest.raises(ValueError, match="goal override"):
            svc.decide(ctxs[0], goal=np.ones(3, np.float32))
    from repro.core.encoding import encode_measurement, encode_state
    import jax.numpy as jnp
    expected = []
    for c in ctxs:
        mask = np.zeros(agent.config.window, bool)
        mask[:min(len(c.window), agent.config.window)] = True
        expected.append(int(greedy_action(
            agent.params, agent.dfp,
            jnp.asarray(encode_state(agent.enc, c)),
            jnp.asarray(encode_measurement(agent.enc, c)),
            jnp.asarray(override), jnp.asarray(mask))))
    assert got == expected


# ---------------------------------------------------------------- replay
def test_service_replay_bit_identical_to_direct():
    """Acceptance: service-routed replay == direct Simulator replay."""
    agent = small_agent()
    jobs = synth_jobs(3)
    direct = run_trace(RES, jobs, agent)
    with DecisionService(agent, ServeConfig(max_batch=8)) as svc:
        served = ServiceSim(svc, RES).run_trace(jobs)
    assert_results_equal(served, direct)


def test_service_vector_replay_bit_identical():
    """Lockstep replay through the service (decide_many coalescing whole
    rounds) matches the direct batched rollout."""
    agent = small_agent()
    jobsets = [synth_jobs(seed) for seed in range(4)]
    direct = run_traces(RES, jobsets, agent)
    with DecisionService(agent, ServeConfig(max_batch=8)) as svc:
        served = ServiceSim(svc, RES).run_traces(jobsets)
    for a, b in zip(served, direct):
        assert_results_equal(a, b)


def test_service_scenario_replay_matches_direct():
    """Registry-scenario replay through the service produces identical
    ScheduleMetrics to the direct simulator run (acceptance criterion)."""
    from repro.workloads import ThetaConfig, build_jobs
    cfg = ThetaConfig.mini(seed=0, duration_days=0.3, jobs_per_day=120)
    res = cfg.resources()
    agent = MRSchAgent(res, AgentConfig(state_hidden=(32, 16), state_out=8,
                                        module_hidden=4))
    jobs = build_jobs("S1", cfg, seed=1)
    direct = run_trace(res, jobs, agent)
    with DecisionService(agent, ServeConfig(max_batch=8)) as svc:
        served = ServiceSim(svc, res).run_scenario("S1", cfg, seed=1)
    assert_results_equal(served, direct)


def test_service_sim_tracks_latency():
    agent = small_agent()
    with DecisionService(agent, ServeConfig(max_batch=4)) as svc:
        ssim = ServiceSim(svc, RES, track_latency=True)
        result = ssim.run_trace(synth_jobs(1, n=15))
    assert len(ssim.latencies_s) == result.decisions
    assert all(t > 0 for t in ssim.latencies_s)


def test_sim_config_validation():
    with pytest.raises(ValueError, match="window"):
        sim_config(window=0)
    with pytest.raises(ValueError, match="max_events"):
        sim_config(max_events=0)
    cfg = sim_config(window=5, backfill=False, max_events=10)
    assert (cfg.window, cfg.backfill, cfg.max_events) == (5, False, 10)


# ---------------------------------------------------------------- hot reload
def test_hot_reload_mid_stream(tmp_path):
    """Requests answered before the swap see the old params, requests
    after see the new ones, and none are dropped or corrupted."""
    agent_a = small_agent(seed=0)
    agent_b = small_agent(seed=13)
    ctxs = harvest_contexts(agent_a)
    expected_a = [agent_a.select(c) for c in ctxs]
    expected_b = [agent_b.select(c) for c in ctxs]
    assert expected_a != expected_b           # the swap is observable
    mgr = CheckpointManager(str(tmp_path))
    with DecisionService(agent_a, ServeConfig(max_batch=8)) as svc:
        watcher = CheckpointWatcher(svc, str(tmp_path))
        before = [svc.decide(c) for c in ctxs]
        mgr.save(agent_b.params, step=5)
        assert watcher.check_once() == 5
        assert svc.params_step == 5
        after = [svc.decide(c) for c in ctxs]
    assert before == expected_a
    assert after == expected_b
    assert svc.stats()["reloads"] == 1


def test_hot_reload_with_concurrent_clients(tmp_path):
    """Params swap while clients are submitting: every answer is the
    correct greedy action under either the old or the new params, and
    every request is answered exactly once."""
    agent_a = small_agent(seed=0)
    agent_b = small_agent(seed=13)
    ctxs = harvest_contexts(agent_a)
    expected_a = [agent_a.select(c) for c in ctxs]
    expected_b = [agent_b.select(c) for c in ctxs]
    rounds = 30
    with DecisionService(agent_a, ServeConfig(max_batch=8)) as svc:
        results = [[None] * rounds for _ in ctxs]
        finals = [None] * len(ctxs)
        swapped = threading.Event()

        def client(i):
            for r in range(rounds):       # overlaps the swap below
                results[i][r] = svc.decide(ctxs[i])
            swapped.wait()                # then one strictly-post-swap round
            finals[i] = svc.decide(ctxs[i])
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(ctxs))]
        for t in threads:
            t.start()
        svc.update_params(agent_b.params, step=1)
        swapped.set()
        for t in threads:
            t.join()
    for i in range(len(ctxs)):
        valid = {expected_a[i], expected_b[i]}
        assert all(r in valid for r in results[i])
        assert finals[i] == expected_b[i]     # post-swap settles on B


def test_update_params_rejects_incompatible_tree():
    agent = small_agent()
    wrong_width = MRSchAgent(RES, AgentConfig(
        state_hidden=(16, 8), state_out=8, module_hidden=4))
    ctxs = harvest_contexts(agent, n_envs=4)
    expected = [agent.select(c) for c in ctxs]
    with DecisionService(agent, ServeConfig(max_batch=4)) as svc:
        with pytest.raises(ValueError, match="shape mismatch"):
            svc.update_params(wrong_width.params)
        with pytest.raises(ValueError, match="tree structure"):
            svc.update_params({"not": "a param tree"})
        # the failed swaps left the service serving the original params
        assert [svc.decide(c) for c in ctxs] == expected
    assert svc.stats()["reloads"] == 0


def test_watcher_skips_stale_and_rejects_foreign(tmp_path):
    agent = small_agent()
    other = small_agent(seed=3)
    wrong = MRSchAgent(RES, AgentConfig(state_hidden=(16, 8), state_out=8,
                                        module_hidden=4))
    mgr = CheckpointManager(str(tmp_path), keep=5)
    with DecisionService(agent, ServeConfig(max_batch=4,
                                            warmup=False)) as svc:
        watcher = CheckpointWatcher(svc, str(tmp_path))
        assert watcher.check_once() is None   # empty directory
        mgr.save(agent.params, step=1)
        mgr.save(other.params, step=2)
        assert watcher.check_once() == 2      # straight to the newest
        assert watcher.check_once() is None   # already current
        mgr.save(wrong.params, step=3)        # foreign architecture
        assert watcher.check_once() is None
        st = watcher.stats()
        assert st["rejected"] == 1
        assert st["loaded_step"] == 3         # not retried until newer
        mgr.save(other.params, step=4)
        assert watcher.check_once() == 4      # recovers on the next good one
    assert svc.stats()["reloads"] == 2


def test_watcher_survives_stray_directory_entries(tmp_path):
    """A non-checkpoint step_* entry (operator's backup copy) must
    neither kill the watcher nor mask real checkpoints behind it."""
    agent = small_agent()
    other = small_agent(seed=3)
    (tmp_path / "step_backup").mkdir()        # int("backup") would raise
    with DecisionService(agent, ServeConfig(max_batch=4,
                                            warmup=False)) as svc:
        watcher = CheckpointWatcher(svc, str(tmp_path))
        assert watcher.check_once() is None   # stray entry alone: no-op
        CheckpointManager(str(tmp_path)).save(other.params, step=7)
        assert watcher.check_once() == 7      # real checkpoint still found
    assert svc.params_step == 7


def test_decide_many_rejects_mismatched_goals():
    agent = small_agent()
    ctxs = harvest_contexts(agent, n_envs=4)
    with DecisionService(agent, ServeConfig(max_batch=4)) as svc:
        with pytest.raises(ValueError, match="decide_many"):
            svc.decide_many(ctxs, goals=[None] * (len(ctxs) - 1))


# ------------------------------------------------------------ telemetry
def test_decide_full_carries_per_request_telemetry():
    """Every response reports how long the request queued, how many
    requests shared its batch, and the padded width it dispatched at —
    with the action identical to the plain decide() path."""
    agent = small_agent()
    ctxs = harvest_contexts(agent, n_envs=4)
    with DecisionService(agent, ServeConfig(max_batch=4)) as svc:
        plain = [svc.decide(c) for c in ctxs]
        full = [svc.decide_full(c) for c in ctxs]
        widths = set(bucket_widths(svc.config.max_batch))
        for resp, action in zip(full, plain):
            assert isinstance(resp, DecisionResponse)
            assert resp.action == action
            assert resp.queue_wait_s >= 0.0
            assert 1 <= resp.batch_size <= svc.config.max_batch
            assert resp.width in widths
            assert resp.width >= resp.batch_size


def test_ticket_meta_populated_after_resolution():
    agent = small_agent()
    ctx = harvest_contexts(agent)[0]
    with DecisionService(agent, ServeConfig(max_batch=4)) as svc:
        ticket = svc.submit(ctx)
        ticket.result(10.0)
        assert set(ticket.meta) == {"queue_wait_s", "batch_size"}
        assert ticket.meta["queue_wait_s"] >= 0.0
        assert ticket.meta["batch_size"] >= 1


def test_service_registry_and_tracer_wiring():
    """The service fills its metrics registry and emits serve.dispatch /
    ckpt.reload host events when given a recording tracer."""
    agent, other = small_agent(), small_agent(seed=3)
    ctxs = harvest_contexts(agent, n_envs=4)
    reg, tracer = MetricsRegistry(), BufferTracer()
    with DecisionService(agent, ServeConfig(max_batch=4),
                         registry=reg, tracer=tracer) as svc:
        for c in ctxs:
            svc.decide(c)
        svc.update_params(other.params, step=5)
    snap = reg.snapshot()
    assert sum(snap["serve_requests_total"].values()) >= len(ctxs)
    assert sum(snap["serve_batches_total"].values()) >= 1
    assert snap["serve_reloads_total"][""] == 1.0
    assert sum(v for v in snap["serve_batch_rows_total"].values()) \
        >= len(ctxs)
    assert 0.0 <= snap["serve_bucket_hit_rate"][""] <= 1.0
    assert snap["serve_queue_wait_seconds"][""]["count"] >= len(ctxs)

    dispatches = [e for e in tracer.events if e["ev"] == "serve.dispatch"]
    assert dispatches and all(e["env"] == -1 and e["wait_s"] >= 0.0
                              and e["width"] >= e["n"]
                              for e in dispatches)
    reloads = [e for e in tracer.events if e["ev"] == "ckpt.reload"]
    assert [e["step"] for e in reloads] == [5]
