"""Import hypothesis, or degrade to skipping the property-based tests.

CI installs hypothesis (pinned in requirements.txt), but the library is
optional at runtime and some execution environments don't ship it.  A
missing import must not take down collection of a whole test module — the
example-based tests in the same file still have to run — so property tests
import ``given``/``settings``/``st`` from here instead of from hypothesis
directly.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies`` at decoration time only;
        the decorated tests are skipped, so strategies are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
