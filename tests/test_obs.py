"""Unified telemetry layer: engine trace parity (`mrsch.trace/v1`),
canonical ordering, JSONL/Chrome round-trips, the metrics registry, and
the trace_report CLI on a real matrix trace."""
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import AgentConfig, EnvSlot, FCFSPolicy, MRSchAgent, \
    TrainConfig, train_agent_vectorized
from repro.eval import MatrixConfig, run_matrix
from repro.obs import (NULL, BufferTracer, JsonlFlusher, MetricsRegistry,
                       Tracer, canonical_events, read_trace, to_chrome,
                       trace_lines, write_trace)
from repro.sim import (DeviceSimulator, DrainEvent, FaultSchedule, Job,
                       ResourceSpec, SimConfig, Simulator, VectorSimulator)
from repro.workloads import ThetaConfig
from repro.workloads.registry import build_jobs, register_swf

REPO = Path(__file__).resolve().parent.parent


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


trace_report = _load("trace_report", "tools/trace_report.py")


def synth_jobs(seed: int, n: int = 30):
    rng = np.random.default_rng(seed)
    jobs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(40.0))
        runtime = float(rng.uniform(20, 300))
        jobs.append(Job(jid=i, submit=t, runtime=runtime,
                        walltime=runtime * float(rng.uniform(1.0, 2.0)),
                        demands={"node": int(rng.integers(1, 12)),
                                 "bb": int(rng.integers(0, 6))}))
    return jobs


# -------------------------------------------------------------- parity
def test_trace_parity_three_engines_swf():
    """The acceptance pin: sequential, vector, and device engines emit
    byte-identical canonical streams for the same SWF replay.  Integer
    SWF timestamps avoid f32 chain-rounding divergence between the f64
    host clocks and the f32 device clock."""
    cfg = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=110)
    register_swf("obs-swf-test", str(REPO / "tests/data/sample.swf"),
                 overwrite=True)
    jobs = build_jobs("obs-swf-test", cfg, seed=1)
    res = [ResourceSpec("node", cfg.n_nodes)]

    t1 = BufferTracer()
    Simulator(res, jobs, FCFSPolicy(), SimConfig.for_engine("sequential"),
              tracer=t1).run()
    t2 = BufferTracer()
    VectorSimulator.from_jobsets(res, [jobs], FCFSPolicy(),
                                 SimConfig.for_engine("vector"),
                                 tracer=t2).run()
    t3 = BufferTracer()
    ds = DeviceSimulator(res, [jobs], FCFSPolicy(),
                         SimConfig.for_engine("device"))
    ds.emit_trace(ds.rollout(trace=True), t3)

    assert len(t1.events) > 0
    assert trace_lines(t1.events) == trace_lines(t2.events)
    assert trace_lines(t1.events) == trace_lines(t3.events)


def test_trace_parity_fault_path():
    """Lifecycle events (requeue, fail, drain/restore, dependency
    release) trace identically through the host and device engines."""
    jobs = [
        Job(jid=1, submit=0.0, runtime=100.0, walltime=200.0,
            demands={"node": 4}),
        Job(jid=2, submit=0.0, runtime=400.0, walltime=500.0,
            demands={"node": 6}, fail_times=(50.0,)),
        Job(jid=3, submit=10.0, runtime=300.0, walltime=400.0,
            demands={"node": 8}),
        Job(jid=4, submit=20.0, runtime=50.0, walltime=100.0,
            demands={"node": 2}, deps=(1,), think_time=30.0),
        Job(jid=5, submit=30.0, runtime=200.0, walltime=250.0,
            demands={"node": 4},
            fail_times=(20.0, 20.0, 20.0, 20.0, 20.0)),
        Job(jid=6, submit=40.0, runtime=80.0, walltime=120.0,
            demands={"node": 3}),
    ]
    faults = FaultSchedule(
        drains=(DrainEvent(time=120.0, resource="node", units=6,
                           duration=200.0),),
        max_requeues=2)
    res = [ResourceSpec("node", 12)]

    t1 = BufferTracer()
    Simulator(res, jobs, FCFSPolicy(), SimConfig.for_engine("sequential"),
              faults=faults, tracer=t1).run()
    t3 = BufferTracer()
    ds = DeviceSimulator(res, [jobs], FCFSPolicy(),
                         SimConfig.for_engine("device"), faults=faults)
    ds.emit_trace(ds.rollout(trace=True), t3)

    assert trace_lines(t1.events) == trace_lines(t3.events)
    kinds = {}
    for e in t1.events:
        kinds[e["ev"]] = kinds.get(e["ev"], 0) + 1
    # Every fault/workflow event kind shows up, with pinned counts.
    assert kinds == {"job.queued": 10, "sched.decision": 17,
                     "job.start": 10, "sched.reserve": 12,
                     "sched.backfill": 12, "job.requeue": 4,
                     "job.finish": 5, "fault.drain": 1,
                     "fault.restore": 1, "job.fail": 1}


def test_vector_interleaving_matches_two_sequential_sims():
    """One shared tracer, two envs: the vector engine's round-robin
    interleaving canonicalizes to the same stream as running each
    simulator alone (float-time jobs — host engines share arithmetic)."""
    res = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]
    jobs_a, jobs_b = synth_jobs(1), synth_jobs(2, n=20)

    seq = BufferTracer()
    cfg = SimConfig.for_engine("sequential")
    Simulator(res, jobs_a, FCFSPolicy(), cfg, tracer=seq, env=0).run()
    Simulator(res, jobs_b, FCFSPolicy(), cfg, tracer=seq, env=1).run()

    vec = BufferTracer()
    VectorSimulator.from_jobsets(res, [jobs_a, jobs_b], FCFSPolicy(),
                                 SimConfig.for_engine("vector"),
                                 tracer=vec).run()
    assert trace_lines(seq.events) == trace_lines(vec.events)


# ----------------------------------------------------- canonical order
def test_canonical_order_groups_envs_and_appends_host_events():
    tr = BufferTracer()
    tr.span("warmup", 0.5)            # host event emitted FIRST
    tr.job_queued(1, 5.0, 7)          # env 1 before env 0
    tr.job_queued(0, 5.0, 3)
    tr.job_finish(0, 5.0, 2)          # same (env, t): finish phase first
    got = [(e["env"], e["ev"]) for e in canonical_events(tr.events)]
    assert got == [(0, "job.finish"), (0, "job.queued"),
                   (1, "job.queued"), (-1, "prof.span")]


def test_null_tracer_accepts_every_emit():
    assert NULL.enabled is False and isinstance(NULL, Tracer)
    NULL.decision(0, 1.0, 2, 3, 4, 1)
    NULL.job_start(0, 1.0, 3, bf=1)
    NULL.drain(0, 1.0, "node", 4)
    NULL.dispatch(4, 8, 0.001)
    NULL.span("x", 0.1)


# ------------------------------------------------------------ round-trip
def test_write_read_roundtrip_and_header_validation(tmp_path):
    tr = BufferTracer()
    tr.meta["envs"] = {"0": {"policy": "FCFS", "scenario": "S1", "seed": 1}}
    tr.job_queued(0, 1.0, 1)
    tr.job_start(0, 2.0, 1)
    tr.job_finish(0, 3.5, 1)
    tr.span("phase", 0.25)
    p = write_trace(tr.events, tmp_path / "t.jsonl", meta=tr.meta)
    meta, events = read_trace(p)
    assert meta == tr.meta
    assert events == canonical_events(tr.events)
    head = json.loads(p.read_text().splitlines()[0])
    assert head["schema"] == "mrsch.trace/v1"

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"schema":"mrsch.trace/v999"}\n')
    with pytest.raises(ValueError, match="mrsch.trace/v1"):
        read_trace(bad)
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(ValueError, match="empty"):
        read_trace(empty)


def test_chrome_export_slices_and_instants():
    tr = BufferTracer()
    tr.job_queued(0, 0.0, 1)
    tr.job_start(0, 1.0, 1)
    tr.job_finish(0, 4.0, 1)
    tr.job_start(0, 2.0, 2, bf=1)     # still running at trace end
    tr.span("policy:FCFS", 0.5)
    chrome = to_chrome(tr.events, meta={"k": "v"})
    assert chrome["otherData"]["meta"] == {"k": "v"}
    slices = [e for e in chrome["traceEvents"] if e["ph"] == "X"]
    byname = {s["name"]: s for s in slices}
    assert byname["job 1"]["dur"] == pytest.approx(3e6)
    assert byname["job 1"]["args"]["outcome"] == "job.finish"
    assert byname["job 2"]["args"] == {"backfilled": 1,
                                       "outcome": "running"}
    assert byname["policy:FCFS"]["pid"] == -1
    instants = {e["name"] for e in chrome["traceEvents"] if e["ph"] == "i"}
    assert "job.queued" in instants


# ------------------------------------------------------------- metrics
def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.counter("serve_requests_total").inc(3)
    reg.counter("serve_requests_total").inc()
    with pytest.raises(ValueError, match="only go up"):
        reg.counter("serve_requests_total").inc(-1)
    reg.gauge("train_loss").set(0.25)
    reg.gauge("train_loss", labels={"lane": "a"}).set(0.5)
    h = reg.histogram("serve_queue_wait_seconds")
    for v in (0.002, 0.02, 0.2):
        h.observe(v)
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("serve_requests_total")

    snap = reg.snapshot()
    assert snap["serve_requests_total"][""] == 4.0
    assert snap["train_loss"][""] == 0.25
    assert snap["train_loss"]['{lane="a"}'] == 0.5
    hs = snap["serve_queue_wait_seconds"][""]
    assert hs["count"] == 3 and hs["min"] == 0.002 and hs["max"] == 0.2

    text = reg.to_prometheus()
    assert "# TYPE mrsch_serve_requests_total counter" in text
    assert "mrsch_serve_requests_total 4" in text
    assert 'mrsch_train_loss{lane="a"} 0.5' in text
    # Cumulative buckets: every le >= 0.2 saw all three observations.
    assert 'mrsch_serve_queue_wait_seconds_bucket{le="+Inf"} 3' in text
    assert "mrsch_serve_queue_wait_seconds_count 3" in text


def test_jsonl_flusher_appends_snapshots(tmp_path):
    reg = MetricsRegistry()
    reg.counter("train_episodes_total").inc()
    fl = JsonlFlusher(reg, tmp_path / "metrics.jsonl", interval_s=3600)
    fl.flush()
    reg.counter("train_episodes_total").inc()
    with fl:                         # start/stop does a final flush
        pass
    lines = [json.loads(ln) for ln in
             (tmp_path / "metrics.jsonl").read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["metrics"]["train_episodes_total"][""] == 1.0
    assert lines[1]["metrics"]["train_episodes_total"][""] == 2.0
    assert all("ts" in ln for ln in lines)


# ----------------------------------------------------- train registry
@pytest.mark.slow
def test_vectorized_trainer_fills_registry():
    res = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]
    agent = MRSchAgent(res, AgentConfig(
        state_hidden=(32, 16), state_out=8, module_hidden=4,
        stream_hidden=16, batch_size=16, grad_steps_per_episode=4,
        eps_decay=0.9, seed=0))
    slots = [EnvSlot(jobsets=[("a", synth_jobs(1, n=40))],
                     resources=res, tag="lane-a")]
    reg = MetricsRegistry()
    train_agent_vectorized(agent, slots, TrainConfig(n_envs=1),
                           registry=reg)
    snap = reg.snapshot()
    assert snap["train_episodes_total"]['{lane="lane-a"}'] == 1.0
    assert snap["train_decisions_total"]['{lane="lane-a"}'] >= 40
    assert np.isfinite(snap["train_loss"][""])
    assert snap["train_grad_norm"][""] > 0.0
    assert 0.0 < snap["train_epsilon"][""] <= 1.0
    assert snap["train_decisions_per_sec"][""] > 0.0
    assert snap["train_episode_loss"][""]["count"] == 1


# --------------------------------------------------------- trace_report
def test_trace_report_roundtrips_matrix_trace(tmp_path):
    """End-to-end: run_matrix with a recording tracer -> write -> read
    -> build_report attributes decisions back to each policy."""
    cfg = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=110)
    res = cfg.resources()
    tracer = BufferTracer()
    matrix = run_matrix({"FCFS": FCFSPolicy}, res, cfg,
                        MatrixConfig(scenarios=("S2",), seeds=(1,),
                                     vector=4),
                        tracer=tracer)
    assert matrix["summary"]["n_cells"] == 1
    assert tracer.meta["envs"]["0"] == {"policy": "FCFS",
                                        "scenario": "S2", "seed": 1}
    path = write_trace(tracer.events, tmp_path / "matrix_trace.jsonl",
                       meta=tracer.meta)

    meta, events = read_trace(path)
    report = trace_report.build_report(meta, events)
    assert report["schema"] == "mrsch.trace/v1"
    assert report["n_events"] == len(events) > 0
    assert report["counts"]["sched.decision"] > 0
    assert "policy:FCFS" in report["spans"]
    pol = report["policies"]["FCFS"]
    decisions = sum(1 for e in events if e["ev"] == "sched.decision")
    assert pol["decisions"] == decisions
    assert pol["ms_per_decision"] >= 0.0

    chrome_path = tmp_path / "trace_chrome.json"
    assert trace_report.main([str(path), "--chrome",
                              str(chrome_path)]) == 0
    chrome = json.loads(chrome_path.read_text())
    assert chrome["traceEvents"]
    assert trace_report.main([str(tmp_path / "missing.jsonl")]) == 2
