"""DFP network, goal vector (Eq. 1), replay targets, agent learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (AgentConfig, DFPConfig, MRSchAgent, ReplayBuffer,
                        action_values, goal_vector, init_params, loss_fn,
                        predict)
from repro.core.replay import Episode
from repro.sim import Cluster, Job, ResourceSpec
from repro.sim.simulator import SchedContext


def small_cfg(state_module="mlp"):
    return DFPConfig(state_dim=64, n_measurements=2, n_actions=5,
                     offsets=(1, 2, 4), temporal_weights=(0.0, 0.5, 1.0),
                     state_hidden=(32, 16), state_out=16, module_hidden=8,
                     stream_hidden=16, state_module=state_module)


@pytest.mark.parametrize("module", ["mlp", "cnn"])
def test_predict_shapes(module):
    cfg = small_cfg(module)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B = 3
    p = predict(params, cfg, jnp.ones((B, 64)), jnp.ones((B, 2)),
                jnp.ones((B, 2)))
    assert p.shape == (B, cfg.n_actions, 3, 2)
    u = action_values(params, cfg, jnp.ones((B, 64)), jnp.ones((B, 2)),
                      jnp.ones((B, 2)))
    assert u.shape == (B, cfg.n_actions)


def test_dueling_normalization():
    """Action-stream is zero-mean over actions: mean_a p(a) equals the
    expectation stream, a property of the dueling decomposition."""
    cfg = small_cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    s, m, g = (jax.random.normal(jax.random.PRNGKey(i), sh) for i, sh in
               enumerate([(2, 64), (2, 2), (2, 2)]))
    p = predict(params, cfg, s, m, g)               # (B, A, T, M)
    # Mean over actions must be action-independent (= expectation stream):
    mean_a = p.mean(axis=1)
    # Recompute with permuted action outputs should keep the same mean.
    assert np.all(np.isfinite(np.asarray(p)))
    centered = p - mean_a[:, None]
    assert np.allclose(np.asarray(centered.mean(axis=1)), 0.0, atol=1e-5)


def _ctx(cluster, window, now=0.0, queue=None):
    return SchedContext(now=now, cluster=cluster, window=window,
                        queue_len=len(window),
                        running=[rj.job for rj in cluster.running_jobs()],
                        queue=queue if queue is not None else list(window))


def test_goal_vector_eq1():
    """Eq. (1): weights proportional to sum_i P_ij * t_i, normalized."""
    c = Cluster([ResourceSpec("node", 10), ResourceSpec("bb", 10)])
    j1 = Job(0, 0, 100, 100, {"node": 5, "bb": 0})   # 0.5 * 100 node-time
    j2 = Job(1, 0, 200, 200, {"node": 0, "bb": 5})   # 0.5 * 200 bb-time
    g = goal_vector(_ctx(c, [j1, j2]), ("node", "bb"), (10, 10))
    assert g.sum() == pytest.approx(1.0, abs=1e-6)
    assert g[1] == pytest.approx(2.0 / 3.0, abs=1e-5)   # bb twice as hot


def test_goal_vector_includes_running_remaining_time():
    c = Cluster([ResourceSpec("node", 10), ResourceSpec("bb", 10)])
    r = Job(7, 0, 100, 100, {"node": 10, "bb": 0})
    c.allocate(r, 0.0)
    # at now=50 the running job has 50s of node demand left
    q = Job(8, 0, 50, 50, {"node": 0, "bb": 10})
    g = goal_vector(_ctx(c, [q], now=50.0), ("node", "bb"), (10, 10))
    assert g[0] == pytest.approx(0.5, abs=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 10), st.integers(0, 10),
                          st.floats(1, 1000)), min_size=1, max_size=10))
def test_goal_vector_simplex(jobs_spec):
    c = Cluster([ResourceSpec("node", 10), ResourceSpec("bb", 10)])
    window = [Job(i, 0, t, t, {"node": n, "bb": b})
              for i, (n, b, t) in enumerate(jobs_spec)]
    g = goal_vector(_ctx(c, window), ("node", "bb"), (10, 10))
    assert g.shape == (2,)
    assert g.min() >= 0
    assert g.sum() == pytest.approx(1.0, abs=1e-5)


def test_replay_future_targets():
    buf = ReplayBuffer(offsets=(1, 2), capacity_rows=100)
    meas = np.array([[0.0, 0.0], [1.0, 0.5], [2.0, 1.0]], np.float32)
    ep = Episode(states=np.zeros((3, 4), np.float32), meas=meas,
                 goals=np.ones((3, 2), np.float32),
                 actions=np.zeros(3, np.int32))
    buf.add(ep)
    rng = np.random.default_rng(0)
    batch = buf.sample(rng, 64)
    # for row t=0: target at offset 1 = m1-m0 = [1, .5]; offset 2 = [2, 1]
    sel = batch["state"].sum(1) == 0      # all rows, find t via meas
    t0 = np.where((batch["meas"] == [0, 0]).all(1))[0]
    assert len(t0) > 0
    np.testing.assert_allclose(batch["target"][t0[0], 0], [1.0, 0.5])
    np.testing.assert_allclose(batch["target"][t0[0], 1], [2.0, 1.0])
    np.testing.assert_allclose(batch["target_mask"][t0[0]], [1.0, 1.0])
    t2 = np.where((batch["meas"] == [2, 1]).all(1))[0]
    np.testing.assert_allclose(batch["target_mask"][t2[0]], [0.0, 0.0])


def test_loss_fits_synthetic_targets():
    """A few Adam steps must reduce the DFP loss on a fixed batch."""
    from repro.nn.optim import adam_init, adam_update
    cfg = small_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(42)
    batch = {
        "state": jax.random.normal(rng, (32, 64)),
        "meas": jax.random.uniform(rng, (32, 2)),
        "goal": jax.random.uniform(rng, (32, 2)),
        "action": jax.random.randint(rng, (32,), 0, 5),
        "target": jax.random.normal(rng, (32, 3, 2)) * 0.1,
        "target_mask": jnp.ones((32, 3)),
    }
    opt = adam_init(params)
    l0 = float(loss_fn(params, cfg, batch))
    p = params
    for _ in range(60):
        loss, grads = jax.value_and_grad(loss_fn)(p, cfg, batch)
        p, opt = adam_update(grads, opt, p, lr=3e-4)
    l1 = float(loss_fn(p, cfg, batch))
    assert l1 < l0 * 0.7, (l0, l1)


def test_agent_paper_state_dim():
    """Full Theta-scale encoding reproduces the paper's 11410-dim state."""
    res = [ResourceSpec("node", 4392), ResourceSpec("bb", 1293)]
    agent = MRSchAgent(res, AgentConfig(state_hidden=(16,), state_out=8,
                                        module_hidden=4))
    assert agent.enc.state_dim == 11410


def test_backend_parity_forward():
    """xla and pallas backends compute the same DFP outputs from the
    same params (parity bound: f32 accumulation reorder only)."""
    import dataclasses
    cfg = small_cfg()
    cfgp = dataclasses.replace(cfg, backend="pallas")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B = 4
    s = jax.random.normal(jax.random.PRNGKey(1), (B, 64))
    m = jax.random.uniform(jax.random.PRNGKey(2), (B, 2))
    g = jax.random.uniform(jax.random.PRNGKey(3), (B, 2))
    np.testing.assert_allclose(
        np.asarray(predict(params, cfg, s, m, g)),
        np.asarray(predict(params, cfgp, s, m, g)), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(action_values(params, cfg, s, m, g)),
        np.asarray(action_values(params, cfgp, s, m, g)),
        rtol=1e-4, atol=1e-5)


def test_backend_parity_gradients():
    """Training-path parity: loss and its full parameter gradient pytree
    match across backends, so the custom-VJP fused backward is a drop-in
    for XLA autodiff."""
    import dataclasses
    cfg = small_cfg()
    cfgp = dataclasses.replace(cfg, backend="pallas")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(42)
    B = 8
    batch = {
        "state": jax.random.normal(rng, (B, 64)),
        "meas": jax.random.uniform(rng, (B, 2)),
        "goal": jax.random.uniform(rng, (B, 2)),
        "action": jax.random.randint(rng, (B,), 0, 5),
        "target": jax.random.normal(rng, (B, 3, 2)) * 0.1,
        "target_mask": jnp.ones((B, 3)),
    }
    lx, gx = jax.value_and_grad(loss_fn)(params, cfg, batch)
    lp, gp = jax.value_and_grad(loss_fn)(params, cfgp, batch)
    assert float(lx) == pytest.approx(float(lp), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(gx),
                    jax.tree_util.tree_leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)


def test_backend_validation():
    with pytest.raises(ValueError, match="unknown nn backend"):
        DFPConfig(state_dim=8, n_measurements=2, n_actions=3,
                  backend="tensorflow")
    agent = MRSchAgent([ResourceSpec("node", 8), ResourceSpec("bb", 4)],
                       AgentConfig(state_hidden=(8,), state_out=4,
                                   module_hidden=2, stream_hidden=4))
    with pytest.raises(ValueError, match="unknown nn backend"):
        agent.set_backend("nope")
    assert agent.dfp.backend == "xla"
    agent.set_backend("pallas")
    assert agent.dfp.backend == "pallas"
    assert agent.config.backend == "pallas"


def test_agent_select_masks_window(rng):
    res = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]
    agent = MRSchAgent(res, AgentConfig(state_hidden=(16,), state_out=8,
                                        module_hidden=4))
    c = Cluster(res)
    window = [Job(0, 0, 10, 10, {"node": 1})]
    a = agent.select(_ctx(c, window))
    assert a == 0                         # only one valid slot
