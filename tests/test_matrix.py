"""Evaluation-matrix schema stability + the bench regression gate."""
import importlib.util
import json
import sys
from pathlib import Path

import pytest

from repro.eval import (MATRIX_SCHEMA, MatrixConfig, default_policies,
                        matrix_columns, matrix_csv, run_matrix, save_matrix)
from repro.workloads import ThetaConfig

REPO = Path(__file__).resolve().parent.parent


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_bench = _load("check_bench", "tools/check_bench.py")


@pytest.fixture(scope="module")
def mini():
    cfg = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=110)
    return cfg, cfg.resources()


@pytest.fixture(scope="module")
def matrix(mini):
    cfg, res = mini
    pols = default_policies(res)        # FCFS + GA + ScalarRL (>=3 policies)
    return run_matrix(pols, res, cfg, MatrixConfig(
        scenarios=("S2", "drift-bb-surge"), seeds=(1,), vector=4))


# ------------------------------------------------------------------ schema
def test_matrix_schema_and_grid_shape(matrix, mini):
    _, res = mini
    assert matrix["schema"] == MATRIX_SCHEMA
    assert matrix["columns"] == matrix_columns(res)
    assert matrix["summary"]["n_cells"] == 2 * 3     # scenarios x policies
    for row in matrix["rows"]:
        assert list(row) == matrix["columns"]        # stable key order too


def test_matrix_rows_flag_drift_and_family(matrix):
    by_scenario = {}
    for r in matrix["rows"]:
        by_scenario.setdefault(r["scenario"], set()).add(r["drift"])
    assert by_scenario == {"S2": {False}, "drift-bb-surge": {True}}
    assert all(r["family"] in ("paper", "drift") for r in matrix["rows"])


def test_matrix_is_deterministic(matrix, mini):
    cfg, res = mini
    again = run_matrix(default_policies(res), res, cfg, MatrixConfig(
        scenarios=("S2", "drift-bb-surge"), seeds=(1,), vector=4))
    assert again["rows"] == matrix["rows"]
    assert again["summary"]["wins"] == matrix["summary"]["wins"]


def test_vector_width_does_not_change_results(matrix, mini):
    """Lockstep chunking is a throughput knob, never a semantics knob."""
    cfg, res = mini
    seq = run_matrix(default_policies(res), res, cfg, MatrixConfig(
        scenarios=("S2", "drift-bb-surge"), seeds=(1,), vector=1))
    assert seq["rows"] == matrix["rows"]


def test_matrix_csv_round_trips_columns(matrix):
    lines = matrix_csv(matrix).strip().splitlines()
    assert lines[0] == ",".join(matrix["columns"])
    assert len(lines) == 1 + len(matrix["rows"])
    first = dict(zip(matrix["columns"], lines[1].split(",")))
    assert first["policy"] == matrix["rows"][0]["policy"]


def test_save_matrix_writes_json_and_csv(matrix, tmp_path):
    jp, cp = save_matrix(matrix, str(tmp_path / "m.json"))
    assert json.load(open(jp))["schema"] == MATRIX_SCHEMA
    assert open(cp).readline().startswith("policy,scenario")


def test_power_scenarios_need_power_resource(mini):
    cfg, res = mini
    with pytest.raises(ValueError, match="power"):
        run_matrix(default_policies(res), res, cfg,
                   MatrixConfig(scenarios=("S7",), seeds=(1,)))


def test_wins_only_name_known_policies(matrix):
    assert set(matrix["summary"]["wins"]) <= {"FCFS", "GA", "ScalarRL"}
    assert sum(matrix["summary"]["wins"].values()) == 2   # one per cell


# ------------------------------------------------------------- check_bench
def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(json.dumps(obj))
    return str(p)


BASE = {"equivalent": True, "decisions_per_sec": 100.0, "avg_wait": 50.0,
        "rows": [{"util_node": 0.8}]}


def test_check_bench_passes_within_tolerance(tmp_path):
    res = _write(tmp_path, "r.json", {**BASE, "avg_wait": 55.0, "extra": 1})
    base = _write(tmp_path, "b.json", BASE)
    assert check_bench.main([res, base, "--rtol", "0.25"]) == 0


def test_check_bench_fails_on_injected_regression(tmp_path):
    """Acceptance criterion: an injected regression must fail the gate."""
    res = _write(tmp_path, "r.json", {**BASE, "avg_wait": 90.0})
    base = _write(tmp_path, "b.json", BASE)
    assert check_bench.main([res, base, "--rtol", "0.25"]) == 1


def test_check_bench_direction_awareness():
    # higher-is-better: a drop fails, a rise passes
    assert check_bench.compare({"decisions_per_sec": 10.0},
                               {"decisions_per_sec": 100.0}, rtol=0.25)
    assert not check_bench.compare({"decisions_per_sec": 500.0},
                                   {"decisions_per_sec": 100.0}, rtol=0.25)
    # lower-is-better: a rise fails, a drop passes
    assert check_bench.compare({"avg_wait": 90.0}, {"avg_wait": 50.0},
                               rtol=0.25)
    assert not check_bench.compare({"avg_wait": 10.0}, {"avg_wait": 50.0},
                                   rtol=0.25)
    # plain keys: two-sided
    assert check_bench.compare({"n_jobs": 10}, {"n_jobs": 100}, rtol=0.25)
    assert check_bench.compare({"n_jobs": 200}, {"n_jobs": 100}, rtol=0.25)


def test_check_bench_structural_contract():
    errs = check_bench.compare({"a": 1}, {"a": 1, "missing": 2}, rtol=0.1)
    assert any("missing" in e for e in errs)
    errs = check_bench.compare({"equivalent": False}, {"equivalent": True},
                               rtol=0.1)
    assert errs
    errs = check_bench.compare({"rows": []}, BASE, rtol=0.1)
    assert any("rows" in e for e in errs)
    # nested rows compare element-wise; extra result rows are fine
    assert not check_bench.compare(
        {**BASE, "rows": [{"util_node": 0.8}, {"util_node": 0.1}]},
        BASE, rtol=0.1)


def test_check_bench_unreadable_input_exits_2(tmp_path):
    ok = _write(tmp_path, "ok.json", BASE)
    assert check_bench.main([str(tmp_path / "nope.json"), ok]) == 2


def test_committed_baselines_gate_current_smoke_outputs():
    """The committed baselines must stay loadable and self-consistent."""
    for name in ("scheduling_sweep", "matrix"):
        base = json.load(open(REPO / "benchmarks" / "baselines"
                              / f"{name}.json"))
        assert not check_bench.compare(base, base, rtol=0.0)


# -------------------------------------------------------------- run.py exit
def test_bench_harness_exit_codes(capsys):
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.run import run_benches
    finally:
        sys.path.pop(0)

    def boom():
        raise RuntimeError("injected")

    failures = run_benches({"ok": lambda: {}, "boom": boom})
    out = capsys.readouterr().out
    assert failures == 1
    assert "ERROR:RuntimeError: injected" in out
    # a bench whose derived-summary contract breaks also fails the run
    failures = run_benches({"eval_matrix": lambda: {"no": "summary"}})
    assert failures == 1
    assert "ERROR:derived" in capsys.readouterr().out
