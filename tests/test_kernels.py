"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention, mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_mlp.ops import fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_layer_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- fused_mlp
@pytest.mark.parametrize("m,k,n", [(1, 64, 32), (37, 300, 129),
                                   (128, 512, 256), (200, 1000, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["leaky_relu", "relu", "tanh", "linear"])
def test_fused_mlp_matches_ref(m, k, n, dtype, act):
    key = jax.random.PRNGKey(m * 7 + n)
    x = jax.random.normal(key, (m, k), dtype)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
         * 0.05).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,), dtype)
    out = fused_mlp(x, w, b, activation=act)
    ref = fused_mlp_layer_ref(x, w, b, activation=act)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_fused_mlp_dfp_sizes():
    """The paper's exact state-module sizes (11410 -> 4000) in bf16."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 11410), jnp.bfloat16)
    w = (jax.random.normal(key, (11410, 4000), jnp.float32) * 0.01
         ).astype(jnp.bfloat16)
    b = jnp.zeros((4000,), jnp.bfloat16)
    out = fused_mlp(x, w, b)
    ref = fused_mlp_layer_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=5e-2,
                               atol=5e-2)


# ------------------------------------------------- fused_mlp custom VJP
def _grads(f, x, w, b, n):
    """d/d(x,w,b) of a fixed scalar projection of f's output."""
    ct = jnp.sin(jnp.arange(n) * 0.37)
    return jax.grad(lambda x, w, b: (f(x, w, b) * ct).sum(), (0, 1, 2))(
        x, w, b)


# Real DFP layer shapes: paper-scale state module rows (4000->1000->512)
# and the packed decision batches the rollout engine actually emits —
# including odd lane counts whose M is no multiple of any block.
DFP_GRAD_SHAPES = [(1, 1000, 512), (3, 512, 128), (5, 4000, 1000),
                   (37, 300, 129)]


@pytest.mark.parametrize("m,k,n", DFP_GRAD_SHAPES)
@pytest.mark.parametrize("act", ["leaky_relu", "relu", "tanh", "linear"])
def test_fused_mlp_grad_matches_ref(m, k, n, act):
    key = jax.random.PRNGKey(m * 31 + n)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.05
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,)) * 0.1
    gk = _grads(lambda x, w, b: fused_mlp(x, w, b, activation=act),
                x, w, b, n)
    gr = _grads(lambda x, w, b: fused_mlp_layer_ref(x, w, b, activation=act),
                x, w, b, n)
    for got, ref, name in zip(gk, gr, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-3, atol=1e-4, err_msg=name)


def test_fused_mlp_grad_bf16():
    """bf16 fwd+grad stays within bf16 resolution of the f32 oracle."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (5, 256), jnp.bfloat16)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (256, 128),
                           jnp.float32) * 0.05).astype(jnp.bfloat16)
    b = jnp.zeros((128,), jnp.bfloat16)
    gk = _grads(lambda x, w, b: fused_mlp(x, w, b), x, w, b, 128)
    xf, wf, bf = (t.astype(jnp.float32) for t in (x, w, b))
    gr = _grads(lambda x, w, b: fused_mlp_layer_ref(x, w, b), xf, wf, bf, 128)
    for got, ref in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=5e-2, atol=5e-2)


def test_fused_mlp_state_module_chain_grad():
    """Grad parity through the whole fused state-module MLP chain."""
    from repro.kernels.fused_mlp.ops import dfp_state_module
    key = jax.random.PRNGKey(11)
    sizes = [(300, 128), (128, 64)]
    layers = [{"w": jax.random.normal(jax.random.fold_in(key, 2 * i),
                                      (k, n)) * 0.05,
               "b": jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                                      (n,)) * 0.1}
              for i, (k, n) in enumerate(sizes)]
    x = jax.random.normal(key, (7, 300))

    def ref_chain(x, layers):
        h = x
        for l in layers:
            h = fused_mlp_layer_ref(h, l["w"], l["b"])
        return h

    gk = jax.grad(lambda x, ls: dfp_state_module(x, ls).sum(), (0, 1))(
        x, layers)
    gr = jax.grad(lambda x, ls: ref_chain(x, ls).sum(), (0, 1))(x, layers)
    for got, ref in zip(jax.tree_util.tree_leaves(gk),
                        jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-3, atol=1e-4)


# ------------------------------------------------------------- flash attn
@pytest.mark.parametrize("B,S,H,KV,dh", [
    (1, 128, 2, 2, 64), (2, 200, 4, 2, 64), (1, 384, 8, 1, 128),
    (2, 256, 6, 6, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, KV, dh, dtype, causal):
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, S, H, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, dh), dtype)
    out = flash_attention(q, k, v, causal=causal)
    kr = jnp.repeat(k, H // KV, 2)
    vr = jnp.repeat(v, H // KV, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    ref = attention_ref(qf, kf, vf, causal=causal).reshape(B, H, S, dh)
    ref = ref.transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_flash_attention_cross_lengths():
    """Sq != Sk (non-causal cross attention path)."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 100, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 260, 4, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 260, 4, 64))
    out = flash_attention(q, k, v, causal=False)
    qf = q.transpose(0, 2, 1, 3).reshape(8, 100, 64)
    kf = k.transpose(0, 2, 1, 3).reshape(8, 260, 64)
    vf = v.transpose(0, 2, 1, 3).reshape(8, 260, 64)
    ref = attention_ref(qf, kf, vf, causal=False).reshape(2, 4, 100, 64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------- masked mha (+ vjp)
# The queue-as-tokens encoder's kernel: non-causal attention over
# variable-length token sets, with a fused Pallas backward.  Shapes are
# the encoder's real ones — S = 1 + queue_cap, which is deliberately odd
# and no multiple of any block size — and the length grids always include
# 0 (an env with an empty queue: a fully-masked tail must output and
# backprop exactly zero, not NaN).

def _mha_case(S, dh, seed=0, BH=8):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (BH, S, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, dh))
    lens = jnp.asarray([0, 1, 3, S // 2, max(S - 1, 1), S, 2, S // 3][:BH],
                       jnp.float32)
    return q, k, v, lens


# S = 1 + Q for queue caps 48 / 128 / 64 (none block-aligned); block 128
# exercises the single-block fast path, 32/64 the multi-block online
# softmax across partially- and fully-masked key blocks.
MHA_SHAPES = [(49, 16, 32), (129, 32, 64), (65, 8, 128)]


@pytest.mark.parametrize("S,dh,block", MHA_SHAPES)
def test_mha_fwd_matches_ref(S, dh, block):
    q, k, v, lens = _mha_case(S, dh, seed=S)
    out = mha(q, k, v, lens, block_q=block, block_k=block, interpret=True)
    ref = attention_ref(q, k, v, causal=False, lengths=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("S,dh,block", MHA_SHAPES)
def test_mha_vjp_matches_ref(S, dh, block):
    q, k, v, lens = _mha_case(S, dh, seed=S + 1)
    ct = jnp.sin(jnp.arange(S * dh) * 0.13).reshape(1, S, dh)

    def proj(f):
        return jax.grad(lambda q, k, v: (f(q, k, v) * ct).sum(), (0, 1, 2))(
            q, k, v)

    gk = proj(lambda q, k, v: mha(q, k, v, lens, block_q=block,
                                  block_k=block, interpret=True))
    gr = proj(lambda q, k, v: attention_ref(q, k, v, causal=False,
                                            lengths=lens))
    for got, ref, name in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-3, atol=1e-4, err_msg=name)


def test_mha_fully_masked_is_exactly_zero():
    """length 0 everywhere: outputs AND all gradients are exactly 0."""
    q, k, v, _ = _mha_case(33, 8, seed=5)
    lens = jnp.zeros((8,), jnp.float32)
    out = mha(q, k, v, lens, block_q=32, block_k=32, interpret=True)
    assert np.all(np.asarray(out) == 0.0)
    grads = jax.grad(lambda q, k, v: mha(q, k, v, lens, block_q=32,
                                         block_k=32, interpret=True).sum(),
                     (0, 1, 2))(q, k, v)
    for g, name in zip(grads, ("dq", "dk", "dv")):
        arr = np.asarray(g)
        assert np.isfinite(arr).all(), f"{name} has non-finite entries"
        np.testing.assert_array_equal(arr, 0.0, err_msg=name)


def test_mha_no_lengths_is_dense_attention():
    q, k, v, _ = _mha_case(40, 16, seed=9)
    out = mha(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------------- ssd
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 100, 3, 16, 8, 32), (1, 256, 4, 32, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_matches_sequential_ref(B, S, H, P, N, chunk, dtype):
    key = jax.random.PRNGKey(S)
    x = jax.random.normal(key, (B, S, H, P), dtype)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    dA = -dt * jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = (jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, N)) * 0.3
          ).astype(dtype)
    Cm = (jax.random.normal(jax.random.fold_in(key, 4), (B, S, H, N)) * 0.3
          ).astype(dtype)
    y = ssd(x, dt, dA, Bm, Cm, chunk=chunk)

    def flat(t, d):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, d)

    yr = ssd_ref(flat(x, P), dt.transpose(0, 2, 1).reshape(B * H, S, 1),
                 dA.transpose(0, 2, 1).reshape(B * H, S, 1),
                 flat(Bm, N), flat(Cm, N))
    yr = yr.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               **(dict(rtol=5e-2, atol=5e-2)
                                  if dtype == jnp.bfloat16 else
                                  dict(rtol=1e-3, atol=1e-3)))


def test_model_chunked_ssd_matches_sequential_ref():
    """The vectorized chunked SSD inside the model (associative scan) must
    also match the exact recurrence."""
    from repro.models.mamba2 import _ssd_chunked
    key = jax.random.PRNGKey(9)
    B, S, H, P, N, chunk = 2, 128, 4, 16, 8, 32
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    dA = -dt * 0.4
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N)) * 0.3
    y = _ssd_chunked(x, dt, dA, Bm, Cm, chunk)

    def flat(t, d):
        return jnp.repeat(t, H, axis=2).transpose(0, 2, 1, 3).reshape(
            B * H, S, d) if t.shape[2] == 1 else \
            t.transpose(0, 2, 1, 3).reshape(B * H, S, d)

    yr = ssd_ref(x.transpose(0, 2, 1, 3).reshape(B * H, S, P),
                 dt.transpose(0, 2, 1).reshape(B * H, S, 1),
                 dA.transpose(0, 2, 1).reshape(B * H, S, 1),
                 flat(Bm, N), flat(Cm, N))
    yr = yr.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-3,
                               atol=1e-3)
