"""Architecture zoo: per-arch smoke tests + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.models import decode_step, forward, init_cache, init_params, loss

# Full-zoo forward/decode system sweeps — slow CI lane (`pytest -m slow`).
pytestmark = pytest.mark.slow


def _batch(cfg, B, S, key):
    if cfg.input_mode == "embeddings":
        b = {"embeddings": jax.random.normal(key, (B, S, cfg.d_model),
                                             jnp.float32)}
    else:
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    b["labels"] = jax.random.randint(key, shape, 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_loss_decode(arch):
    """Reduced same-family config: one forward/loss/decode step on CPU with
    shape and finiteness assertions (assignment requirement)."""
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    B, S = 2, 64
    batch = _batch(cfg, B, S, key)
    logits = forward(params, cfg, batch)
    want = (B, S, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks > 1 \
        else (B, S, cfg.vocab_size)
    assert logits.shape == want
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    l = loss(params, cfg, batch)
    assert np.isfinite(float(l))

    cache = init_cache(cfg, B, 128, jnp.float32)
    if cfg.input_mode == "embeddings":
        db = {"embeddings": jnp.ones((B, 1, cfg.d_model), jnp.float32)}
    elif cfg.n_codebooks > 1:
        db = {"tokens": jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32)}
    else:
        db = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    lg, cache2 = decode_step(params, cfg, db, cache, 0)
    assert lg.shape[:2] == (B, 1)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mamba2-1.3b",
                                  "deepseek-v2-lite-16b", "zamba2-7b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full-sequence forward
    logits (KV cache, MLA absorbed decode, SSM state recurrence).  MoE
    capacity is raised to dropless here: capacity dropping is
    batch-dependent by design, so it would differ between the two paths."""
    from dataclasses import replace
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg, jnp.float32)
    B, S = 1, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    ref = forward(params, cfg, {"tokens": tokens})        # (B,S,V)

    cache = init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cfg,
                                {"tokens": tokens[:, t:t + 1]}, cache, t)
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_param_count_sane():
    """Analytic parameter counts are within 15% of actual leaf sums for
    representative archs (drives MODEL_FLOPS)."""
    for arch in ["stablelm-1.6b", "gemma-2b", "mamba2-1.3b"]:
        cfg = get_config(arch)
        expected = {"stablelm-1.6b": 1.6e9, "gemma-2b": 2.5e9,
                    "mamba2-1.3b": 1.3e9}[arch]
        total, active = cfg.param_count()
        assert total == pytest.approx(expected, rel=0.35), (arch, total)
        assert active <= total


def test_full_config_shapes_via_eval_shape():
    """FULL configs instantiate as shapes only (no allocation)."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c, jnp.bfloat16))
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
        total, _ = cfg.param_count()
        assert n == pytest.approx(total, rel=0.1), (arch, n, total)
