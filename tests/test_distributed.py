"""Distribution correctness on multi-device CPU meshes (subprocesses,
because jax fixes the device count per process)."""
import os
import subprocess
import sys
import textwrap

import pytest

# Multi-device subprocess system tests — slow CI lane (`pytest -m slow`).
pytestmark = pytest.mark.slow


def run_sub(code: str, devices: int = 8, timeout: int = 600):
    prelude = (f"import os\n"
               f"os.environ['XLA_FLAGS'] = "
               f"'--xla_force_host_platform_device_count={devices}'\n")
    p = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=dict(os.environ, PYTHONPATH="src"),
                       cwd=os.getcwd())
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


def test_moe_shard_map_matches_local():
    """Both MoE shard_map paths (small-T token-replicated, big-T
    data-local) must reproduce the single-device oracle."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.distributed.compat import make_mesh
        from repro.distributed.sharding import Rules, use_rules
        from repro.models.moe import moe_init, moe_apply
        cfg = smoke_config("deepseek-v2-lite-16b").moe
        mesh = make_mesh((2, 4), ("data", "model"))
        params = moe_init(jax.random.PRNGKey(0), 64, cfg, True, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
        ref = moe_apply(params, x, cfg, "silu", True)
        rules = Rules(mapping=dict(batch=("data",), fsdp=("data",),
                                   experts=("model",), mlp=("model",),
                                   heads=("model",), kv_heads=("model",),
                                   vocab=("model",), act_seq=None,
                                   kv_seq=None), mesh=mesh)
        with use_rules(rules):
            out = jax.jit(lambda p, xx: moe_apply(p, xx, cfg, "silu", True))(
                params, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("SMALL-T-OK")
    """)
    assert "SMALL-T-OK" in out


def test_sharded_train_step_matches_single_device():
    """One train step on a (2,4) mesh must match the unsharded step."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.configs.shapes import InputShape
        from repro.data.pipeline import make_batch
        from repro.distributed.sharding import default_rules, use_rules, param_shardings
        from repro.launch.steps import _bind_rules, make_train_step
        from repro.models import transformer
        from repro.optim import OptConfig, opt_init

        cfg = smoke_config("stablelm-1.6b")
        shape = InputShape("t", 64, 4, "train")
        opt = OptConfig(lr=1e-3, weight_decay=0.0)
        batch = make_batch(cfg, shape, 0)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg,
                                         jnp.float32)
        opt_state = opt_init(params, opt)
        # single device reference
        step = make_train_step(cfg, opt)
        p1, o1, m1 = jax.jit(step)(params, opt_state, batch)
        # sharded
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = default_rules(mesh)
        with use_rules(rules):
            pshard = param_shardings(params, rules)
            params_s = jax.device_put(params, pshard)
            opt_s = opt_init(params_s, opt)
        step_s = jax.jit(_bind_rules(make_train_step(cfg, opt), rules))
        p2, o2, m2 = step_s(params_s, opt_s, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, (
            float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=3e-3, atol=3e-3)
        print("TRAIN-STEP-OK")
    """)
    assert "TRAIN-STEP-OK" in out


def test_tp_row_matmul_matches_plain():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compat import make_mesh
        from repro.distributed.sharding import (Rules, tp_row_matmul,
                                                use_rules)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = Rules(mapping=dict(batch=("data",), act_seq=("model",),
                                   mlp=("model",), fsdp=("data",)),
                      mesh=mesh)
        h = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 24)) * 0.1
        ref = h @ w
        with use_rules(rules):
            out = jax.jit(lambda a, b: tp_row_matmul(a, b))(h, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("TP-RS-OK")
    """)
    assert "TP-RS-OK" in out


def test_dryrun_single_cell_runs():
    """The dry-run entry point itself (512 fake devices) on the smallest
    cell; proves mesh construction + AOT compile + roofline record."""
    out = run_sub("""
        import json
        from repro.launch.dryrun import run_cell
        rec = run_cell("gemma-2b", "prefill_32k", multi_pod=False,
                       extrapolate=True)
        assert rec["status"] == "ok", rec
        assert rec["roofline"]["compute_s"] > 0
        print("DRYRUN-OK", rec["roofline"]["dominant"])
    """, devices=512, timeout=900)
    assert "DRYRUN-OK" in out
