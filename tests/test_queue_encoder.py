"""Queue-as-tokens attention state module: layout, property tests
(padding invariance, permutation equivariance), backend x module parity,
checkpoint portability, engine agreement, end-to-end training, serving.
"""
import os
import tempfile
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import AgentConfig, MRSchAgent, evaluate, train_agent
from repro.core.dfp import DFPConfig, action_values, init_params, loss_fn
from repro.core.encoding import EncodingConfig, encode_state
from repro.core.train import TrainConfig
from repro.nn.queue_encoder import (QueueEncoderConfig, encode_queue_tokens,
                                    queue_encoder_init, queue_state_features)
from repro.sim import (Job, ResourceSpec, SimConfig, Simulator, run_trace,
                       run_traces, run_traces_device)
from repro.workloads import ThetaConfig, build_jobs

RES = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]


def tiny_agent(module="attention", seed=0, backend="xla", window=4,
               queue_cap=12, **kw):
    return MRSchAgent(RES, AgentConfig(
        window=window, state_module=module, queue_cap=queue_cap,
        state_hidden=(32,), state_out=16, module_hidden=8, stream_hidden=16,
        attn_dim=8, attn_heads=2, attn_layers=1, seed=seed, backend=backend,
        **kw))


def synth_jobs(seed, n=24, span=150.0):
    rng = np.random.default_rng(seed)
    return [Job(jid=i, submit=float(rng.uniform(0, span)),
                runtime=float(rng.integers(30, 400)),
                walltime=float(rng.integers(60, 600)),
                demands={"node": int(rng.integers(1, 10)),
                         "bb": int(rng.integers(0, 5))})
            for i in range(n)]


def enc_cfg(queue_cap=8, window=4):
    return QueueEncoderConfig(queue_cap=queue_cap, job_dim=4, ctx_dim=4,
                              window=window, d_model=8, n_heads=2,
                              n_layers=2, mlp_mult=2, out_dim=16)


def flat_state(tokens, qlen, ctx, queue_cap):
    """Build the attention-layout state vector from its pieces."""
    B, n, jd = tokens.shape
    out = np.zeros((B, queue_cap * jd + 1 + ctx.shape[1]), np.float32)
    out[:, :queue_cap * jd].reshape(B, queue_cap, jd)[:, :n] = tokens
    out[:, queue_cap * jd] = qlen
    out[:, queue_cap * jd + 1:] = ctx
    return out


# ------------------------------------------------------------- layout math
def test_encoding_attention_state_dim_and_validation():
    cfg = EncodingConfig(window=4, resource_names=("node", "bb"),
                         capacities=(16, 8), state_module="attention",
                         queue_cap=12)
    assert cfg.state_dim == 12 * 4 + 1 + 4
    with pytest.raises(ValueError, match="queue_cap"):
        EncodingConfig(window=4, resource_names=("node",), capacities=(8,),
                       state_module="attention", queue_cap=2)
    with pytest.raises(ValueError, match="state_module"):
        EncodingConfig(window=4, resource_names=("node",), capacities=(8,),
                       state_module="transformer")
    with pytest.raises(ValueError, match="state_dim mismatch"):
        DFPConfig(state_dim=99, n_measurements=2, n_actions=4,
                  state_module="attention", attn_queue=12)


def test_encode_state_attention_layout_values():
    """Hand-check tokens / queue_len / context against a live cluster."""
    enc = EncodingConfig(window=2, resource_names=("node", "bb"),
                         capacities=(16, 8), state_module="attention",
                         queue_cap=4)
    jobs = [Job(jid=i, submit=10.0 * i, runtime=100.0, walltime=200.0,
                demands={"node": 4, "bb": 2}) for i in range(6)]
    sim = Simulator(RES, jobs, policy=None, config=SimConfig(window=2))
    ctx = sim.next_decision()
    state = encode_state(enc, ctx)
    jd, Q = enc.job_dim, enc.queue_cap
    assert state[Q * jd] == min(ctx.queue_len, Q)
    # token 0 = first waiting job: [node_frac, bb_frac, wall_norm, queued]
    j0 = ctx.queue[0]
    np.testing.assert_allclose(
        state[:jd], [4 / 16, 2 / 8, 200.0 / enc.time_scale,
                     (ctx.now - j0.submit) / enc.time_scale], rtol=1e-6)
    # idle cluster: free fraction 1, mean time-to-free 0 (per resource)
    np.testing.assert_allclose(state[Q * jd + 1: Q * jd + 5],
                               [1.0, 0.0, 1.0, 0.0], atol=1e-7)


# ------------------------------------------------------ padding invariance
@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_jobs=st.integers(0, 8),
       extra=st.integers(1, 24))
def test_padding_length_invariance(seed, n_jobs, extra):
    """Features must not depend on how much padding the buffer carries:
    the same valid tokens through queue_cap=8 and queue_cap=8+extra give
    the same state features under the SAME parameters (the parameter
    tree is buffer-size-agnostic by construction)."""
    cfg1 = enc_cfg(queue_cap=8)
    cfg2 = replace(cfg1, queue_cap=8 + extra)
    params = queue_encoder_init(jax.random.PRNGKey(seed), cfg1)
    rng = np.random.default_rng(seed)
    tokens = rng.normal(size=(2, n_jobs, 4)).astype(np.float32)
    qlen = np.full(2, float(n_jobs), np.float32)
    ctx = rng.normal(size=(2, 4)).astype(np.float32)
    out1 = queue_state_features(params, cfg1, jnp.asarray(
        flat_state(tokens, qlen, ctx, 8)))
    out2 = queue_state_features(params, cfg2, jnp.asarray(
        flat_state(tokens, qlen, ctx, 8 + extra)))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_permutation_equivariance(seed):
    """No positional embeddings: permuting the (fully valid) job tokens
    permutes the per-token embeddings and leaves the context token
    invariant — slot identity comes only from the pooled window readout."""
    cfg = enc_cfg(queue_cap=6)
    params = queue_encoder_init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 1)
    tokens = rng.normal(size=(1, 6, 4)).astype(np.float32)
    qlen = jnp.asarray([6.0])
    ctx = jnp.asarray(rng.normal(size=(1, 4)).astype(np.float32))
    perm = rng.permutation(6)
    h = encode_queue_tokens(params, cfg, jnp.asarray(tokens), qlen, ctx)
    hp = encode_queue_tokens(params, cfg, jnp.asarray(tokens[:, perm]),
                             qlen, ctx)
    np.testing.assert_allclose(np.asarray(hp[:, 0]), np.asarray(h[:, 0]),
                               rtol=1e-4, atol=1e-5)     # context invariant
    np.testing.assert_allclose(np.asarray(hp[:, 1:]),
                               np.asarray(h[:, 1:][:, perm]),
                               rtol=1e-4, atol=1e-5)     # tokens equivariant


# ---------------------------------------------- backend x module parity
@pytest.mark.parametrize("module", ["mlp", "attention"])
@pytest.mark.parametrize("batch", [1, 5])
def test_backend_parity_outputs_and_grads(module, batch):
    """xla and pallas produce the same action values AND the same
    parameter gradients for both state modules, at N=1 and batched."""
    qcap = 12
    cfg_x = DFPConfig(
        state_dim=(qcap * 4 + 1 + 4) if module == "attention" else 40,
        n_measurements=2, n_actions=4, state_module=module,
        state_hidden=(16,), state_out=8, module_hidden=4, stream_hidden=8,
        attn_queue=qcap, attn_dim=8, attn_heads=2, attn_layers=1,
        backend="xla")
    cfg_p = replace(cfg_x, backend="pallas")
    params = init_params(jax.random.PRNGKey(3), cfg_x)
    rng = np.random.default_rng(batch)
    state = rng.normal(size=(batch, cfg_x.state_dim)).astype(np.float32)
    if module == "attention":
        # Realistic layout: valid queue length + zeroed padding tail.
        qlen = rng.integers(0, qcap + 1, batch)
        toks = state[:, :qcap * 4].reshape(batch, qcap, 4)
        for b, n in enumerate(qlen):
            toks[b, n:] = 0.0
        state[:, qcap * 4] = qlen
    meas = rng.random((batch, 2)).astype(np.float32)
    goal = rng.random((batch, 2)).astype(np.float32)
    goal /= goal.sum(axis=1, keepdims=True)
    u_x = action_values(params, cfg_x, state, meas, goal)
    u_p = action_values(params, cfg_p, state, meas, goal)
    np.testing.assert_allclose(np.asarray(u_x), np.asarray(u_p),
                               rtol=2e-4, atol=2e-4)
    batch_d = {
        "state": jnp.asarray(state), "meas": jnp.asarray(meas),
        "goal": jnp.asarray(goal),
        "action": jnp.zeros(batch, jnp.int32),
        "target": jnp.ones((batch, cfg_x.n_offsets, 2)),
        "target_mask": jnp.ones((batch, cfg_x.n_offsets)),
    }
    g_x = jax.grad(loss_fn)(params, cfg_x, batch_d)
    g_p = jax.grad(loss_fn)(params, cfg_p, batch_d)
    for gx, gp in zip(jax.tree_util.tree_leaves(g_x),
                      jax.tree_util.tree_leaves(g_p)):
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gp),
                                   rtol=5e-3, atol=1e-4)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_cross_module_failure():
    """An attention checkpoint restores exactly; loading it into an MLP
    agent (or vice versa) fails loudly via check_leaves_compat."""
    attn = tiny_agent("attention", seed=1)
    mlp = tiny_agent("mlp", seed=1)
    with tempfile.TemporaryDirectory() as d:
        pa = os.path.join(d, "attn.npz")
        attn.save(pa)
        clone = tiny_agent("attention", seed=2)
        clone.load(pa)
        for a, b in zip(jax.tree_util.tree_leaves(attn.params),
                        jax.tree_util.tree_leaves(clone.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        with pytest.raises(ValueError):
            mlp.load(pa)
        pm = os.path.join(d, "mlp.npz")
        mlp.save(pm)
        with pytest.raises(ValueError):
            attn.load(pm)


def test_train_config_rejects_module_switch():
    agent = tiny_agent("mlp")
    with pytest.raises(ValueError, match="state_module"):
        train_agent(agent, RES, [synth_jobs(0)],
                    config=TrainConfig(n_envs=1, state_module="attention"))


# ------------------------------------------------------------- the engines
@pytest.mark.parametrize("module", ["mlp", "attention"])
def test_three_engines_agree_with_module(module):
    """Sequential, lockstep-vector, and device rollouts produce identical
    schedules and metrics (incl. truncated_jobs) for both state modules."""
    agent = tiny_agent(module, seed=4)
    jobs = synth_jobs(7, n=20)
    r_seq = run_trace(RES, jobs, agent, window=4)
    r_vec = run_traces(RES, [jobs], agent, window=4)[0]
    r_dev = run_traces_device(RES, [jobs], agent,
                              SimConfig.for_engine("device", window=4))[0]
    assert (r_seq.truncated_jobs == r_vec.truncated_jobs
            == r_dev.truncated_jobs)
    rows = [r.metrics.as_row() for r in (r_seq, r_vec, r_dev)]
    for key in rows[0]:
        vals = [row[key] for row in rows]
        np.testing.assert_allclose(vals, vals[0], rtol=2e-5, atol=2e-4,
                                   err_msg=key)


# ----------------------------------------------------------- end to end
@pytest.mark.slow
def test_attention_trains_end_to_end_on_registry_scenario():
    """Loss decreases over a short vectorized run on huge-queue-flood,
    and the trained agent evaluates cleanly on the held-out trace."""
    cfg = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=140.0)
    res = cfg.resources()
    agent = MRSchAgent(res, AgentConfig(
        state_module="attention", queue_cap=32,
        state_hidden=(64,), state_out=32, module_hidden=16,
        stream_hidden=32, attn_dim=16, attn_heads=2, attn_layers=1,
        batch_size=32, grad_steps_per_episode=48, eps_decay=0.6, seed=0))
    sets = [build_jobs("huge-queue-flood", cfg, seed=s) for s in (1, 2, 3)]
    log = train_agent(agent, res, sets, config=TrainConfig(n_envs=2))
    assert len(log.episode_losses) >= 2
    assert log.episode_losses[-1] < log.episode_losses[0]
    r = evaluate(agent, res, build_jobs("huge-queue-flood", cfg, seed=9),
                 window=agent.config.window)
    assert r.decisions > 0 and np.isfinite(r.metrics.avg_wait)
    assert r.truncated_jobs > 0            # the scenario actually floods


@pytest.mark.slow
def test_serving_smoke_with_attention_agent():
    """The decision service accepts the wider attention-layout rows and
    answers exactly like the agent's evaluation-mode select."""
    from repro.serve import DecisionService, ServeConfig
    agent = tiny_agent("attention", seed=6)
    jobs = synth_jobs(11, n=18)
    sim = Simulator(RES, jobs, agent, SimConfig(window=4))
    ctxs = []
    ctx = sim.next_decision()
    for _ in range(6):
        if ctx is None:
            break
        ctxs.append(ctx)
        sim.post_action(agent.select(ctx))
        ctx = sim.next_decision()
    assert ctxs
    with DecisionService(agent, ServeConfig(max_batch=4,
                                            warmup=False)) as svc:
        for c in ctxs:
            assert svc.decide(c) == agent.select(c)
