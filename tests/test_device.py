"""Device-resident rollout engine: round-for-round parity with the
sequential engine (registry scenarios, both NN backends), window-pack
kernel parity, Policy-protocol gating, and ``SimConfig.for_engine``."""
import numpy as np
import pytest

from repro.core import (AgentConfig, FCFSPolicy, GAConfig, GAOptimizer,
                        MRSchAgent, ScalarRLConfig, ScalarRLPolicy,
                        supports_batch, supports_device)
from repro.kernels.window_pack.ops import pack_window
from repro.sim import (DeviceSimulator, Job, ResourceSpec, SimConfig,
                       Simulator, run_traces_device, sim_config)
from repro.workloads import ThetaConfig
from repro.workloads.registry import build_jobs

RES = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]


def synth_jobs(seed: int, n: int = 40):
    rng = np.random.default_rng(seed)
    jobs = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(40.0))
        runtime = float(rng.uniform(20, 300))
        jobs.append(Job(jid=i, submit=t, runtime=runtime,
                        walltime=runtime * float(rng.uniform(1.0, 2.0)),
                        demands={"node": int(rng.integers(1, 12)),
                                 "bb": int(rng.integers(0, 6))}))
    return jobs


def small_agent(resources, seed: int = 0, backend: str = "xla") -> MRSchAgent:
    return MRSchAgent(resources, AgentConfig(
        state_hidden=(32, 16), state_out=8, module_hidden=4, seed=seed,
        backend=backend))


class _Recorder:
    """Wrap a policy so the sequential engine's action trace is kept."""

    def __init__(self, policy):
        self.policy = policy
        self.actions = []

    def select(self, ctx):
        a = int(self.policy.select(ctx))
        self.actions.append(a)
        return a


def seq_run(resources, jobs, policy):
    rec = _Recorder(policy)
    result = Simulator(resources, jobs, rec, SimConfig()).run()
    return result, rec.actions


def env_actions(ro, i):
    return [int(a) for a, d in zip(ro.actions[:, i], ro.decided[:, i]) if d]


def assert_results_close(a, b, rtol=1e-5, atol=1e-2):
    """Host (f64) vs device (f32 clock) results: same schedule, metrics
    equal to float32 precision (time ulp ~2e-3 s at day scale)."""
    assert a.decisions == b.decisions
    assert a.n_unstarted == b.n_unstarted
    ra, rb = a.metrics.as_row(), b.metrics.as_row()
    assert set(ra) == set(rb)
    for k in ra:
        assert np.isclose(ra[k], rb[k], rtol=rtol, atol=atol), \
            (k, ra[k], rb[k])
    for ja, jb in zip(a.jobs, b.jobs):
        assert ja.jid == jb.jid and ja.started == jb.started
        if ja.started:
            assert np.isclose(ja.start, jb.start, rtol=1e-6, atol=1e-2)


# ------------------------------------------------------- N=1 parity (pinned)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_equals_sequential_fcfs(seed):
    """Same actions, decision for decision, and the same schedule."""
    jobs = synth_jobs(seed)
    seq, actions = seq_run(RES, jobs, FCFSPolicy())
    dev = DeviceSimulator(RES, [jobs], FCFSPolicy())
    ro = dev.rollout()
    assert env_actions(ro, 0) == actions
    assert_results_close(seq, ro.results[0])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("scenario", ["S2", "diurnal-heavy"])
def test_device_equals_sequential_agent_registry(scenario, backend):
    """The acceptance pin: N=1 device rollout reproduces the sequential
    engine round for round on registry scenarios, on both NN backends."""
    theta = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=110)
    res = theta.resources()
    jobs = build_jobs(scenario, theta, seed=1)
    agent = small_agent(res, backend=backend)
    seq, actions = seq_run(res, jobs, agent)
    ro = DeviceSimulator(res, [jobs], agent).rollout()
    assert env_actions(ro, 0) == actions
    assert_results_close(seq, ro.results[0])


def test_device_equals_sequential_scalar_rl():
    jobs = synth_jobs(7)
    rl = ScalarRLPolicy(RES, ScalarRLConfig(hidden=(16, 8)))
    seq, actions = seq_run(RES, jobs, rl)
    ro = DeviceSimulator(RES, [jobs], rl).rollout()
    assert env_actions(ro, 0) == actions
    assert_results_close(seq, ro.results[0])


def test_device_multi_env_matches_per_env_sequential():
    """N>1 envs share one program but stay independent trajectories."""
    jobsets = [synth_jobs(seed, n=25) for seed in range(4)]
    ro = DeviceSimulator(RES, jobsets, FCFSPolicy()).rollout()
    for i, jobs in enumerate(jobsets):
        seq, actions = seq_run(RES, jobs, FCFSPolicy())
        assert env_actions(ro, i) == actions
        assert_results_close(seq, ro.results[i])
    st = ro.stats
    assert st.decisions == sum(r.decisions for r in ro.results)
    assert st.policy_calls == st.rounds
    assert 1 < st.max_batch <= 4


def test_device_no_backfill_matches_sequential():
    jobs = synth_jobs(3)
    cfg = SimConfig.for_engine("device", backfill=False)
    seq_nb = Simulator(RES, jobs, FCFSPolicy(),
                       SimConfig(backfill=False)).run()
    ro = DeviceSimulator(RES, [jobs], FCFSPolicy(), cfg).rollout()
    assert_results_close(seq_nb, ro.results[0])


# ------------------------------------------------------------ rollout extras
def test_rollout_collect_yields_transitions():
    jobs = synth_jobs(0, n=15)
    agent = small_agent(RES)
    dev = DeviceSimulator(RES, [jobs], agent)
    ro = dev.rollout(collect=True)
    trans = list(ro.transitions())
    assert len(trans) == ro.stats.decisions
    obs_dim = dev.layout.state_dim + 2 * 2 + dev.layout.window
    for t, i, row, a in trans:
        assert row.shape == (obs_dim,)
        assert 0 <= a < dev.layout.window
        assert bool(ro.decided[t, i])


def test_rollout_epsilon_greedy_still_schedules_everything():
    jobs = synth_jobs(1, n=20)
    ro = DeviceSimulator(RES, [jobs], small_agent(RES)).rollout(eps=1.0,
                                                                seed=3)
    assert ro.results[0].n_unstarted == 0
    assert all(0 <= a < 10 for a in env_actions(ro, 0))


def test_run_traces_device_convenience():
    jobsets = [synth_jobs(s, n=12) for s in range(2)]
    out = run_traces_device(RES, jobsets, FCFSPolicy())
    assert len(out) == 2 and all(r.n_unstarted == 0 for r in out)


# ------------------------------------------------------------ protocol gates
def test_device_rejects_host_only_policy():
    ga = GAOptimizer(GAConfig(population=4, generations=2))
    assert not supports_device(ga)
    assert supports_batch(FCFSPolicy()) and supports_device(FCFSPolicy())
    with pytest.raises(TypeError, match="device stages"):
        DeviceSimulator(RES, [synth_jobs(0, n=5)], ga)


def test_device_rejects_window_mismatch():
    agent = small_agent(RES)                       # enc.window == 10
    with pytest.raises(ValueError, match="window"):
        DeviceSimulator(RES, [synth_jobs(0, n=5)], agent,
                        SimConfig.for_engine("device", window=5))


def test_device_round_budget_error():
    cfg = SimConfig.for_engine("device", max_rounds=2)
    with pytest.raises(RuntimeError, match="round budget"):
        DeviceSimulator(RES, [synth_jobs(0, n=20)], FCFSPolicy(),
                        cfg).rollout()


# ----------------------------------------------------------- window-pack op
def test_window_pack_kernel_matches_reference():
    rng = np.random.default_rng(0)
    waiting = (rng.uniform(size=(3, 50)) < 0.4).astype(np.float32)
    feats = rng.normal(size=(3, 50, 7)).astype(np.float32)
    ref = pack_window(waiting, feats, window=10, use_pallas=False)
    ker = pack_window(waiting, feats, window=10, use_pallas=True,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(ker[0]), np.asarray(ref[0]),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ker[1]), np.asarray(ref[1]))
    np.testing.assert_array_equal(np.asarray(ker[2]), np.asarray(ref[2]))
    # Packing semantics: slot w holds the (w+1)-th waiting job's features.
    wait_idx = np.flatnonzero(waiting[1] > 0.5)
    n = min(len(wait_idx), 10)
    assert list(np.asarray(ref[1])[1, :n]) == list(wait_idx[:n])
    assert np.asarray(ref[2])[1, :n].all()


# ------------------------------------------------------ for_engine construct
def test_for_engine_is_the_single_constructor_path():
    cfg = SimConfig.for_engine("device", window=6, backfill=False,
                               max_rounds=99)
    assert (cfg.engine, cfg.window, cfg.backfill, cfg.max_rounds) \
        == ("device", 6, False, 99)
    assert sim_config(window=6).engine == "sequential"  # deprecation alias
    with pytest.raises(ValueError, match="engine"):
        SimConfig.for_engine("gpu_cluster")
    with pytest.raises(ValueError):
        SimConfig.for_engine("vector", window=0)
    with pytest.raises(ValueError):
        SimConfig.for_engine("device", max_rounds=0)
