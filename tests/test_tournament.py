"""Tournament schema stability, standings math, the partial-failure
contract (a crashing policy fails the bench, never shrinks the grid),
and the check_bench gate-override/summary paths the nightly lane uses."""
import importlib.util
import json
import sys
from pathlib import Path

import pytest

from repro.eval import (TOURNAMENT_SCHEMA, TournamentConfig,
                        leaderboard_columns, render_leaderboard,
                        run_tournament, save_tournament, zoo_policies)
from repro.eval.matrix import matrix_columns
from repro.eval.tournament import _ranks
from repro.workloads import ThetaConfig

REPO = Path(__file__).resolve().parent.parent


def _load(name, rel):
    spec = importlib.util.spec_from_file_location(name, REPO / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_bench = _load("check_bench", "tools/check_bench.py")

SCENARIOS = ("S2", "bursty-campaigns")


@pytest.fixture(scope="module")
def mini():
    cfg = ThetaConfig.mini(seed=0, duration_days=0.4, jobs_per_day=140)
    return cfg, cfg.resources()


@pytest.fixture(scope="module")
def tourney(mini):
    cfg, res = mini
    pols = zoo_policies(res)    # paper methods (no agent) + the zoo = 7
    return run_tournament(pols, res, cfg, TournamentConfig(
        scenarios=SCENARIOS, seeds=(1,), vector=4))


# ------------------------------------------------------------------ schema
def test_tournament_schema_and_pinned_columns(tourney, mini):
    _, res = mini
    assert tourney["schema"] == TOURNAMENT_SCHEMA
    assert tourney["columns"] == matrix_columns(res)   # rows = matrix schema
    # leaderboard column order is part of the schema contract — pinned
    # literally, not recomputed, so accidental reorders fail loudly
    assert tourney["leaderboard_columns"] == [
        "rank", "policy", "overall_score", "wins", "h2h_win_rate",
        "avg_wait", "avg_slowdown", "p95_wait", "util_node", "util_bb",
        "wait_improvement_vs"]
    assert tourney["leaderboard_columns"] == leaderboard_columns(res)
    for entry in tourney["leaderboard"]:
        assert list(entry) == tourney["leaderboard_columns"]


def test_full_zoo_round_robin(tourney):
    assert tourney["summary"]["n_policies"] == 7
    assert tourney["summary"]["n_cells"] == 7 * len(SCENARIOS)
    assert not tourney["summary"]["failures"]
    pols = {e["policy"] for e in tourney["leaderboard"]}
    assert {"FCFS", "GA", "ScalarRL", "PRB-EWT", "CP-Dispatch", "DRAS",
            "CoSchedRL"} == pols


def test_leaderboard_rank_computation(tourney):
    """rank 1..N follows overall_score descending (name tie-break)."""
    lb = tourney["leaderboard"]
    assert [e["rank"] for e in lb] == list(range(1, len(lb) + 1))
    key = [(-e["overall_score"], e["policy"]) for e in lb]
    assert key == sorted(key)
    assert tourney["summary"]["leader"] == lb[0]["policy"]
    # per-metric ranks are permutations of 1..N
    for metric, ranks in tourney["ranks"].items():
        assert sorted(ranks.values()) == list(range(1, len(lb) + 1)), metric


def test_ranks_direction_and_tiebreak():
    agg = {"A": {"avg_wait": 10.0}, "B": {"avg_wait": 5.0},
           "C": {"avg_wait": 10.0}}
    assert _ranks(agg, "avg_wait", lower_is_better=True) \
        == {"B": 1, "A": 2, "C": 3}
    assert _ranks(agg, "avg_wait", lower_is_better=False) \
        == {"A": 1, "C": 2, "B": 3}


def test_head_to_head_is_antisymmetric(tourney):
    h2h = tourney["head_to_head"]
    for p in h2h:
        for q, rate in h2h[p].items():
            assert 0.0 <= rate <= 1.0
            # strict wins: p-beats-q and q-beats-p can't both exceed 1
            assert rate + h2h[q][p] <= 1.0 + 1e-9


def test_tournament_is_deterministic(tourney, mini):
    cfg, res = mini
    again = run_tournament(zoo_policies(res), res, cfg, TournamentConfig(
        scenarios=SCENARIOS, seeds=(1,), vector=4))
    assert again["rows"] == tourney["rows"]
    assert again["leaderboard"] == tourney["leaderboard"]
    assert again["per_policy"] == tourney["per_policy"]
    assert again["head_to_head"] == tourney["head_to_head"]


def test_render_and_save(tourney, tmp_path):
    md = render_leaderboard(tourney)
    assert "# Tournament leaderboard" in md
    assert "Head-to-head win rate" in md
    for e in tourney["leaderboard"]:
        assert f"| {e['rank']} | {e['policy']} |" in md
    jp, mp = save_tournament(tourney, str(tmp_path / "t.json"))
    assert json.load(open(jp))["schema"] == TOURNAMENT_SCHEMA
    assert mp.endswith("leaderboard.md") and open(mp).read() == md


# ---------------------------------------------------------- partial failure
class BoomPolicy:
    """Deliberately-crashing entrant for the partial-failure contract."""
    requires_obs = False

    def select(self, ctx):
        raise RuntimeError("boom")


def test_crashing_policy_marks_cells_failed_not_dropped(mini):
    """Regression: a crashing policy must surface under failures with
    its lost cells while every other policy's rows are kept."""
    cfg, res = mini
    pols = dict(zoo_policies(res))
    pols["Boom"] = BoomPolicy
    t = run_tournament(pols, res, cfg, TournamentConfig(
        scenarios=SCENARIOS, seeds=(1,), vector=4))
    fails = t["summary"]["failures"]
    assert [f["policy"] for f in fails] == ["Boom"]
    assert "RuntimeError: boom" in fails[0]["error"]
    assert t["summary"]["n_failed_cells"] == len(SCENARIOS)
    assert t["summary"]["n_cells"] == 7 * len(SCENARIOS)   # others intact
    assert "Boom" not in {e["policy"] for e in t["leaderboard"]}
    assert "FAILED policies" in render_leaderboard(t)
    # ... and the bench entry points turn that into a non-zero exit
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks import run as bench_run
        from benchmarks.bench_scheduling import _grid_exit
    finally:
        sys.path.pop(0)
    assert _grid_exit(t["summary"]) == 1
    assert _grid_exit({"failures": []}) == 0
    with pytest.raises(RuntimeError, match="Boom"):
        bench_run._raise_on_grid_failures(t["summary"])


# ----------------------------------------------- check_bench gate overrides
def test_check_bench_per_section_gate_overrides():
    base = {"per_policy": {"FCFS": {"avg_wait": 100.0, "util_node": 0.8},
                           "MRSch": {"avg_wait": 50.0}},
            "__gates__": {"FCFS": {"avg_wait": 0.1, "*": 0.05},
                          "MRSch": {"*": 0.5}}}
    res = {"per_policy": {"FCFS": {"avg_wait": 115.0, "util_node": 0.74},
                          "MRSch": {"avg_wait": 70.0}}}
    errs = check_bench.compare(res, base, rtol=0.25,
                               gates=base["__gates__"])
    # FCFS.avg_wait gated at 0.1 (fails), util_node at "*"=0.05 (fails),
    # MRSch.avg_wait at 0.5 (passes despite +40%)
    assert sorted(e.split(":")[0] for e in errs) == [
        "$.per_policy.FCFS.avg_wait", "$.per_policy.FCFS.util_node"]
    assert "rtol=0.1" in [e for e in errs if "avg_wait" in e][0]
    # without gates the global rtol applies and MRSch fails too
    errs = check_bench.compare(res, {k: v for k, v in base.items()
                                     if k != "__gates__"}, rtol=0.25)
    assert any("MRSch" in e for e in errs)


def test_check_bench_collects_all_violations_not_fail_fast():
    base = {"a": {"avg_wait": 1.0}, "b": {"avg_wait": 1.0},
            "vals": [1.0, 2.0, 3.0]}
    res = {"a": {"avg_wait": 9.0}, "b": {"avg_wait": 9.0},
           "vals": [9.0, 2.0]}
    errs = check_bench.compare(res, base, rtol=0.1)
    # both dict regressions + the truncation + the element regression
    assert len(errs) == 4
    assert any("3 entries" in e and "only 2" in e for e in errs)
    assert any("$.vals[0]" in e for e in errs)


def test_check_bench_summary_md_flag(tmp_path):
    base = {"schema": "v1", "per_policy": {"FCFS": {"avg_wait": 10.0}}}
    bp = tmp_path / "b.json"
    bp.write_text(json.dumps(base))
    rp = tmp_path / "r.json"
    rp.write_text(json.dumps({"schema": "v1",
                              "per_policy": {"FCFS": {"avg_wait": 99.0}}}))
    md = tmp_path / "gate.md"
    assert check_bench.main([str(rp), str(bp),
                             "--summary-md", str(md)]) == 1
    text = md.read_text()
    assert "| `$.per_policy.FCFS` | ❌ FAIL |" in text
    assert "| `$.schema` | ✅ pass |" in text and "**FAIL**" in text
    # passing run writes a PASS table
    assert check_bench.main([str(bp), str(bp),
                             "--summary-md", str(md)]) == 0
    assert "**PASS**" in md.read_text()


def test_committed_tournament_baseline_is_self_consistent():
    path = REPO / "benchmarks" / "baselines" / "tournament.json"
    base = json.load(open(path))
    assert base["schema"] == TOURNAMENT_SCHEMA
    assert "__gates__" in base and "per_policy" in base
    assert set(base["__gates__"]) <= set(base["per_policy"])
    assert not check_bench.compare(base, base, rtol=0.0,
                                   gates=base["__gates__"])
