"""Checkpointing: atomic save, restore, GC, async, elastic resharding."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_pytree,
                              save_pytree)


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, str(tmp_path), step=3, extra={"note": "x"})
    out, manifest = restore_pytree(t, str(tmp_path))
    assert manifest["step"] == 3
    assert manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(tree(), s)
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4]                       # GC keeps newest 2


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save_async(tree(), 10)
    m.wait()
    out, manifest = m.restore_latest(tree())
    assert manifest["step"] == 10


def test_async_save_failure_surfaces(tmp_path, monkeypatch):
    """A failed background save must not vanish: wait() (and the next
    save_async, which flushes first) re-raises the worker exception."""
    from repro.checkpoint import store

    m = CheckpointManager(str(tmp_path))

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(store, "save_pytree", boom)
    m.save_async(tree(), 1)
    with pytest.raises(OSError, match="disk full"):
        m.wait()
    monkeypatch.undo()
    # the failure is reported once, then the manager is usable again
    m.wait()
    m.save_async(tree(), 2)
    m.wait()
    assert latest_step(str(tmp_path)) == 2


def test_async_save_failure_surfaces_on_next_save(tmp_path, monkeypatch):
    from repro.checkpoint import store

    m = CheckpointManager(str(tmp_path))
    monkeypatch.setattr(store, "save_pytree",
                        lambda *a, **kw: (_ for _ in ()).throw(ValueError("bad dtype")))
    m.save_async(tree(), 1)
    m._thread.join()
    monkeypatch.undo()
    with pytest.raises(ValueError, match="bad dtype"):
        m.save_async(tree(), 2)


@pytest.mark.parametrize("dtype_name", ["bfloat16", "float8_e4m3fn",
                                        "complex64"])
def test_roundtrip_viewed_dtypes(tmp_path, dtype_name):
    """The byte-view fallback must invert for 2-byte (bf16), 1-byte (fp8)
    and wide (complex64) dtypes, with the manifest recording the logical
    shape."""
    import ml_dtypes

    if dtype_name == "complex64":
        dt = np.complex64
        arr = (np.arange(6, dtype=np.float32).reshape(2, 3)
               + 1j * np.ones((2, 3), np.float32)).astype(dt)
        t = {"x": arr}
    else:
        dt = getattr(ml_dtypes, dtype_name)
        t = {"x": np.linspace(-2, 2, 12, dtype=np.float32)
             .reshape(3, 4).astype(dt)}
    save_pytree(t, str(tmp_path), step=1)
    with open(tmp_path / "step_00000001" / "manifest.json") as f:
        manifest = json.load(f)
    (leaf,) = manifest["leaves"]
    assert leaf["shape"] == list(t["x"].shape)      # logical, not viewed
    assert leaf["dtype"] == dtype_name
    out, _ = restore_pytree(t, str(tmp_path))
    restored = np.asarray(out["x"])
    assert restored.dtype == np.dtype(dt)
    np.testing.assert_array_equal(restored.view(np.uint8),
                                  np.asarray(t["x"]).view(np.uint8))


def test_shape_mismatch_rejected(tmp_path):
    save_pytree(tree(), str(tmp_path), 1)
    bad = tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        restore_pytree(bad, str(tmp_path))


def test_interrupted_save_never_corrupts(tmp_path):
    """A .tmp directory (simulated crash mid-save) is ignored."""
    save_pytree(tree(), str(tmp_path), 1)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    out, manifest = restore_pytree(tree(), str(tmp_path))
    assert manifest["step"] == 1


ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import sys, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import restore_pytree, save_pytree
    from repro.distributed.compat import make_mesh

    mode, path = sys.argv[1], sys.argv[2]
    mesh = make_mesh((%d,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    if mode == "save":
        t = {"w": jax.device_put(t["w"], sh)}
        save_pytree(t, path, 5)
        print("saved")
    else:
        out, m = restore_pytree(t, path, shardings={"w": sh})
        assert m["step"] == 5
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(64.).reshape(8, 8))
        assert len(out["w"].sharding.device_set) == %d
        print("restored-ok")
""")


@pytest.mark.slow
def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Save on an 8-way mesh, restore onto a 4-way mesh (elastic restart).
    Runs in subprocesses because device count is fixed per process."""
    env = dict(os.environ, PYTHONPATH="src")
    p1 = subprocess.run(
        [sys.executable, "-c", ELASTIC % (8, 8, 8), "save", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert "saved" in p1.stdout, p1.stderr[-2000:]
    p2 = subprocess.run(
        [sys.executable, "-c", ELASTIC % (4, 4, 4), "restore", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert "restored-ok" in p2.stdout, p2.stderr[-2000:]


@pytest.mark.slow
def test_train_loop_restart_resumes(tmp_path):
    """Kill-and-restart: a second train_loop picks up from the checkpoint
    and skips completed steps (fault-tolerant restart path)."""
    from repro.configs import smoke_config
    from repro.configs.shapes import InputShape
    from repro.launch.train import train_loop
    cfg = smoke_config("stablelm-1.6b")
    shape = InputShape("t", 32, 2, "train")
    r1 = train_loop(cfg, shape, steps=4, ckpt_dir=str(tmp_path),
                    ckpt_every=2, log_every=10)
    assert r1.restored_from is None
    r2 = train_loop(cfg, shape, steps=8, ckpt_dir=str(tmp_path),
                    ckpt_every=2, log_every=10)
    assert r2.restored_from == 4
    assert r2.steps == 4                        # only the remaining steps


# ------------------------------------------------------------ agent.load
def _tiny_agent(state_hidden=(32, 16)):
    from repro.core import AgentConfig, MRSchAgent
    from repro.sim import ResourceSpec
    res = [ResourceSpec("node", 16), ResourceSpec("bb", 8)]
    return MRSchAgent(res, AgentConfig(state_hidden=state_hidden,
                                       state_out=8, module_hidden=4))


def test_agent_load_roundtrip(tmp_path):
    a = _tiny_agent()
    a.epsilon = 0.37
    path = str(tmp_path / "agent.npz")
    a.save(path)
    b = _tiny_agent()
    b.load(path)
    assert b.epsilon == 0.37
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_agent_load_rejects_wrong_width(tmp_path):
    """A checkpoint from a different architecture must fail loudly, not
    silently unflatten incompatible leaves into the live tree."""
    narrow = _tiny_agent(state_hidden=(16, 8))
    path = str(tmp_path / "narrow.npz")
    narrow.save(path)
    wide = _tiny_agent(state_hidden=(32, 16))
    before = jax.tree_util.tree_leaves(wide.params)
    with pytest.raises(ValueError, match="shape mismatch"):
        wide.load(path)
    after = jax.tree_util.tree_leaves(wide.params)
    for x, y in zip(before, after):             # params untouched on failure
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_agent_load_rejects_wrong_leaf_count(tmp_path):
    a = _tiny_agent()
    flat, _ = jax.tree_util.tree_flatten(a.params)
    path = str(tmp_path / "truncated.npz")
    np.savez(path, n=len(flat) - 2, epsilon=0.5,
             **{f"p{i}": np.asarray(x) for i, x in enumerate(flat[:-2])})
    with pytest.raises(ValueError, match="leaves"):
        a.load(path)


def test_check_leaves_compat_dtype():
    from repro.checkpoint import check_leaves_compat
    good = [np.zeros((2, 3), np.float32)]
    with pytest.raises(ValueError, match="dtype mismatch"):
        check_leaves_compat(good, [np.zeros((2, 3), np.float64)])
    check_leaves_compat(good, [np.zeros((2, 3), np.float32)])  # no raise


def test_agent_load_rejects_truncated_archive(tmp_path):
    """n claiming more leaves than the archive holds is a ValueError,
    not a KeyError from deep inside np.load."""
    a = _tiny_agent()
    flat, _ = jax.tree_util.tree_flatten(a.params)
    path = str(tmp_path / "claims_more.npz")
    np.savez(path, n=len(flat) + 2, epsilon=0.5,
             **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})
    with pytest.raises(ValueError, match="absent"):
        a.load(path)
