"""Scenario registry + workload-drift subsystem (§V-D machinery)."""
import numpy as np
import pytest

from repro.core import FCFSPolicy
from repro.workloads import (DriftPhase, DriftSchedule, ScenarioSpec,
                             ThetaConfig, apply_drift, build_jobs,
                             generate_trace, get_scenario, register,
                             run_phases, scenario_names, segment_jobs,
                             step_schedule)

CFG = ThetaConfig.mini(seed=3, duration_days=6, jobs_per_day=200)


# ----------------------------------------------------------------- registry
def test_registry_covers_every_family():
    names = set(scenario_names())
    assert {f"S{i}" for i in range(1, 11)} <= names
    assert {"theta-base", "diurnal-heavy", "bursty-campaigns",
            "size-skew-small", "size-skew-large"} <= names
    assert set(scenario_names(family="drift")) == {
        "drift-bb-surge", "drift-arrival-ramp", "drift-node-shift",
        "drift-failure-wave"}
    assert set(scenario_names(family="workflow")) == {
        "workflow-pipelines", "workflow-ensembles"}
    assert set(scenario_names(family="faulty")) == {
        "faulty-jobs", "faulty-drain"}
    assert set(scenario_names(tag="power")) == {f"S{i}" for i in range(6, 11)}


def test_workflow_scenarios_build_dags():
    from repro.sim.lifecycle import workflow_components
    for name in ("workflow-pipelines", "workflow-ensembles"):
        jobs = build_jobs(name, CFG, seed=1)
        comps = workflow_components(jobs)
        assert comps, name
        jids = {j.jid for j in jobs}
        for j in jobs:
            assert set(j.deps) <= jids and j.jid not in j.deps
            if j.deps:
                assert j.think_time >= 0.0
    # Ensembles contain fan-in joins (a job with >1 parent).
    ens = build_jobs("workflow-ensembles", CFG, seed=1)
    assert any(len(j.deps) > 1 for j in ens)


def test_faulty_scenarios_carry_failure_plan():
    jobs = build_jobs("faulty-jobs", CFG, seed=1)
    afflicted = [j for j in jobs if j.fail_times]
    assert 0 < len(afflicted) < len(jobs)
    for j in afflicted:
        assert all(0.0 < f < j.runtime for f in j.fail_times)
    # faulty-drain puts the plan on the spec, not the jobs.
    spec = get_scenario("faulty-drain")
    assert spec.faults is not None and spec.faults.relative
    assert not any(j.fail_times for j in build_jobs("faulty-drain", CFG, seed=1))


def test_drift_failure_wave_is_mid_trace_only():
    jobs = sorted(build_jobs("drift-failure-wave", CFG, seed=1),
                  key=lambda j: j.submit)
    t0, t1 = jobs[0].submit, jobs[-1].submit
    frac = [(j.submit - t0) / max(t1 - t0, 1e-9)
            for j in jobs if j.fail_times]
    assert frac, "wave injected no failures"
    assert min(frac) >= 0.35 and max(frac) <= 0.85


def test_unknown_scenario_lists_known_names():
    with pytest.raises(KeyError, match="drift-bb-surge"):
        get_scenario("no-such-scenario")


def test_duplicate_registration_rejected():
    spec = get_scenario("S1")
    with pytest.raises(ValueError, match="already registered"):
        register(spec)
    register(spec, overwrite=True)      # explicit overwrite allowed


def test_builds_are_deterministic_per_seed():
    a = build_jobs("bursty-campaigns", CFG, seed=2)
    b = build_jobs("bursty-campaigns", CFG, seed=2)
    c = build_jobs("bursty-campaigns", CFG, seed=3)
    key = lambda js: [(j.jid, j.submit, tuple(sorted(j.demands.items())))
                      for j in js]
    assert key(a) == key(b)
    assert key(a) != key(c)


def test_paper_scenarios_match_direct_builds():
    """Registry S-names delegate to scenarios.build_scenarios verbatim."""
    from repro.workloads import build_scenarios
    direct = build_scenarios(CFG, names=("S4",), seed=5)["S4"]
    via_registry = build_jobs("S4", CFG, seed=5)
    assert [(j.jid, j.demands["bb"]) for j in direct] == \
        [(j.jid, j.demands["bb"]) for j in via_registry]


def test_power_family_attaches_power_demands():
    jobs = build_jobs("S7", CFG, seed=1)
    assert all(j.demands.get("power", 0) >= 1 for j in jobs)


def test_size_skew_shifts_node_demand_distribution():
    small = build_jobs("size-skew-small", CFG, seed=1)
    large = build_jobs("size-skew-large", CFG, seed=1)
    med = lambda js: np.median([j.demands["node"] for j in js])
    assert med(small) * 4 < med(large)


def test_bursty_campaigns_compress_interarrivals():
    base = build_jobs("theta-base", CFG, seed=1)
    bursty = build_jobs("bursty-campaigns", CFG, seed=1)
    gaps = lambda js: np.diff(sorted(j.submit for j in js))
    # Same jobs, regrouped: many tiny within-burst gaps -> median drops.
    assert np.median(gaps(bursty)) < 0.5 * np.median(gaps(base))
    assert len(bursty) == len(base)


def test_capacity_invariants_hold_for_all_scenarios():
    cfg = ThetaConfig.mini(seed=0, duration_days=1.5, jobs_per_day=150)
    for name in scenario_names():
        for j in build_jobs(name, cfg, seed=1):
            assert 0 < j.demands["node"] <= cfg.n_nodes, name
            assert 0 <= j.demands["bb"] <= cfg.bb_units, name


def test_runtime_registration_extension():
    register(ScenarioSpec(
        name="test-custom", family="synthetic",
        build=lambda cfg, seed: generate_trace(cfg)[: 5],
        description="tiny custom scenario"), overwrite=True)
    assert len(build_jobs("test-custom", CFG)) == 5


# -------------------------------------------------------------------- drift
def test_drift_schedule_validation():
    with pytest.raises(ValueError, match="sorted"):
        DriftSchedule(phases=(DriftPhase(start=0.5), DriftPhase(start=0.0)))
    with pytest.raises(ValueError, match="first at 0"):
        DriftSchedule(phases=(DriftPhase(start=0.2),))
    with pytest.raises(ValueError, match="rate_scale"):
        DriftPhase(start=0.0, rate_scale=0.0)
    with pytest.raises(ValueError, match="mode"):
        DriftSchedule(phases=(DriftPhase(start=0.0),), mode="cubic")


def test_seeded_mid_trace_shift_changes_bb_distribution():
    """Acceptance criterion: pre/post-shift BB demand measurably differs."""
    jobs = apply_drift(generate_trace(CFG),
                       step_schedule(at=0.5, bb_fraction=0.85), CFG, seed=11)
    t0, t1 = jobs[0].submit, jobs[-1].submit
    mid = t0 + 0.5 * (t1 - t0)
    pre = np.mean([j.demands["bb"] > 0 for j in jobs if j.submit < mid])
    post = np.mean([j.demands["bb"] > 0 for j in jobs if j.submit >= mid])
    assert pre < 0.55                    # base Darshan-style mix
    assert post == pytest.approx(0.85, abs=0.06)
    # deterministic for the seed
    again = apply_drift(generate_trace(CFG),
                        step_schedule(at=0.5, bb_fraction=0.85), CFG, seed=11)
    assert [(j.jid, j.demands["bb"]) for j in again] == \
        [(j.jid, j.demands["bb"]) for j in jobs]


def test_drift_registry_scenario_applies_shift():
    jobs = build_jobs("drift-bb-surge", CFG, seed=1)
    t0, t1 = jobs[0].submit, jobs[-1].submit
    mid = t0 + 0.5 * (t1 - t0)
    pre = np.mean([j.demands["bb"] > 0 for j in jobs if j.submit < mid])
    post = np.mean([j.demands["bb"] > 0 for j in jobs if j.submit >= mid])
    assert post - pre > 0.2


def test_rate_ramp_compresses_late_arrivals():
    jobs = apply_drift(
        generate_trace(CFG),
        DriftSchedule(mode="ramp", phases=(
            DriftPhase(start=0.0), DriftPhase(start=1.0, rate_scale=4.0))),
        CFG, seed=1)
    gaps = np.diff([j.submit for j in jobs])
    q = len(gaps) // 4
    assert gaps[-q:].mean() < 0.6 * gaps[:q].mean()


def test_ramp_interpolates_between_phases():
    sched = DriftSchedule(mode="ramp", phases=(
        DriftPhase(start=0.0, node_scale=1.0),
        DriftPhase(start=1.0, node_scale=3.0)))
    assert sched.params_at(0.0)["node_scale"] == pytest.approx(1.0)
    assert sched.params_at(0.5)["node_scale"] == pytest.approx(2.0)
    assert sched.params_at(1.0)["node_scale"] == pytest.approx(3.0)
    piece = DriftSchedule(phases=sched.phases)      # piecewise: hard step
    assert piece.params_at(0.99)["node_scale"] == pytest.approx(1.0)
    assert piece.params_at(1.0)["node_scale"] == pytest.approx(3.0)


def test_node_scale_clamps_to_cluster():
    sched = DriftSchedule(phases=(DriftPhase(start=0.0, node_scale=1e6),))
    jobs = apply_drift(generate_trace(CFG), sched, CFG, seed=1)
    assert all(j.demands["node"] == CFG.n_nodes for j in jobs)


# ------------------------------------------------------------------- phases
def test_segment_jobs_partitions_and_rebases():
    jobs = generate_trace(CFG)
    segs = segment_jobs(jobs, 3)
    assert sum(len(s) for s in segs) == len(jobs)
    for seg in segs:
        assert seg[0].submit == 0.0
        assert all(seg[i].submit <= seg[i + 1].submit
                   for i in range(len(seg) - 1))


def test_run_phases_isolates_sequential_policies_per_lane():
    from repro.core import GAConfig, GAOptimizer
    cfg = ThetaConfig.mini(seed=0, duration_days=0.5, jobs_per_day=100)
    phases = segment_jobs(build_jobs("S1", cfg, seed=1), 2)
    ga = lambda: GAOptimizer(GAConfig(population=6, generations=2))
    # sharing one stateful sequential policy across lanes is rejected...
    with pytest.raises(ValueError, match="policy_factory"):
        run_phases(ga(), cfg.resources(), [phases, phases])
    # ...per-lane instances via the factory give identical lanes
    out = run_phases(None, cfg.resources(), [phases, phases],
                     policy_factory=ga)
    assert sorted((p.env, p.phase) for p in out) == \
        [(0, 0), (0, 1), (1, 0), (1, 1)]
    rows = {e: [p.result.metrics.as_row() for p in
                sorted(out, key=lambda p: p.phase) if p.env == e]
            for e in (0, 1)}
    assert rows[0] == rows[1]


def test_run_phases_yields_one_result_per_phase_via_refill():
    cfg = ThetaConfig.mini(seed=0, duration_days=1.0, jobs_per_day=120)
    phases = segment_jobs(build_jobs("drift-bb-surge", cfg, seed=1), 2)
    out = run_phases(FCFSPolicy(), cfg.resources(), [phases, phases])
    assert sorted((p.env, p.phase) for p in out) == \
        [(0, 0), (0, 1), (1, 0), (1, 1)]
    for p in out:
        assert p.result.metrics.n_jobs == len(phases[p.phase]) \
            - p.result.n_unstarted
    # Both lanes play identical phases -> identical per-phase metrics.
    by_env = {e: sorted((p.phase, p.result.metrics.as_row().items())
                        for p in out if p.env == e) for e in (0, 1)}
    assert by_env[0] == by_env[1]
