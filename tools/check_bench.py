#!/usr/bin/env python3
"""Gate a bench-result JSON against a committed baseline (CI bench-gate).

    python tools/check_bench.py RESULT.json BASELINE.json [--rtol 0.25]
        [--summary-md OUT.md]

The BASELINE is the contract: every leaf it contains must exist in the
RESULT and match within tolerance — extra keys in the result are free
(benches may grow fields without breaking the gate), but curate the
baseline to stable fields only (drop wall-clock noise you don't want to
gate, keep deterministic metric rows and generous-tolerance throughput).
ALL violations are collected and reported, never just the first one —
a regressing run prints its complete damage list in one pass.

Numeric comparison is direction-aware by key name:

* higher-is-better (``*speedup*``, ``*per_sec*``, ``*throughput*``,
  ``util_*``, ``*_frac*`` e.g. completed-work fraction): only a *drop*
  below ``base * (1 - rtol)`` fails;
* lower-is-better (``*_us``, ``*_ms``, ``*seconds*``, ``*latency*``,
  ``*wait*``, ``*slowdown*``, ``*loss*``, ``*makespan*`` incl. the
  workflow pipeline makespan, ``*requeues*``, ``*n_failed*``,
  ``failed_*`` node-hours, ``*overhead*`` e.g. the telemetry-off tracer
  overhead): only a *rise* above ``base * (1 + rtol)`` fails;
* anything else: two-sided relative error > rtol fails.

Per-section tolerance overrides: a baseline may carry a top-level
``__gates__`` object (stripped from the contract) mapping a *section
name* — any dict key on the path, e.g. a policy name under the
tournament's ``per_policy`` section — to per-key rtol overrides::

    "__gates__": {"FCFS": {"avg_wait": 0.2, "*": 0.3},
                  "MRSch": {"*": 0.6}}

While descending into a dict key that names a gate section, its
overrides become active for every leaf below it: the leaf's own key
wins, then the section's ``"*"`` default, then the global ``--rtol``.
Nested sections override outer ones.

``--summary-md`` writes a markdown pass/fail table over the baseline's
top-level sections (per-policy sections are broken out one level
deeper) — CI appends it to ``$GITHUB_STEP_SUMMARY``.

Non-numeric leaves (schema strings, ``equivalent`` flags) must match
exactly.  Exit 1 with one line per violation; exit 2 on unreadable
input.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Mapping, Optional

HIGHER_IS_BETTER = ("speedup", "per_sec", "throughput", "util_", "_frac")
LOWER_IS_BETTER = ("_us", "_ms", "seconds", "latency", "wait",
                   "slowdown", "loss", "makespan", "requeues",
                   "n_failed", "failed_", "overhead")

GATES_KEY = "__gates__"


def _direction(key: str) -> str:
    k = key.lower()
    if any(p in k for p in HIGHER_IS_BETTER):
        return "higher"
    if any(p in k for p in LOWER_IS_BETTER):
        return "lower"
    return "both"


def _is_number(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def compare(result: Any, baseline: Any, rtol: float, atol: float = 1e-9,
            path: str = "$", gates: Optional[Mapping[str, Mapping[str, float]]] = None,
            section: Optional[Mapping[str, float]] = None) -> List[str]:
    """ALL violations of ``result`` against the ``baseline`` contract.

    ``gates`` maps section names (dict keys on the path) to per-key rtol
    overrides active below that key; ``section`` is the innermost active
    override map (see module docstring).
    """
    errors: List[str] = []
    if isinstance(baseline, dict):
        if not isinstance(result, dict):
            return [f"{path}: expected object, got {type(result).__name__}"]
        for key, bval in baseline.items():
            if key == GATES_KEY:
                continue
            if key not in result:
                errors.append(f"{path}.{key}: missing from result")
                continue
            sub = gates.get(key, section) if gates else section
            errors.extend(compare(result[key], bval, rtol, atol,
                                  f"{path}.{key}", gates, sub))
        return errors
    if isinstance(baseline, list):
        if not isinstance(result, list):
            return [f"{path}: expected array, got {type(result).__name__}"]
        if len(result) < len(baseline):
            # Not fail-fast: the truncation is one violation, and the
            # entries both sides DO have are still compared below.
            errors.append(f"{path}: baseline has {len(baseline)} entries, "
                          f"result only {len(result)}")
        for i, bval in enumerate(baseline[:len(result)]):
            errors.extend(compare(result[i], bval, rtol, atol, f"{path}[{i}]",
                                  gates, section))
        return errors
    key = path.rsplit(".", 1)[-1].split("[")[0]
    if _is_number(baseline):
        if not _is_number(result):
            return [f"{path}: expected number, got {result!r}"]
        if section:
            rtol = section.get(key, section.get("*", rtol))
        lo = baseline - (abs(baseline) * rtol + atol)
        hi = baseline + (abs(baseline) * rtol + atol)
        direction = _direction(key)
        if direction == "higher" and result < lo:
            return [f"{path}: regressed {baseline} -> {result} "
                    f"(below {lo:.6g}, higher is better, rtol={rtol})"]
        if direction == "lower" and result > hi:
            return [f"{path}: regressed {baseline} -> {result} "
                    f"(above {hi:.6g}, lower is better, rtol={rtol})"]
        if direction == "both" and not lo <= result <= hi:
            return [f"{path}: drifted {baseline} -> {result} "
                    f"(outside [{lo:.6g}, {hi:.6g}], rtol={rtol})"]
        return []
    if result != baseline:
        return [f"{path}: expected {baseline!r}, got {result!r}"]
    return []


def _sections(baseline: Any) -> List[str]:
    """Summary-table row paths: every top-level key, with dict-of-dict
    sections (``per_policy``-style) broken out one level deeper."""
    if not isinstance(baseline, dict):
        return ["$"]
    out: List[str] = []
    for key, val in baseline.items():
        if key == GATES_KEY:
            continue
        if (isinstance(val, dict) and val
                and all(isinstance(v, dict) for v in val.values())):
            out.extend(f"$.{key}.{k}" for k in val)
        else:
            out.append(f"$.{key}")
    return out


def summary_md(baseline: Any, errors: List[str], result_path: str,
               baseline_path: str, rtol: float) -> str:
    """Markdown pass/fail table CI appends to the step summary."""
    lines = [
        f"### bench-gate: `{result_path}` vs `{baseline_path}` "
        f"(rtol={rtol})",
        "",
        "| section | status | violations |",
        "|---|---|---|",
    ]
    claimed = set()
    for sec in _sections(baseline):
        hits = [e for e in errors
                if e.startswith(sec + ".") or e.startswith(sec + "[")
                or e.startswith(sec + ":")]
        claimed.update(hits)
        status = "❌ FAIL" if hits else "✅ pass"
        detail = "<br>".join(h.replace("|", "\\|") for h in hits[:4])
        if len(hits) > 4:
            detail += f"<br>… {len(hits) - 4} more"
        lines.append(f"| `{sec}` | {status} | {detail or '—'} |")
    orphans = [e for e in errors if e not in claimed]
    if orphans:
        lines.append("| *(other)* | ❌ FAIL | "
                     + "<br>".join(o.replace("|", "\\|")
                                   for o in orphans[:4]) + " |")
    lines += ["",
              ("**FAIL** — " + str(len(errors)) + " violation(s)") if errors
              else "**PASS** — all sections within tolerance",
              ""]
    return "\n".join(lines)


def check(result_path: str, baseline_path: str, rtol: float,
          atol: float = 1e-9):
    """Load both files -> (violations, baseline).  The baseline's
    ``__gates__`` section, when present, supplies per-section rtol
    overrides and is excluded from the contract itself."""
    with open(result_path) as f:
        result = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    gates: Optional[Dict] = None
    if isinstance(baseline, dict):
        gates = baseline.get(GATES_KEY)
    return compare(result, baseline, rtol=rtol, atol=atol,
                   gates=gates), baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail (exit 1) when a bench JSON regresses vs a baseline")
    ap.add_argument("result", help="freshly produced bench JSON")
    ap.add_argument("baseline", help="committed baseline JSON (the contract)")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="relative tolerance (default 0.25)")
    ap.add_argument("--atol", type=float, default=1e-9,
                    help="absolute slack added to every bound")
    ap.add_argument("--summary-md", default=None, metavar="OUT.md",
                    help="write a markdown pass/fail section table "
                         "(for $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    try:
        errors, baseline = check(args.result, args.baseline, rtol=args.rtol,
                                 atol=args.atol)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot load inputs: {e}", file=sys.stderr)
        return 2
    if args.summary_md:
        with open(args.summary_md, "w") as f:
            f.write(summary_md(baseline, errors, args.result, args.baseline,
                               args.rtol))
    for e in errors:
        print(f"REGRESSION {e}")
    if errors:
        print(f"check_bench: {len(errors)} violation(s) vs {args.baseline} "
              f"(rtol={args.rtol})", file=sys.stderr)
        return 1
    print(f"check_bench: ok ({args.result} within rtol={args.rtol} "
          f"of {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
