#!/usr/bin/env python3
"""Gate a bench-result JSON against a committed baseline (CI bench-gate).

    python tools/check_bench.py RESULT.json BASELINE.json [--rtol 0.25]

The BASELINE is the contract: every leaf it contains must exist in the
RESULT and match within tolerance — extra keys in the result are free
(benches may grow fields without breaking the gate), but curate the
baseline to stable fields only (drop wall-clock noise you don't want to
gate, keep deterministic metric rows and generous-tolerance throughput).

Numeric comparison is direction-aware by key name:

* higher-is-better (``*speedup*``, ``*per_sec*``, ``*throughput*``,
  ``util_*``, ``*_frac*`` e.g. completed-work fraction): only a *drop*
  below ``base * (1 - rtol)`` fails;
* lower-is-better (``*_us``, ``*_ms``, ``*seconds*``, ``*latency*``,
  ``*wait*``, ``*slowdown*``, ``*loss*``, ``*makespan*`` incl. the
  workflow pipeline makespan, ``*requeues*``, ``*n_failed*``,
  ``failed_*`` node-hours): only a *rise* above ``base * (1 + rtol)``
  fails;
* anything else: two-sided relative error > rtol fails.

Non-numeric leaves (schema strings, ``equivalent`` flags) must match
exactly.  Exit 1 with one line per violation; exit 2 on unreadable
input.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

HIGHER_IS_BETTER = ("speedup", "per_sec", "throughput", "util_", "_frac")
LOWER_IS_BETTER = ("_us", "_ms", "seconds", "latency", "wait",
                   "slowdown", "loss", "makespan", "requeues",
                   "n_failed", "failed_")


def _direction(key: str) -> str:
    k = key.lower()
    if any(p in k for p in HIGHER_IS_BETTER):
        return "higher"
    if any(p in k for p in LOWER_IS_BETTER):
        return "lower"
    return "both"


def _is_number(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def compare(result: Any, baseline: Any, rtol: float, atol: float = 1e-9,
            path: str = "$") -> List[str]:
    """Violations of ``result`` against the ``baseline`` contract."""
    errors: List[str] = []
    if isinstance(baseline, dict):
        if not isinstance(result, dict):
            return [f"{path}: expected object, got {type(result).__name__}"]
        for key, bval in baseline.items():
            if key not in result:
                errors.append(f"{path}.{key}: missing from result")
                continue
            errors.extend(compare(result[key], bval, rtol, atol,
                                  f"{path}.{key}"))
        return errors
    if isinstance(baseline, list):
        if not isinstance(result, list):
            return [f"{path}: expected array, got {type(result).__name__}"]
        if len(result) < len(baseline):
            return [f"{path}: baseline has {len(baseline)} entries, "
                    f"result only {len(result)}"]
        for i, bval in enumerate(baseline):
            errors.extend(compare(result[i], bval, rtol, atol, f"{path}[{i}]"))
        return errors
    key = path.rsplit(".", 1)[-1].split("[")[0]
    if _is_number(baseline):
        if not _is_number(result):
            return [f"{path}: expected number, got {result!r}"]
        lo = baseline - (abs(baseline) * rtol + atol)
        hi = baseline + (abs(baseline) * rtol + atol)
        direction = _direction(key)
        if direction == "higher" and result < lo:
            return [f"{path}: regressed {baseline} -> {result} "
                    f"(below {lo:.6g}, higher is better)"]
        if direction == "lower" and result > hi:
            return [f"{path}: regressed {baseline} -> {result} "
                    f"(above {hi:.6g}, lower is better)"]
        if direction == "both" and not lo <= result <= hi:
            return [f"{path}: drifted {baseline} -> {result} "
                    f"(outside [{lo:.6g}, {hi:.6g}])"]
        return []
    if result != baseline:
        return [f"{path}: expected {baseline!r}, got {result!r}"]
    return []


def check(result_path: str, baseline_path: str, rtol: float,
          atol: float = 1e-9) -> List[str]:
    with open(result_path) as f:
        result = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    return compare(result, baseline, rtol=rtol, atol=atol)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail (exit 1) when a bench JSON regresses vs a baseline")
    ap.add_argument("result", help="freshly produced bench JSON")
    ap.add_argument("baseline", help="committed baseline JSON (the contract)")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="relative tolerance (default 0.25)")
    ap.add_argument("--atol", type=float, default=1e-9,
                    help="absolute slack added to every bound")
    args = ap.parse_args(argv)
    try:
        errors = check(args.result, args.baseline, rtol=args.rtol,
                       atol=args.atol)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot load inputs: {e}", file=sys.stderr)
        return 2
    for e in errors:
        print(f"REGRESSION {e}")
    if errors:
        print(f"check_bench: {len(errors)} violation(s) vs {args.baseline} "
              f"(rtol={args.rtol})", file=sys.stderr)
        return 1
    print(f"check_bench: ok ({args.result} within rtol={args.rtol} "
          f"of {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
