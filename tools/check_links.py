#!/usr/bin/env python3
"""Fail on broken intra-repo links in README.md and docs/*.md.

Checks every markdown link target that is not an external URL:
  * relative file targets must exist on disk;
  * ``path#fragment`` / ``#fragment`` anchors must match a heading slug
    in the target (GitHub-style slugification).

Run from anywhere:  python tools/check_links.py  [files...]
Exit code 1 and one line per broken link on failure.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
INLINE_CODE_RE = re.compile(r"`[^`]*`")
FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def heading_slugs(path: Path) -> set:
    """GitHub-style anchors for every markdown heading in ``path``."""
    slugs = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence or not line.startswith("#"):
            continue
        title = line.lstrip("#").strip()
        title = re.sub(r"[`*_]", "", title)
        slug = re.sub(r"[^\w\- ]", "", title.lower()).strip()
        slugs.add(re.sub(r" +", "-", slug))
    return slugs


def iter_links(path: Path):
    """(line_number, target) for every markdown link outside code."""
    in_fence = False
    for ln, line in enumerate(path.read_text(encoding="utf-8").splitlines(),
                              start=1):
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for match in LINK_RE.finditer(INLINE_CODE_RE.sub("", line)):
            yield ln, match.group(1)


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)


def check_file(path: Path) -> list:
    errors = []
    for ln, target in iter_links(path):
        if target.startswith(EXTERNAL):
            continue
        base, _, fragment = target.partition("#")
        dest = path if not base else (path.parent / base).resolve()
        if base and not dest.exists():
            errors.append(f"{_rel(path)}:{ln}: broken link "
                          f"-> {target} (no such file)")
            continue
        if fragment and dest.suffix == ".md":
            if fragment not in heading_slugs(dest):
                errors.append(f"{_rel(path)}:{ln}: broken anchor "
                              f"-> {target} (no matching heading)")
    return errors


def main(argv) -> int:
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))
    missing = [f for f in files if not f.exists()]
    errors = [f"missing input file: {f}" for f in missing]
    for f in files:
        if f.exists():
            errors.extend(check_file(f))
    for e in errors:
        print(e)
    n_files = len(files) - len(missing)
    if not errors:
        print(f"ok: {n_files} files, all intra-repo links resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
