#!/usr/bin/env python3
"""Summarize a ``mrsch.trace/v1`` JSONL trace into readable tables.

    python tools/trace_report.py TRACE.jsonl [--chrome OUT.json] [--json]

Sections:

* run metadata (from the trace header);
* event counts per kind;
* per-phase / per-kernel wall-clock table aggregated from ``prof.span``
  events (count, total seconds, mean milliseconds);
* per-policy decision latency: ``policy:<name>`` spans (emitted by
  ``repro.eval.matrix.run_matrix``) divided by that policy's
  ``sched.decision`` count via the header's ``envs`` map;
* job lifecycle + serving summary (starts/finishes/fails/requeues,
  backfill share, dispatch batches and queue waits).

``--chrome`` additionally writes a Chrome-trace (Perfetto-loadable)
JSON of the same events; ``--json`` prints the machine-readable report
instead of the tables.  Exit 2 on unreadable/invalid input.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Sequence

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.obs.trace import read_trace, to_chrome  # noqa: E402


def _fmt_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    cells = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    def line(parts):
        return "  ".join(p.ljust(w) for p, w in zip(parts, widths)).rstrip()
    out = [line(headers), line(["-" * w for w in widths])]
    out += [line(r) for r in cells]
    return "\n".join(out)


def build_report(meta: Dict, events: List[Dict]) -> Dict:
    """Aggregate a trace into the report dict the CLI renders."""
    counts: Dict[str, int] = {}
    spans: Dict[str, Dict[str, float]] = {}
    starts = bf_starts = 0
    dispatch = {"batches": 0, "requests": 0, "max_wait_s": 0.0}
    env_decisions: Dict[int, int] = {}
    for e in events:
        ev = e["ev"]
        counts[ev] = counts.get(ev, 0) + 1
        if ev == "prof.span":
            s = spans.setdefault(e["name"], {"count": 0, "total_s": 0.0})
            s["count"] += 1
            s["total_s"] += e["dur_s"]
        elif ev == "job.start":
            starts += 1
            bf_starts += e.get("bf", 0)
        elif ev == "sched.decision":
            env_decisions[e["env"]] = env_decisions.get(e["env"], 0) + 1
        elif ev == "serve.dispatch":
            dispatch["batches"] += 1
            dispatch["requests"] += e["n"]
            dispatch["max_wait_s"] = max(dispatch["max_wait_s"], e["wait_s"])
    for s in spans.values():
        s["total_s"] = round(s["total_s"], 6)
        s["mean_ms"] = round(1e3 * s["total_s"] / s["count"], 3)

    # Per-policy decision latency: policy:<name> span time over that
    # policy's decision count (envs map: env id -> {policy, ...}).
    envs = meta.get("envs", {})
    per_policy: Dict[str, Dict[str, float]] = {}
    for env, n in sorted(env_decisions.items()):
        policy = envs.get(str(env), {}).get("policy", f"env{env}")
        row = per_policy.setdefault(policy, {"decisions": 0, "span_s": 0.0})
        row["decisions"] += n
    for name, row in per_policy.items():
        span = spans.get(f"policy:{name}")
        if span:
            row["span_s"] = span["total_s"]
            row["ms_per_decision"] = round(
                1e3 * span["total_s"] / max(row["decisions"], 1), 4)

    return {
        "schema": "mrsch.trace/v1",
        "meta": meta,
        "n_events": len(events),
        "counts": dict(sorted(counts.items())),
        "spans": dict(sorted(spans.items())),
        "policies": dict(sorted(per_policy.items())),
        "jobs": {
            "starts": starts,
            "backfilled": bf_starts,
            "backfill_share": round(bf_starts / starts, 4) if starts else 0.0,
            "finished": counts.get("job.finish", 0),
            "failed": counts.get("job.fail", 0),
            "requeues": counts.get("job.requeue", 0),
        },
        "serving": dispatch,
    }


def render(rep: Dict) -> str:
    out = [f"mrsch.trace/v1 report — {rep['n_events']} events"]
    meta = {k: v for k, v in rep["meta"].items() if k != "envs"}
    if meta:
        out.append("meta: " + json.dumps(meta, sort_keys=True))
    if "envs" in rep["meta"]:
        out.append(f"envs: {len(rep['meta']['envs'])} mapped")
    out += ["", "Event counts", _fmt_table(
        ("event", "count"), sorted(rep["counts"].items()))]
    if rep["spans"]:
        out += ["", "Phases / kernels (prof.span)", _fmt_table(
            ("span", "count", "total_s", "mean_ms"),
            [(n, s["count"], s["total_s"], s["mean_ms"])
             for n, s in rep["spans"].items()])]
    if rep["policies"]:
        out += ["", "Per-policy decision latency", _fmt_table(
            ("policy", "decisions", "span_s", "ms_per_decision"),
            [(n, r["decisions"], r.get("span_s", "-"),
              r.get("ms_per_decision", "-"))
             for n, r in rep["policies"].items()])]
    j = rep["jobs"]
    out += ["", "Jobs: "
            f"{j['starts']} starts ({j['backfilled']} backfilled, "
            f"share {j['backfill_share']}), {j['finished']} finished, "
            f"{j['failed']} failed, {j['requeues']} requeues"]
    s = rep["serving"]
    if s["batches"]:
        out.append(f"Serving: {s['requests']} requests in {s['batches']} "
                   f"batches, max queue wait {s['max_wait_s']}s")
    return "\n".join(out)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="mrsch.trace/v1 JSONL file")
    ap.add_argument("--chrome", metavar="OUT",
                    help="also write a Chrome-trace JSON (Perfetto)")
    ap.add_argument("--json", action="store_true",
                    help="print the machine-readable report")
    args = ap.parse_args(argv)
    try:
        meta, events = read_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    rep = build_report(meta, events)
    if args.chrome:
        Path(args.chrome).write_text(
            json.dumps(to_chrome(events, meta)), encoding="utf-8")
        print(f"wrote chrome trace: {args.chrome}", file=sys.stderr)
    print(json.dumps(rep, indent=1, sort_keys=True) if args.json
          else render(rep))
    return 0


if __name__ == "__main__":
    sys.exit(main())
